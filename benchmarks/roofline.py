"""Roofline analysis over the dry-run matrix (S.Roofline deliverable).

Per (arch x shape x mesh) cell, from the compiled dry-run artifacts:

  compute term    = HLO_FLOPs_per_device / peak_FLOPs            [s]
  memory term     = HBM_bytes_per_device / HBM_bw                [s]
  collective term = collective_bytes_per_device / ICI_link_bw    [s]

FLOPs and collective bytes come from the corrected static HLO analysis
(while-loop bodies weighted by trip count - launch/hlo_cost.py); the memory
term uses the materialized-buffer traffic proxy from the same analysis,
cross-checked against an analytic floor (weights + KV cache + token I/O).

Hardware model (TPU v5e-class): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s per ICI link.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

REPO = Path(__file__).resolve().parent.parent
DRYRUN = REPO / "experiments" / "dryrun"


def model_flops_per_device(arch: str, shape_name: str, n_devices: int) -> float:
    """MODEL_FLOPS: 6ND (train), 2ND (prefill), 2N_active*B (decode)."""
    sys.path.insert(0, str(REPO / "src"))
    from repro.configs import SHAPES, get_config
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / n_devices


def analytic_memory_floor(arch: str, shape_name: str, n_devices: int) -> float:
    """Unavoidable HBM bytes per device per step: parameter reads (+grad/opt
    updates for training), KV-cache read (+write) for decode."""
    sys.path.insert(0, str(REPO / "src"))
    from repro.configs import SHAPES, get_config
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    params = cfg.param_count()
    if shape.kind == "train":
        # bf16 params read + fp32 grad write + fp32 m,v read+write
        per_dev = params * (2 + 4 + 16) / n_devices
        # remat-saved residual stream (bf16, write+read)
        acts = (shape.global_batch * shape.seq_len * cfg.d_model * 2
                * cfg.n_layers * 2) / n_devices
        return per_dev + acts
    if shape.kind == "prefill":
        cache = (2 * cfg.n_layers * shape.global_batch * shape.seq_len
                 * cfg.n_kv_heads * cfg.head_dim * 2)
        return (params * 2 + cache) / n_devices
    # decode
    if cfg.family == "ssm":
        state = cfg.n_layers * shape.global_batch * cfg.d_model * 64 * 4
        return (params * 2 + 2 * state) / n_devices
    cache = (2 * cfg.n_layers * shape.global_batch * shape.seq_len
             * cfg.n_kv_heads * cfg.head_dim * 2)
    return (params * 2 + cache) / n_devices


def bottleneck_advice(dom: str, arch: str, shape: str) -> str:
    if dom == "collective":
        return ("reshape the sharding to cut resharding collectives "
                "(head/seq-aware constraints; bf16 payloads)")
    if dom == "memory":
        return ("raise arithmetic intensity: larger per-device batch, "
                "fused kernels to avoid materialized copies, bf16 residuals")
    return ("compute-bound: increase MXU occupancy (block shapes) or "
            "shard over more chips")


def load_cells(dirpath: Path = DRYRUN):
    cells = []
    for p in sorted(dirpath.glob("*.json")):
        if p.name.endswith(".error.json"):
            continue
        cells.append(json.loads(p.read_text()))
    return cells


def roofline_rows(cells):
    rows = []
    for rec in cells:
        n_dev = rec["n_devices"]
        corr = rec.get("corrected", {})
        flops = corr.get("flops", rec["cost"]["flops"])
        coll = corr.get("collective_bytes_tpu",
                        corr.get("collective_bytes",
                                 rec["collectives"]["total_bytes"]))
        bytes_proxy = corr.get("bytes_proxy", rec["cost"]["bytes_accessed"])
        floor = analytic_memory_floor(rec["arch"], rec["shape"], n_dev)
        mem_bytes = max(bytes_proxy, floor)
        t_c = flops / PEAK_FLOPS
        t_m = mem_bytes / HBM_BW
        t_x = coll / ICI_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_x}
        dom = max(terms, key=terms.get)
        bound = max(terms.values())
        mf = model_flops_per_device(rec["arch"], rec["shape"], n_dev)
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "dominant": dom,
            "roofline_frac": t_c / bound if bound else 0.0,
            "model_flops": mf, "hlo_flops": flops,
            "useful_ratio": mf / flops if flops else 0.0,
            "mem_gib": rec["memory"]["total_per_device_bytes"] / 2 ** 30,
            "advice": bottleneck_advice(dom, rec["arch"], rec["shape"]),
        })
    return rows


def markdown_table(rows, mesh="single") -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| roofline frac | MODEL/HLO flops | mem GiB |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} "
            f"| {r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} "
            f"| {r['dominant']} | {r['roofline_frac']:.2f} "
            f"| {r['useful_ratio']:.2f} | {r['mem_gib']:.1f} |")
    return "\n".join(out)


def run():
    cells = load_cells()
    if not cells:
        print("roofline/no_dryrun_data,0.0,run launch.dryrun first")
        return []
    rows = roofline_rows(cells)
    for r in rows:
        print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},0.0,"
              f"dom={r['dominant']};frac={r['roofline_frac']:.2f};"
              f"tc={r['t_compute_s']:.2e};tm={r['t_memory_s']:.2e};"
              f"tx={r['t_collective_s']:.2e};useful={r['useful_ratio']:.2f}")
    out = REPO / "experiments" / "roofline.md"
    out.write_text("# Roofline (single-pod 16x16)\n\n"
                   + markdown_table(rows, "single")
                   + "\n\n# Roofline (multi-pod 2x16x16)\n\n"
                   + markdown_table(rows, "multi") + "\n")
    print(f"roofline/table_written,0.0,{out}")
    return rows


if __name__ == "__main__":
    run()
