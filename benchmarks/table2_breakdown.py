"""Table II: energy breakdown of 3D-Flow across sequence lengths."""
from repro.core import simulate_attention
from repro.core.workloads import PAPER_SEQS, opt_6_7b

from .common import emit, timed

PAPER = {1024: dict(MAC=.085, Reg=.212, SRAM=.383, DRAM=.267),
         4096: dict(MAC=.117, Reg=.319, SRAM=.350, DRAM=.151),
         16384: dict(MAC=.104, Reg=.292, SRAM=.295, DRAM=.208),
         65536: dict(MAC=.120, Reg=.344, SRAM=.285, DRAM=.162)}


def run():
    # thermal feasibility (paper Section III-C)
    from repro.core.thermal import report as thermal_report
    tr = thermal_report()
    emit("thermal/stack", 0.0,
         f"tier_W={tr['tier_power_w']:.2f};total_W={tr['total_power_w']:.1f};"
         f"rise_C={tr['internal_rise_c']:.1f};Tj_C={tr['junction_temp_c']:.1f};"
         f"feasible={tr['feasible_105c']} (paper: 3.3/13.1/2.8/83-with-errata)")
    # end-to-end inference energy (paper: 32.7%..64.2% average savings)
    import statistics as st
    from repro.core import DESIGNS, simulate_model
    from repro.core.workloads import opt_6_7b, qwen_7b
    for d in DESIGNS:
        if d == "3D-Flow":
            continue
        vals = [1 - simulate_model("3D-Flow", mk(s)).total_energy
                / simulate_model(d, mk(s)).total_energy
                for mk in (opt_6_7b, qwen_7b) for s in PAPER_SEQS]
        emit(f"e2e/energy_saving_vs_{d}", 0.0,
             f"{100*st.mean(vals):.1f}% mean (paper band 32.7..64.2%; ours dilutes "
             f"short-seq cells via per-forward weight streaming - see test)")
    out = {}
    for seq in PAPER_SEQS:
        r, us = timed(simulate_attention, "3D-Flow", opt_6_7b(seq).attn)
        sh = r.energy.shares()
        out[seq] = sh
        emit(f"table2/N={seq}", us,
             f"MAC={sh['MAC']:.3f};Reg={sh['Reg']:.3f};SRAM={sh['SRAM']:.3f};"
             f"DRAM={sh['DRAM']:.3f};3D-IC={sh['3D-IC']:.3f}"
             f" (paper MAC={PAPER[seq]['MAC']};Reg={PAPER[seq]['Reg']};"
             f"SRAM={PAPER[seq]['SRAM']};DRAM={PAPER[seq]['DRAM']})")
    return out


if __name__ == "__main__":
    run()
