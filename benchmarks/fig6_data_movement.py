"""Fig 6: average data-movement volume (DRAM / SRAM / vertical) per design."""
from repro.core import DESIGNS, sweep
from repro.core.simulator import data_movement
from repro.core.workloads import PAPER_SEQS, opt_6_7b, qwen_7b

from .common import emit, timed


def run():
    wls = [m(s).attn for m in (opt_6_7b, qwen_7b) for s in PAPER_SEQS]
    res, us = timed(sweep, list(DESIGNS), wls, reps=1)
    dm = data_movement(res)
    for d, v in dm.items():
        emit(f"fig6/{d}", us / len(res),
             f"dram_GB={v['dram']/1e9:.1f};sram_GB={v['sram']/1e9:.1f};"
             f"tsv_GB={v['tsv']/1e9:.1f}")
    cut = 1 - dm["3D-Flow"]["sram"] / dm["2D-Fused"]["sram"]
    emit("fig6/ours_sram_cut_vs_fused", 0.0,
         f"{100*cut:.1f}% (paper: 76.6%)")
    emit("fig6/fused_dram_cut_vs_unfused", 0.0,
         f"{100*(1 - dm['2D-Fused']['dram']/dm['2D-Unfused']['dram']):.1f}%"
         " (paper: 85.5%)")
    return dm


if __name__ == "__main__":
    run()
