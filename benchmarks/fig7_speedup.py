"""Fig 7: inference speedup of 3D-Flow over each baseline.
Paper: 7.62x / 1.46x / 2.36x / 1.43x."""
from repro.core import DESIGNS, sweep
from repro.core.simulator import speedups
from repro.core.workloads import PAPER_SEQS, opt_6_7b, qwen_7b

from .common import emit, timed

PAPER = {"2D-Unfused": 7.62, "2D-Fused": 1.46, "Dual-SA": 2.36,
         "3D-Base": 1.43}


def run():
    wls = [m(s).attn for m in (opt_6_7b, qwen_7b) for s in PAPER_SEQS]
    res, us = timed(sweep, list(DESIGNS), wls, reps=1)
    sp = speedups(res)
    for d, v in sp.items():
        emit(f"fig7/speedup_vs_{d}", us / len(res),
             f"{v:.2f} (paper: {PAPER[d]})")
    return sp


if __name__ == "__main__":
    run()
