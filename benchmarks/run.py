"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  fig1   motivation energy split (fused vs unfused, SRAM > 60%)
  fig5   normalized attention energy, all designs, 1K..64K
  fig6   data-movement volumes (DRAM / SRAM / TSV)
  fig7   speedups vs the four baselines
  fig8   PE-array utilization
  table2 3D-Flow energy breakdown
  kernel kernel micro-benchmarks + latency-balanced block configs
  roofline  three-term roofline per dry-run cell (needs experiments/dryrun)
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (fig1_motivation, fig5_energy, fig6_data_movement,
                            fig7_speedup, fig8_utilization, kernel_bench,
                            roofline, table2_breakdown)
    fig1_motivation.run()
    fig5_energy.run()
    fig6_data_movement.run()
    fig7_speedup.run()
    fig8_utilization.run()
    table2_breakdown.run()
    kernel_bench.run()
    roofline.run()


if __name__ == "__main__":
    main()
