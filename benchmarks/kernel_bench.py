"""Kernel micro-benchmarks: jit wall time of the portable (ref) paths and
interpret-mode validation cost of the Pallas kernels, the latency-
balanced block configs the scheduler picks for TPU, and the ragged
batched chunk-prefill kernel (one launch for K chunks vs K single-row
launches - the dispatch fold behind the serve engine's one-launch
tick)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import choose_block_config
from repro.kernels import ops, ref

from .common import emit, timed


def run():
    key = jax.random.PRNGKey(0)

    def rn(*s, dtype=jnp.bfloat16):
        return jax.random.normal(key, s, jnp.float32).astype(dtype)

    B, S, H, D = 1, 1024, 8, 128
    q, k, v = rn(B, S, H, D), rn(B, S, H, D), rn(B, S, H, D)
    fa = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, causal=True,
                                                     impl="ref"))
    _, us = timed(lambda: fa(q, k, v).block_until_ready(), reps=3)
    flops = 4 * B * S * S * H * D / 2
    emit("kernel/flash_fwd_ref_1k", us, f"gflops={flops/us/1e3:.1f}")

    kc, vc = rn(B, 32768, H, D), rn(B, 32768, H, D)
    qd = rn(B, 1, H, D)
    fd = jax.jit(lambda q, kc, vc: ops.flash_decode(q, kc, vc, 32768,
                                                    impl="ref"))
    _, us = timed(lambda: fd(qd, kc, vc).block_until_ready(), reps=3)
    emit("kernel/flash_decode_ref_32k", us,
         f"GBps={(2*32768*H*D*2)/us/1e3:.1f}")

    x = rn(2, 512, 8, 64, dtype=jnp.float32)
    dt = jax.nn.softplus(rn(2, 512, 8, dtype=jnp.float32))
    A = jnp.abs(rn(8, dtype=jnp.float32)) + 0.1
    Bm, Cm = rn(2, 512, 16, dtype=jnp.float32), rn(2, 512, 16, dtype=jnp.float32)
    m2 = jax.jit(lambda *a: ops.mamba2_scan(*a, impl="ref"))
    _, us = timed(lambda: m2(x, dt, A, Bm, Cm).block_until_ready(), reps=3)
    emit("kernel/mamba2_chunked_ref", us, "chunk=128")

    r = rn(2, 512, 8, 64, dtype=jnp.float32)
    kk = rn(2, 512, 8, 64, dtype=jnp.float32)
    vv = rn(2, 512, 8, 64, dtype=jnp.float32)
    w = jnp.exp(-jnp.exp(jnp.clip(rn(2, 512, 8, 64, dtype=jnp.float32),
                                  -8, 0.75)))
    u = rn(8, 64, dtype=jnp.float32) * 0.1
    rw = jax.jit(lambda *a: ops.rwkv6_scan(*a, impl="ref"))
    _, us = timed(lambda: rw(r, kk, vv, w, u).block_until_ready(), reps=3)
    emit("kernel/rwkv6_chunked_ref", us, "chunk=32")

    # ragged batched chunk prefill: K chunks of K different sequences at K
    # different prompt positions - ONE launch (the serve one-launch tick)
    # vs K single-row launches (the sequential per-chunk oracle)
    Kc, Sc, Hqc, Hkvc, Dc, psc = 4, 128, 8, 4, 64, 32
    n_pages, n_max = 64, 16
    kp = rn(n_pages, psc, Hkvc, Dc, dtype=jnp.float32)
    vp = rn(n_pages, psc, Hkvc, Dc, dtype=jnp.float32)
    qc = rn(Kc, Sc, Hqc, Dc, dtype=jnp.float32)
    rng = np.random.default_rng(0)
    perm = rng.permutation(np.arange(1, n_pages))
    tables = np.zeros((Kc, n_max), np.int32)
    offsets = np.array([0, 96, 224, 352], np.int32)
    pos = 0
    for row in range(Kc):
        need = (int(offsets[row]) + Sc + psc - 1) // psc
        tables[row, :need] = perm[pos:pos + need]
        pos += need
    tbl_j = jnp.asarray(tables)
    off_j = jnp.asarray(offsets)
    tls_j = off_j + Sc
    single = jax.jit(lambda q, row, off: ops.paged_prefill_attention(
        q, kp, vp, row, off, impl="ref"))
    batched = jax.jit(lambda q: ops.batched_paged_prefill_attention(
        q, kp, vp, tbl_j, off_j, tls_j, impl="ref"))
    _, us = timed(lambda: [single(qc[row:row + 1], tbl_j[row],
                                  off_j[row]).block_until_ready()
                           for row in range(Kc)], reps=3)
    emit(f"kernel/chunk_prefill_ref_seq_k{Kc}", us, f"launches={Kc}")
    _, us_b = timed(lambda: batched(qc).block_until_ready(), reps=3)
    emit(f"kernel/chunk_prefill_ref_batched_k{Kc}", us_b,
         f"launches=1;speedup={us / max(us_b, 1e-9):.2f}")

    # latency-balanced Pallas block configs (the paper's scheduling method)
    for hd, seq in ((64, 4096), (128, 4096), (128, 32768), (256, 32768)):
        bc = choose_block_config(hd, seq)
        emit(f"kernel/block_config_d{hd}_s{seq}", 0.0,
             f"bq={bc.block_q};bkv={bc.block_kv};"
             f"balance={bc.balanced:.2f};bubble_free={bc.bubble_free};"
             f"vmem_KiB={bc.vmem_bytes//1024}")


if __name__ == "__main__":
    run()
