"""Fig 1: energy breakdown, fused vs unfused 2D execution vs sequence length.

Claim reproduced: once fusion removes off-chip traffic, on-chip SRAM access
dominates (>60% of energy for N >= 2k)."""
from repro.core import simulate_attention
from repro.core.workloads import PAPER_SEQS, opt_6_7b

from .common import emit, timed


def run():
    rows = []
    for design in ("2D-Unfused", "2D-Fused"):
        for seq in PAPER_SEQS:
            (r, us) = timed(simulate_attention, design, opt_6_7b(seq).attn)
            sh = r.energy.shares()
            rows.append((design, seq, sh))
            emit(f"fig1/{design}/N={seq}", us,
                 f"SRAM={sh['SRAM']:.3f};DRAM={sh['DRAM']:.3f};"
                 f"MAC={sh['MAC']:.3f};Reg={sh['Reg']:.3f}")
    fused_big = [sh for d, s, sh in rows if d == "2D-Fused" and s >= 2048]
    claim = all(sh["SRAM"] > 0.60 for sh in fused_big)
    emit("fig1/claim_sram_gt_60pct_fused_N>=2k", 0.0, str(claim))
    return rows


if __name__ == "__main__":
    run()
