"""Fig 8: average PE-array utilization.  Paper: ours ~87%."""
from repro.core import DESIGNS, sweep
from repro.core.simulator import mean_utilization
from repro.core.workloads import PAPER_SEQS, opt_6_7b, qwen_7b

from .common import emit, timed


def run():
    wls = [m(s).attn for m in (opt_6_7b, qwen_7b) for s in PAPER_SEQS]
    res, us = timed(sweep, list(DESIGNS), wls, reps=1)
    util = mean_utilization(res)
    for d, v in util.items():
        emit(f"fig8/util_{d}", us / len(res), f"{v:.3f}")
    emit("fig8/claim_ours_~87pct", 0.0,
         f"{util['3D-Flow']:.3f} (paper: 0.87)")
    return util


if __name__ == "__main__":
    run()
