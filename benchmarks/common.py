"""Shared helpers for the paper-figure benchmarks."""
import time


def timed(fn, *args, reps=3, **kw):
    fn(*args, **kw)                      # warmup
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / reps
    return out, dt * 1e6                 # us per call


def emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
