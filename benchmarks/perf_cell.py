"""One-cell perf measurement for the S.Perf hypothesis->change->measure loop.

Usage:
  PYTHONPATH=src python -m benchmarks.perf_cell --arch granite-8b \
      --shape train_4k [--mesh single] [--tag variant-name]

Prints the three roofline terms, the per-type collective breakdown, and the
top collective shapes - the 'profile' the iteration loop reads.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion")
os.environ["REPRO_MIXED_DOTS"] = "1"

import argparse
import re
import sys
from collections import Counter
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="V?")
    ap.add_argument("--top-collectives", type=int, default=8)
    args = ap.parse_args()

    from repro.compat import use_mesh
    from repro.configs import SHAPES, get_config
    from repro.configs.base import TrainConfig
    from repro.launch.dryrun import run_cell
    from repro.launch.hlo_cost import analyze, parse_hlo, _trip_count
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import prefill_cell, serve_cell, train_cell

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    with use_mesh(mesh):
        if shape.kind == "train":
            tcfg = TrainConfig(global_batch=shape.global_batch,
                               seq_len=shape.seq_len, remat="full")
            step, cargs, shardings = train_cell(cfg, shape, mesh, tcfg)
        elif shape.kind == "prefill":
            step, cargs, shardings = prefill_cell(cfg, shape, mesh)
        else:
            step, cargs, shardings = serve_cell(cfg, shape, mesh)
        compiled = jax.jit(step, in_shardings=shardings).lower(*cargs).compile()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()

    corr = analyze(hlo)
    t_c = corr["flops"] / PEAK_FLOPS
    t_m = corr["bytes"] / HBM_BW
    t_x = corr.get("collective_bytes_tpu", corr["collective_bytes"]) / ICI_BW
    mem_gib = (mem.argument_size_in_bytes + mem.output_size_in_bytes
               + mem.temp_size_in_bytes) / 2 ** 30
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    print(f"[{args.tag}] {args.arch}/{args.shape}/{args.mesh}")
    print(f"  mem/dev {mem_gib:6.2f} GiB | tc {t_c:.3e} s | tm {t_m:.3e} s "
          f"| tx {t_x:.3e} s | dominant={dom} "
          f"| roofline_frac={t_c/max(t_c,t_m,t_x):.2f}")
    for k, v in corr["collectives"].items():
        if v["bytes"]:
            print(f"  {k:20s} count {v['count']:10.0f}  "
                  f"{v['bytes']/2**30:9.2f} GiB raw | "
                  f"{v.get('bytes_tpu', v['bytes'])/2**30:9.2f} GiB tpu-equiv")

    # top individual collective shapes (weighted by loop multiplicity)
    comps = parse_hlo(hlo)
    from collections import defaultdict
    mult = defaultdict(float)

    def visit(name, m, depth=0):
        if depth > 64 or name not in comps:
            return
        mult[name] += m
        c = comps[name]
        for body, cond in c.while_edges:
            t = _trip_count(comps[cond]) if cond in comps else 1
            visit(body, m * t, depth + 1)
            visit(cond, m * (t + 1), depth + 1)
        for bg in c.branch_groups:
            for b in bg:
                visit(b, m / len(bg), depth + 1)
        for cal in c.callees:
            visit(cal, m, depth + 1)

    visit(comps["__entry__"].name, 1.0)
    from repro.launch.hlo_cost import COLLECTIVE_OPS, _nbytes
    rows = Counter()
    for name, m in mult.items():
        if name == "__entry__" or name not in comps:
            continue
        for ins in comps[name].instrs:
            base = ins.op.replace("-start", "")
            if base in COLLECTIVE_OPS:
                ts = ins.rhs.split(ins.op + "(")[0]
                shape_m = re.search(r"\w+\[[\d,]*\]", ts)
                rows[(base, shape_m.group(0) if shape_m else "?")] += \
                    m * _nbytes(ts)
    for (op, sh), b in rows.most_common(args.top_collectives):
        print(f"    {b/2**30:8.2f} GiB  {op:20s} {sh}")


if __name__ == "__main__":
    main()
