"""Serving benchmark: dense vs paged KV cache at mixed sequence lengths.

    PYTHONPATH=src python benchmarks/serve_bench.py
    PYTHONPATH=src python benchmarks/serve_bench.py --quick   # CI-sized

Serves the same mixed-length request trace (short / medium / long prompts,
default 128 / 1024 / 3968 with max_seq=4096) through both engine modes and
reports tokens/s and KV-cache memory.  The point of the paged mode: the
dense engine preallocates max_batch * max_seq KV whether requests need it
or not; the paged pool is sized to the traffic, so peak KV bytes drop while
throughput holds (requests that don't fit simply queue - admission
backpressure, never a mid-flight failure).

Output (CSV, one row per mode):
    mode,requests,tokens,seconds,tok_per_s,kv_bytes,peak_pages,pool_pages
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.serve import dense_kv_bytes, paged_kv_bytes, pages_needed
from repro.serve.engine import ServeEngine


def run_mode(model, params, scfg, prompts, max_new):
    eng = ServeEngine(model, params, scfg)
    t0 = time.time()
    for p in prompts:
        eng.submit(p, max_new_tokens=max_new)
    done = eng.run_until_done(max_ticks=100_000)
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    assert len(done) == len(prompts), (len(done), len(prompts))
    return {"requests": len(done), "tokens": toks, "seconds": dt,
            "tok_per_s": toks / max(dt, 1e-9),
            "kv_bytes": eng.kv_cache_bytes(),
            "peak_pages": getattr(eng, "peak_pages", 0),
            "pool_pages": scfg.pool_pages() if scfg.paged else 0}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=4096)
    ap.add_argument("--lens", type=int, nargs="+", default=[128, 1024, 3968],
                    help="mixed prompt lengths (cycled)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--page-size", type=int, default=64)
    ap.add_argument("--num-pages", type=int, default=0,
                    help="paged pool size (0 = sized to the trace: "
                         "max_batch * pages(longest request) / 2 + slack)")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (max_seq=512, lens 64/128/448)")
    args = ap.parse_args(argv)
    if args.quick:
        args.max_seq, args.lens = 512, [64, 128, 448]
        args.max_new, args.page_size = 16, 16

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=args.lens[i % len(args.lens)]).tolist()
               for i in range(args.requests)]

    num_pages = args.num_pages
    if num_pages == 0:
        # size the pool to the trace: the longest request fully resident on
        # every slot would be dense-equivalent; halving it is what paging
        # buys on a mixed trace (short requests hold few pages)
        per_req = pages_needed(max(args.lens) + args.max_new, args.page_size)
        num_pages = max(args.max_batch * per_req // 2,
                        2 * per_req) + 1

    dense_cfg = ServeConfig(max_batch=args.max_batch, max_seq=args.max_seq,
                            max_new_tokens=args.max_new)
    paged_cfg = ServeConfig(max_batch=args.max_batch, max_seq=args.max_seq,
                            max_new_tokens=args.max_new, paged=True,
                            page_size=args.page_size, num_pages=num_pages)

    print(f"# arch={cfg.name} max_batch={args.max_batch} "
          f"max_seq={args.max_seq} lens={args.lens} "
          f"requests={args.requests} max_new={args.max_new}")
    print(f"# capacity math: dense {dense_kv_bytes(cfg, dense_cfg)} B, "
          f"paged pool {paged_kv_bytes(cfg, paged_cfg, num_pages)} B "
          f"({num_pages} pages x {args.page_size} tok)")
    print("mode,requests,tokens,seconds,tok_per_s,kv_bytes,"
          "peak_pages,pool_pages")
    rows = {}
    for mode, scfg in (("dense", dense_cfg), ("paged", paged_cfg)):
        r = run_mode(model, params, scfg, prompts, args.max_new)
        rows[mode] = r
        print(f"{mode},{r['requests']},{r['tokens']},{r['seconds']:.2f},"
              f"{r['tok_per_s']:.1f},{r['kv_bytes']},{r['peak_pages']},"
              f"{r['pool_pages']}")
    saved = 1 - rows["paged"]["kv_bytes"] / rows["dense"]["kv_bytes"]
    print(f"# paged peak KV bytes {rows['paged']['kv_bytes']} "
          f"vs dense {rows['dense']['kv_bytes']} "
          f"({saved:.0%} smaller)")
    assert rows["paged"]["kv_bytes"] < rows["dense"]["kv_bytes"], \
        "paged pool must be strictly smaller than the dense cache"
    return rows


if __name__ == "__main__":
    main()
