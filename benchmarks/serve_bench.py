"""Serving benchmark: dense vs paged KV cache, prefix caching, and
token-budget chunked prefill.

    PYTHONPATH=src python benchmarks/serve_bench.py
    PYTHONPATH=src python benchmarks/serve_bench.py --quick   # CI-sized
    PYTHONPATH=src python benchmarks/serve_bench.py --prefix-trace \
        --json serve_prefix_bench.json
    PYTHONPATH=src python benchmarks/serve_bench.py --chunked \
        --json serve_chunked_bench.json

Default mode serves the same mixed-length request trace (short / medium /
long prompts, default 128 / 1024 / 3968 with max_seq=4096) through the
dense and the paged engine and reports tokens/s and KV-cache memory: the
dense engine preallocates max_batch * max_seq KV whether requests need it
or not; the paged pool is sized to the traffic, so peak KV bytes drop
while throughput holds (admission backpressure, never a mid-flight
failure).

--prefix-trace serves a SHARED-PREFIX trace (the shape of real traffic:
shared system prompts / few-shot templates with per-request tails)
through the paged engine with prefix caching off and on.  One warmup
request per prefix publishes its prompt pages into the radix tree; the
followers then run concurrently, attach the cached pages, and prefill
only their tails.  Reported: prefix hit rate, prefill tokens computed /
saved, and peak working-set pages - with bitwise-identical greedy outputs
cache-on vs cache-off (asserted).

--chunked serves the mixed trace through the paged engine with monolithic
admission-time prefill vs the token-budget scheduler (chunked prefill
mixed into decode ticks, docs/scheduling.md).  Reported: p50/p95 TTFT and
time-between-tokens, in wall seconds and in deterministic WORK-CLOCK
tokens (total prefill + decode tokens executed between two events - the
exact size of a scheduling bubble), plus dispatch accounting (jitted
launches and device->host transfers per tick, recompile count, host-loop
wall time).  Asserted: byte-identical greedy outputs, a hard per-tick
budget ceiling, and lower p95 work-clock TTFT and TBT for chunked
(decodes no longer stall behind whole-prompt prefills).

--chunked --batched additionally runs the sequential per-chunk oracle
(ServeConfig.batched=False) and pins the ONE-LAUNCH TICK: the batched
engine must serve a steady-state tick - K prefill chunks + M decodes in
flight - with exactly one batched ragged prefill launch, one fused
decode launch, and one device->host transfer, with greedy outputs
bit-identical to the sequential path and strictly fewer total launches.

--speculative serves a shared-prefix LONG-GENERATION trace through the
paged chunked batched engine with self-speculative decoding off vs on
(draft by n-gram lookup over each request's own history, verify the
chain in one batched chunk launch, roll back rejects by lens -
docs/speculative.md).  Asserted, never eyeballed: bit-identical greedy
outputs, equal work-clock totals, nonzero acceptance, and generated
tokens per decode launch > 1.5x the non-speculative baseline (tokens
per KV page read reported alongside).

--fleet serves the shared-prefix trace (one warmup per prefix, then the
followers) through a FleetRouter (serve/router.py, docs/routing.md)
sweeping replica counts (default 1/2/4) under the cache-hit-weighted
affinity policy, with round-robin at the same replica counts as the
control.  Affinity peeks every replica's radix tree per submit and lands
each follower on the replica that already caches its prefix; round-robin
scatters them.  Asserted, never eyeballed: bit-identical greedy outputs
across EVERY fleet size and policy (replicas share the jitted steps),
per-replica page conservation after the drain, and strictly fewer
prefill tokens computed under affinity than round-robin at every n > 1
(the prefill-tokens-saved curve is the headline artifact,
BENCH_fleet.json).

--tp serves the mixed trace through the paged chunked batched engine at
tp_degree=1 and tp_degree=N (--tp-degree, default 2): the KV page pool
and paged attention kernels shard across devices on the head axis with
the block table replicated (docs/tensor_parallel.md).  Asserted, never
eyeballed: bit-identical greedy outputs, equal work clocks and page
reads, and per-device KV read bytes <= single-device bytes / N + the
block-table replication overhead (the headline artifact, BENCH_tp.json).
Needs >= N devices (on CPU,
XLA_FLAGS=--xla_force_host_platform_device_count=N).

--preempt-trace exercises decode-priority budget shaping and victim
preemption (docs/scheduling.md): in-flight decodes' p95 work-clock TBT
under a long-prompt prefill burst must be strictly lower with
`decode_priority` on (the prefill share of every tick is capped), and a
high-priority burst against a capacity cap (ServeConfig.usable_pages)
must shed, park, and resume low-priority victims with greedy outputs
bit-identical to the same trace served uncapped.

Output: CSV rows per mode; --json additionally writes the full metrics
dict (CI uploads it as a workflow artifact).

--emit-trace PATH / --emit-metrics PATH (any mode, composable with the
flags above) write the engine-telemetry artifacts from the LAST engine
the selected mode ran - the interesting one in every comparison (paged,
prefix-on, chunked-batched, spec-on, preempted): a Chrome trace-event
JSON openable in Perfetto (docs/observability.md) and a metrics snapshot
+ per-launch-kind data-movement breakdown (HBM/SRAM bytes, energy,
padding overhead).  The emitted per-launch KV-page counts are asserted
to match the engine's PageAllocator-derived accounting before the file
is written.
"""
import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.serve import (FleetConfig, FleetRouter, dense_kv_bytes,
                         paged_kv_bytes, pages_needed)
from repro.serve.engine import ServeEngine

# --emit-trace / --emit-metrics plumbing: every mode builds engines
# through make_engine, which turns span tracing on when a trace was
# requested and remembers the most recent engine so emit_artifacts can
# export from the mode's final (always the telemetry-interesting) run
_EMIT = {"trace": "", "metrics": "", "eng": None}


def make_engine(model, params, scfg):
    if _EMIT["trace"]:
        scfg = dataclasses.replace(scfg, telemetry=True)
    eng = ServeEngine(model, params, scfg)
    _EMIT["eng"] = eng
    return eng


def emit_artifacts():
    eng = _EMIT["eng"]
    if eng is None:
        return
    if _EMIT["trace"]:
        eng.export_trace(_EMIT["trace"])
        print(f"# wrote {_EMIT['trace']} (open in Perfetto / "
              f"chrome://tracing)")
    if _EMIT["metrics"]:
        movement = eng.movement_stats()
        recs = eng.launch_records()
        # the attribution must agree with the allocator: per-launch page
        # counts come from block-table rows, the legacy counter from the
        # analytic ceil - both sides of the same accounting
        pages_rec = sum(r.kv_pages_read for r in recs
                        if r.kind in ("decode", "spec_verify"))
        assert pages_rec == eng.kv_pages_read, \
            f"launch-record KV pages {pages_rec} != engine counter " \
            f"{eng.kv_pages_read}"
        Path(_EMIT["metrics"]).write_text(json.dumps(
            {"metrics": eng.metrics_snapshot(), "movement": movement,
             "launches": len(recs)}, indent=2))
        tot = movement.get("total", {})
        print(f"# wrote {_EMIT['metrics']}: launches={len(recs)} "
              f"hbm={tot.get('hbm_bytes', 0):.3e}B "
              f"sram={tot.get('sram_bytes', 0):.3e}B "
              f"energy={tot.get('energy_j', 0):.3e}J "
              f"padding_overhead={tot.get('padding_overhead', 0):.3f}")


def run_mode(model, params, scfg, prompts, max_new):
    eng = make_engine(model, params, scfg)
    t0 = time.time()
    for p in prompts:
        eng.submit(p, max_new_tokens=max_new)
    done = eng.run_until_done(max_ticks=100_000)
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    assert len(done) == len(prompts), (len(done), len(prompts))
    return {"requests": len(done), "tokens": toks, "seconds": dt,
            "tok_per_s": toks / max(dt, 1e-9),
            "kv_bytes": eng.kv_cache_bytes(),
            "peak_pages": eng.peak_pages,
            "pool_pages": scfg.pool_pages() if scfg.paged else 0}


# ===========================================================================
# chunked-prefill trace (monolithic vs token-budget scheduler)
# ===========================================================================

def make_wave_trace(rng, vocab, lens, waves):
    """`waves` arrival waves, each [longest, *shorter lens] submitted the
    same tick - the bubble-inducing shape: every wave's long prompt lands
    at the head of the FIFO queue while earlier waves are mid-decode and
    this wave's short prompts queue behind it."""
    order = sorted(lens, reverse=True)
    arrivals = []
    for w in range(waves):
        for n in order:
            arrivals.append((w * 4, rng.integers(1, vocab,
                                                 size=n).tolist()))
    return arrivals


def run_latency_mode(model, params, scfg, arrivals, max_new, short_len):
    """Serve a timed-arrival trace and report latency stats: p50/p95 TTFT,
    time-between-tokens, and per-token tick-work stalls (deterministic
    bubble sizes - see docs/scheduling.md), wall-clock and work-clock."""
    eng = make_engine(model, params, scfg)
    pending = list(arrivals)
    uids_short = []
    t0 = time.time()
    tick = 0
    done = []
    while pending or eng.queue or any(s is not None for s in eng.slots):
        while pending and pending[0][0] <= tick:
            _, prompt = pending.pop(0)
            uid = eng.submit(prompt, max_new_tokens=max_new)
            if len(prompt) <= short_len:
                uids_short.append(uid)
        done.extend(eng.tick())
        tick += 1
        assert tick < 500_000, "trace did not drain"
    dt = time.time() - t0
    assert len(done) == len(arrivals), (len(done), len(arrivals))
    outs = {r.uid: r.out_tokens for r in done}
    st = eng.stats()
    # TTFT of the interactive class: short prompts that queued behind a
    # long prefill - the requests chunking is supposed to protect
    short_reqs = [r for r in done if r.uid in uids_short]
    short_ttft = [r.ttft_work() for r in short_reqs]
    toks = sum(len(t) for t in outs.values())
    row = {"requests": len(done), "tokens": toks, "seconds": dt,
           "tok_per_s": toks / max(dt, 1e-9),
           "prefill_tokens": st["prefill_tokens"],
           "tick_token_budget": st["tick_token_budget"],
           "short_ttft_work_p95": float(np.percentile(short_ttft, 95))}
    row.update({k: st[k] for k in (
        "ticks", "chunks_run", "packs_run", "max_tick_tokens",
        "ttft_wall_p50", "ttft_wall_p95", "tbt_wall_p50", "tbt_wall_p95",
        "ttft_work_p50", "ttft_work_p95", "tbt_work_p50", "tbt_work_p95",
        "stall_work_p50", "stall_work_p95", "stall_work_max",
        # dispatch accounting: jitted launches, device->host transfers,
        # recompiles, and per-tick host-loop wall time
        "jit_calls", "host_syncs", "compile_count",
        "jit_calls_per_tick_max", "jit_calls_per_tick_mean",
        "jit_calls_per_busy_tick_max", "host_syncs_per_tick_max",
        "tick_host_wall_p50", "tick_host_wall_p95")})
    return outs, row


def run_chunked_trace(args, out_json):
    """Mixed 128/1k/4k wave trace through the paged engine: monolithic
    admission-time prefill vs chunked prefill under a per-tick token
    budget.  Asserted: byte-identical greedy outputs; tick_token_budget a
    hard per-tick ceiling the monolithic engine blows through; lower p95
    tick-work stalls (time-between-tokens for in-flight decodes) and
    lower p95 TTFT for short prompts queued behind long prefills."""
    # float32 keeps greedy argmax ties out of the comparison
    cfg = get_smoke_config(args.arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    waves = max(args.requests // len(args.lens), 2)
    arrivals = make_wave_trace(rng, cfg.vocab_size, args.lens, waves)
    short_len = sorted(args.lens)[-2]          # everything but the longest
    per_req = pages_needed(max(args.lens) + args.max_new, args.page_size)
    # a latency trace, so no slot or page contention: every request admits
    # the tick it arrives and the measured TTFT/TBT gaps are pure PREFILL
    # SCHEDULING (the default trace exercises backpressure instead)
    max_batch = len(arrivals)
    num_pages = len(arrivals) * per_req + 1
    # room for the oldest request's guaranteed chunk PLUS a
    # shortest-remaining-first chunk every tick (serve/scheduler.py)
    budget = args.tick_budget or max_batch + 2 * args.prefill_chunk
    base = dict(max_batch=max_batch, max_seq=args.max_seq,
                max_new_tokens=args.max_new, paged=True,
                page_size=args.page_size, num_pages=num_pages)
    chunk_kw = dict(chunked=True, prefill_chunk=args.prefill_chunk,
                    tick_token_budget=budget)
    cfg_mono = ServeConfig(**base)
    cfg_chunk = ServeConfig(**base, **chunk_kw)            # batched (default)
    modes = [("monolithic", cfg_mono)]
    if args.batched:
        # the sequential per-chunk oracle the one-launch tick is held to
        modes.append(("chunked_seq",
                      ServeConfig(**base, **chunk_kw, batched=False)))
    modes.append(("chunked", cfg_chunk))

    print(f"# arch={cfg.name} max_batch={max_batch} lens={args.lens} "
          f"waves={waves} max_new={args.max_new} "
          f"page={args.page_size} chunk={args.prefill_chunk} "
          f"budget={budget}")
    print("mode,requests,tokens,seconds,tok_per_s,ticks,chunks_run,"
          "max_tick_tokens,stall_work_p95,short_ttft_work_p95,"
          "tbt_wall_p95,ttft_wall_p95,jit_calls,busy_tick_jit_max,"
          "sync_max,compiles")
    rows, outs = {}, {}
    for mode, scfg in modes:
        outs[mode], r = run_latency_mode(model, params, scfg, arrivals,
                                         args.max_new, short_len)
        rows[mode] = r
        print(f"{mode},{r['requests']},{r['tokens']},{r['seconds']:.2f},"
              f"{r['tok_per_s']:.1f},{r['ticks']},{r['chunks_run']},"
              f"{r['max_tick_tokens']},{r['stall_work_p95']:.0f},"
              f"{r['short_ttft_work_p95']:.0f},"
              f"{r['tbt_wall_p95'] * 1e3:.1f}ms,"
              f"{r['ttft_wall_p95'] * 1e3:.1f}ms,"
              f"{r['jit_calls']},{r['jit_calls_per_busy_tick_max']},"
              f"{r['host_syncs_per_tick_max']},{r['compile_count']}")

    mono, chunk = rows["monolithic"], rows["chunked"]
    if args.batched:
        seq = rows["chunked_seq"]
        print(f"# one-launch ticks: busy-tick jit calls "
              f"{chunk['jit_calls_per_busy_tick_max']} vs "
              f"{seq['jit_calls_per_busy_tick_max']} sequential, total "
              f"launches {chunk['jit_calls']} vs {seq['jit_calls']}, "
              f"syncs {chunk['host_syncs']} vs {seq['host_syncs']}, "
              f"compiles {chunk['compile_count']} vs "
              f"{seq['compile_count']}")
        assert outs["chunked"] == outs["chunked_seq"], \
            "batched chunk execution changed greedy outputs"
        # the acceptance criterion: a steady-state tick with prefill AND
        # decode in flight is one batched prefill launch + one decode
        # launch; no tick ever syncs more than once
        assert chunk["jit_calls_per_busy_tick_max"] == 2, \
            f"batched busy tick ran {chunk['jit_calls_per_busy_tick_max']}" \
            f" jitted calls (want exactly 2)"
        assert chunk["jit_calls_per_tick_max"] <= 2
        assert chunk["host_syncs_per_tick_max"] <= 1
        assert chunk["jit_calls"] < seq["jit_calls"], \
            "batched path must issue fewer launches than sequential"
        rows["savings_batched"] = {
            "jit_calls_ratio": chunk["jit_calls"] / max(seq["jit_calls"], 1),
            "host_syncs_ratio": chunk["host_syncs"]
            / max(seq["host_syncs"], 1),
            "identical_greedy_outputs": True,
        }
    print(f"# p95 tick-work stall {chunk['stall_work_p95']:.0f} vs "
          f"{mono['stall_work_p95']:.0f} tokens, short-prompt p95 TTFT "
          f"{chunk['short_ttft_work_p95']:.0f} vs "
          f"{mono['short_ttft_work_p95']:.0f} work-tokens, max tick "
          f"{chunk['max_tick_tokens']} vs {mono['max_tick_tokens']}")
    assert outs["chunked"] == outs["monolithic"], \
        "chunked scheduling changed greedy outputs"
    assert chunk["max_tick_tokens"] <= budget, \
        "tick_token_budget exceeded"
    assert mono["max_tick_tokens"] > budget, \
        "monolithic trace never exceeded the budget - trace too easy to " \
        "show a scheduling bubble"
    assert chunk["stall_work_p95"] < mono["stall_work_p95"], \
        "chunked scheduling must lower p95 decode stalls (TBT)"
    assert chunk["short_ttft_work_p95"] < mono["short_ttft_work_p95"], \
        "chunked scheduling must lower p95 TTFT for short prompts"
    rows["savings"] = {
        "stall_work_p95_ratio": chunk["stall_work_p95"]
        / max(mono["stall_work_p95"], 1e-9),
        "short_ttft_work_p95_ratio": chunk["short_ttft_work_p95"]
        / max(mono["short_ttft_work_p95"], 1e-9),
        "identical_greedy_outputs": True,
    }
    if out_json:
        Path(out_json).write_text(json.dumps(rows, indent=2))
        print(f"# wrote {out_json}")
    return rows


# ===========================================================================
# shared-prefix trace (prefix caching on vs off)
# ===========================================================================

def make_prefix_trace(rng, vocab, groups, followers, shared_len, tail_len):
    """One warmup + `followers` follower prompts per shared prefix."""
    warm, follow = [], []
    for _ in range(groups):
        shared = rng.integers(1, vocab, size=shared_len).tolist()
        warm.append(shared + rng.integers(1, vocab, size=tail_len).tolist())
        for _ in range(followers):
            follow.append(shared
                          + rng.integers(1, vocab, size=tail_len).tolist())
    return warm, follow


def run_prefix_mode(model, params, scfg, warm, follow, max_new):
    eng = make_engine(model, params, scfg)
    out = {}
    t0 = time.time()
    # warmups run to completion first so their prompt pages are published
    # before any follower is admitted; followers then run concurrently
    for wave in (warm, follow):
        for p in wave:
            eng.submit(p, max_new_tokens=max_new)
        for r in eng.run_until_done(max_ticks=100_000):
            out[r.uid] = r.out_tokens
    dt = time.time() - t0
    assert len(out) == len(warm) + len(follow)
    stats = eng.prefix_stats()
    toks = sum(len(t) for t in out.values())
    return out, {
        "requests": len(out), "tokens": toks, "seconds": dt,
        "tok_per_s": toks / max(dt, 1e-9),
        "prefill_tokens": stats["prefill_tokens"],
        "prefix_hit_tokens": stats["prefix_hit_tokens"],
        "prompt_tokens": stats["prompt_tokens"],
        "hit_rate": stats["prefix_hit_tokens"]
        / max(stats["prompt_tokens"], 1),
        "cow_copies": stats["cow_copies"],
        "cached_pages": stats["cached_pages"],
        "peak_pages": stats["peak_pages"],
        "peak_live_pages": stats["peak_live_pages"],
        "pool_pages": scfg.pool_pages(),
    }


def run_prefix_trace(args, out_json):
    # float32 keeps greedy argmax ties out of the cache-on/off comparison
    cfg = get_smoke_config(args.arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    warm, follow = make_prefix_trace(rng, cfg.vocab_size, args.groups,
                                     args.followers, args.shared_len,
                                     args.tail_len)
    per_req = pages_needed(args.shared_len + args.tail_len + args.max_new,
                           args.page_size)
    num_pages = (args.groups * pages_needed(args.shared_len, args.page_size)
                 + args.max_batch * per_req + 1)
    base = dict(max_batch=args.max_batch, max_seq=args.max_seq,
                max_new_tokens=args.max_new, paged=True,
                page_size=args.page_size, num_pages=num_pages)
    cfg_off = ServeConfig(**base)
    cfg_on = ServeConfig(**base, prefix_cache=True)

    print(f"# arch={cfg.name} groups={args.groups} "
          f"followers={args.followers} shared={args.shared_len} "
          f"tail={args.tail_len} max_new={args.max_new} "
          f"page={args.page_size} pool={num_pages}")
    print("mode,requests,tokens,seconds,tok_per_s,prefill_tokens,"
          "hit_rate,peak_live_pages,peak_pages,cached_pages,cow_copies")
    rows = {}
    outs = {}
    for mode, scfg in (("prefix_off", cfg_off), ("prefix_on", cfg_on)):
        outs[mode], r = run_prefix_mode(model, params, scfg, warm, follow,
                                        args.max_new)
        rows[mode] = r
        print(f"{mode},{r['requests']},{r['tokens']},{r['seconds']:.2f},"
              f"{r['tok_per_s']:.1f},{r['prefill_tokens']},"
              f"{r['hit_rate']:.2f},{r['peak_live_pages']},"
              f"{r['peak_pages']},{r['cached_pages']},{r['cow_copies']}")

    off, on = rows["prefix_off"], rows["prefix_on"]
    saved = 1 - on["prefill_tokens"] / max(off["prefill_tokens"], 1)
    print(f"# prefill tokens {on['prefill_tokens']} vs "
          f"{off['prefill_tokens']} ({saved:.0%} saved), peak live pages "
          f"{on['peak_live_pages']} vs {off['peak_live_pages']}")
    assert outs["prefix_on"] == outs["prefix_off"], \
        "prefix caching changed greedy outputs"
    assert saved >= 0.40, f"prefill savings {saved:.0%} < 40%"
    assert on["peak_live_pages"] < off["peak_live_pages"], \
        "prefix caching must shrink the peak working set"
    rows["savings"] = {"prefill_tokens_saved_frac": saved,
                       "identical_greedy_outputs": True}
    if out_json:
        Path(out_json).write_text(json.dumps(rows, indent=2))
        print(f"# wrote {out_json}")
    return rows


# ===========================================================================
# fleet routing (prefix-aware affinity vs round-robin, 1/2/4 replicas)
# ===========================================================================

def run_fleet_mode(model, params, scfg, fcfg, warm, follow, max_new):
    """Serve the warm-then-followers shared-prefix trace through one
    router configuration.  Warmups drain first so every shared prefix is
    published on SOME replica before the followers are scored against the
    fleet; the followers then run concurrently."""
    router = FleetRouter(model, params, scfg, fcfg)
    out = {}
    t0 = time.time()
    for wave in (warm, follow):
        for p in wave:
            router.submit(p, max_new_tokens=max_new)
        for r in router.run_until_done(max_ticks=100_000):
            out[r.fleet_uid] = r.out_tokens
    dt = time.time() - t0
    assert len(out) == len(warm) + len(follow)
    router.check_invariants()
    st = router.fleet_stats()
    toks = sum(len(t) for t in out.values())
    row = {"n_replicas": st["n_replicas"], "policy": st["policy"],
           "requests": st["requests"], "tokens": toks, "seconds": dt,
           "tok_per_s": toks / max(dt, 1e-9),
           "prefill_tokens": st["prefill_tokens"],
           "prefix_hit_tokens": st["prefix_hit_tokens"],
           "hit_rate": st["prefix_hit_tokens"]
           / max(st["prompt_tokens"], 1),
           "ticks": st["ticks"], "dispatch": st["dispatch"],
           "spills": st["spills"], "affinity_hits": st["affinity_hits"],
           "affinity_hit_tokens": st["affinity_hit_tokens"]}
    return out, row, router


def run_fleet_trace(args, out_json):
    """Replica-count sweep of the fleet router on the shared-prefix trace:
    affinity at every count in --replicas, round-robin at the same counts
    as the control.  The affinity policy must (a) reproduce the 1-replica
    outputs bit-identically at every fleet size (shared jitted steps) and
    (b) strictly beat round-robin on prefill tokens computed at every
    n > 1 - a follower routed off its cached prefix recomputes the whole
    shared prefix, and that recompute is exactly what prefix-aware
    dispatch exists to avoid."""
    cfg = get_smoke_config(args.arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    warm, follow = make_prefix_trace(rng, cfg.vocab_size, args.groups,
                                     args.followers, args.shared_len,
                                     args.tail_len)
    per_req = pages_needed(args.shared_len + args.tail_len + args.max_new,
                           args.page_size)
    num_pages = (args.groups * pages_needed(args.shared_len, args.page_size)
                 + args.max_batch * per_req + 1)
    scfg = ServeConfig(max_batch=args.max_batch, max_seq=args.max_seq,
                       max_new_tokens=args.max_new, paged=True,
                       page_size=args.page_size, num_pages=num_pages,
                       prefix_cache=True,
                       telemetry=bool(args.emit_trace))
    sweep = [("affinity", n) for n in args.replicas]
    sweep += [("round_robin", n) for n in args.replicas if n > 1]

    print(f"# arch={cfg.name} groups={args.groups} "
          f"followers={args.followers} shared={args.shared_len} "
          f"tail={args.tail_len} max_new={args.max_new} "
          f"pool={num_pages}/replica replicas={args.replicas}")
    print("mode,replicas,requests,tokens,seconds,tok_per_s,"
          "prefill_tokens,hit_rate,affinity_hit_tokens,spills,dispatch")
    rows, outs = {}, {}
    router = None
    for policy, n in sweep:
        key = f"{policy}_n{n}"
        outs[key], rows[key], router = run_fleet_mode(
            model, params, scfg,
            FleetConfig(n_replicas=n, policy=policy),
            warm, follow, args.max_new)
        r = rows[key]
        print(f"{policy},{n},{r['requests']},{r['tokens']},"
              f"{r['seconds']:.2f},{r['tok_per_s']:.1f},"
              f"{r['prefill_tokens']},{r['hit_rate']:.2f},"
              f"{r['affinity_hit_tokens']},{r['spills']},"
              f"\"{r['dispatch']}\"")
    if args.emit_trace and router is not None:
        router.export_trace(args.emit_trace, clock="work")
        print(f"# wrote {args.emit_trace} (merged fleet trace, one track "
              f"group per replica; open in Perfetto)")

    base_key = f"affinity_n{args.replicas[0]}"
    for key, out in outs.items():
        assert out == outs[base_key], \
            f"{key} changed greedy outputs vs {base_key}"
    curve = {n: rows[f"affinity_n{n}"]["prefill_tokens"]
             for n in args.replicas}
    print(f"# affinity prefill-token curve over replicas: {curve}")
    savings = {}
    for n in args.replicas:
        if n <= 1 or f"round_robin_n{n}" not in rows:
            continue
        aff = rows[f"affinity_n{n}"]
        rr = rows[f"round_robin_n{n}"]
        saved = 1 - aff["prefill_tokens"] / max(rr["prefill_tokens"], 1)
        print(f"# n={n}: affinity prefill {aff['prefill_tokens']} vs "
              f"round-robin {rr['prefill_tokens']} ({saved:.0%} saved)")
        assert aff["prefill_tokens"] < rr["prefill_tokens"], \
            f"affinity routing saved no prefill over round-robin at n={n}"
        assert aff["affinity_hit_tokens"] > 0, \
            f"affinity never matched a cached prefix at n={n}"
        savings[f"n{n}"] = {"prefill_tokens_saved_frac": saved,
                            "affinity_hit_tokens":
                            aff["affinity_hit_tokens"]}
    rows["savings_fleet"] = dict(savings,
                                 prefill_curve={str(n): curve[n]
                                                for n in args.replicas},
                                 identical_greedy_outputs=True)
    if out_json:
        Path(out_json).write_text(json.dumps(rows, indent=2))
        print(f"# wrote {out_json}")
    return rows


# ===========================================================================
# self-speculative decoding (draft/verify vs plain decode)
# ===========================================================================

def run_spec_trace(args, out_json):
    """Shared-prefix, LONG-GENERATION trace through the paged chunked
    batched engine with ServeConfig.speculative off vs on.  Long greedy
    generations on the smoke models settle into repeating patterns - the
    traffic shape self-drafting (prompt-lookup over the request's own
    history, serve/drafting.py) is built for, standing in for the
    copy/paraphrase structure of real retrieval and code traffic.

    Asserted, not eyeballed: bit-identical greedy outputs spec-on vs
    spec-off, equal work-clock totals (the work clock counts ACCEPTED
    tokens only), nonzero acceptance, and the headline speedup -
    generated tokens per decode-path launch > 1.5x the baseline's, with
    tokens per KV page read (the memory-traffic side of the same win)
    reported alongside."""
    cfg = get_smoke_config(args.arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # calibrated trace: 48-token shared prefix, short tails, LONG greedy
    # generations (the drafter's acceptance comes from the repeating
    # patterns long generations settle into - short runs never get there)
    shared_len, tails = 48, (8, 16, 24, 4)
    shared = rng.integers(1, cfg.vocab_size, size=shared_len).tolist()
    prompts = [shared + rng.integers(1, cfg.vocab_size, size=t).tolist()
               for t in tails]
    max_new = args.spec_max_new
    base = dict(max_batch=len(tails), max_seq=2048, max_new_tokens=max_new,
                paged=True, page_size=16, chunked=True, prefill_chunk=32,
                tick_token_budget=128, batched=True, prefix_cache=True,
                spec_k=args.spec_k)

    print(f"# arch={cfg.name} shared={shared_len} tails={tails} "
          f"max_new={max_new} spec_k={args.spec_k}")
    print("mode,requests,tokens,seconds,tok_per_s,ticks,launches,"
          "tokens_per_launch,tokens_per_kv_page,drafted,accepted,"
          "rejected,accept_rate,chain_accept_mean")
    rows, outs = {}, {}
    for mode, spec in (("spec_off", False), ("spec_on", True)):
        eng = make_engine(model, params,
                          ServeConfig(speculative=spec, **base))
        t0 = time.time()
        for p in prompts:
            eng.submit(p, max_new_tokens=max_new)
        done = eng.run_until_done(max_ticks=100_000)
        dt = time.time() - t0
        assert len(done) == len(prompts)
        outs[mode] = {r.uid: r.out_tokens for r in done}
        st = eng.stats()
        rows[mode] = {"requests": len(done),
                      "tokens": st["gen_tokens"], "seconds": dt,
                      "tok_per_s": st["gen_tokens"] / max(dt, 1e-9),
                      "work_tokens": st["work_tokens"]}
        rows[mode].update({k: st[k] for k in (
            "ticks", "jit_calls", "decode_launches", "kv_pages_read",
            "tokens_per_launch", "tokens_per_kv_page", "spec_drafted",
            "spec_accepted", "spec_rejected", "spec_acceptance_rate",
            "spec_chain_accept_mean", "host_syncs", "compile_count")})
        r = rows[mode]
        print(f"{mode},{r['requests']},{r['tokens']},{r['seconds']:.2f},"
              f"{r['tok_per_s']:.1f},{r['ticks']},{r['decode_launches']},"
              f"{r['tokens_per_launch']:.2f},{r['tokens_per_kv_page']:.4f},"
              f"{r['spec_drafted']},{r['spec_accepted']},"
              f"{r['spec_rejected']},{r['spec_acceptance_rate']:.2f},"
              f"{r['spec_chain_accept_mean']:.2f}")

    off, on = rows["spec_off"], rows["spec_on"]
    launch_ratio = on["tokens_per_launch"] / max(off["tokens_per_launch"],
                                                 1e-9)
    page_ratio = on["tokens_per_kv_page"] / max(off["tokens_per_kv_page"],
                                                1e-9)
    print(f"# tokens/launch {on['tokens_per_launch']:.2f} vs "
          f"{off['tokens_per_launch']:.2f} ({launch_ratio:.2f}x), "
          f"tokens/KV-page {on['tokens_per_kv_page']:.4f} vs "
          f"{off['tokens_per_kv_page']:.4f} ({page_ratio:.2f}x), "
          f"acceptance {on['spec_acceptance_rate']:.2f} "
          f"(drafted {on['spec_drafted']} accepted {on['spec_accepted']} "
          f"rejected {on['spec_rejected']}, per-chain mean "
          f"{on['spec_chain_accept_mean']:.2f})")
    assert outs["spec_on"] == outs["spec_off"], \
        "speculative decoding changed greedy outputs"
    assert on["work_tokens"] == off["work_tokens"], \
        "the work clock must count accepted tokens only"
    assert on["spec_accepted"] > 0, "no draft token was ever accepted"
    assert launch_ratio > 1.5, \
        f"tokens-per-launch speedup {launch_ratio:.2f}x <= 1.5x"
    rows["savings_speculative"] = {
        "tokens_per_launch_ratio": launch_ratio,
        "tokens_per_kv_page_ratio": page_ratio,
        "acceptance_rate": on["spec_acceptance_rate"],
        "identical_greedy_outputs": True,
    }
    if out_json:
        Path(out_json).write_text(json.dumps(rows, indent=2))
        print(f"# wrote {out_json}")
    return rows


# ===========================================================================
# preemption + decode-priority trace (budget shaping and load shedding)
# ===========================================================================

def run_preempt_replay(model, params, scfg, arrivals):
    """Serve a timed-arrival (tick, prompt, max_new, priority) trace."""
    eng = make_engine(model, params, scfg)
    pending = list(arrivals)
    tick, done = 0, []
    t0 = time.time()
    while pending or eng.queue or any(s is not None for s in eng.slots):
        while pending and pending[0][0] <= tick:
            _, prompt, max_new, prio = pending.pop(0)
            eng.submit(prompt, max_new_tokens=max_new, priority=prio)
        done.extend(eng.tick())
        tick += 1
        assert tick < 500_000, "trace did not drain"
    dt = time.time() - t0
    return done, eng, dt


def _decode_tbt_p95(done, uids):
    tbt = [d for r in done if r.uid in uids for d in r.tbt_work()]
    return float(np.percentile(tbt, 95)) if tbt else 0.0


def run_preempt_trace(args, out_json):
    """Two-part trace for the preemption/shaping acceptance criteria.

    Part 1 - decode-priority budget shaping: short interactive requests
    decode while a burst of long prompts floods the prefill queue; with
    `decode_priority` ON the prefill share of every tick is capped, so
    the in-flight decodes' p95 work-clock TBT must be STRICTLY lower
    than with shaping off (asserted), at identical request completion.

    Part 2 - preemption: low-priority background requests fill a capacity
    cap (ServeConfig.usable_pages - same pool shape, fewer grantable
    pages); a high-priority burst then preempts victims, which park
    QUEUED->RESUMING and resume through the chunk path.  Greedy outputs
    must be bit-identical to the same trace served WITHOUT the capacity
    cap (the uninterrupted oracle), and preemptions/resumes/
    pages_reclaimed are reported (asserted > 0)."""
    cfg = get_smoke_config(args.arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    short_len, long_len = sorted(args.lens)[0], max(args.lens)
    n_short, n_long = 3, 4
    shorts = [(0, rng.integers(1, cfg.vocab_size, size=short_len).tolist(),
               args.max_new * 2, 0) for _ in range(n_short)]
    burst = [(4, rng.integers(1, cfg.vocab_size, size=long_len).tolist(),
              args.max_new, 0) for _ in range(n_long)]
    arrivals = sorted(shorts + burst)
    max_batch = n_short + n_long
    per_req = pages_needed(long_len + 2 * args.max_new, args.page_size)
    chunk = args.prefill_chunk
    budget = max_batch + 4 * chunk         # room for several chunks/tick
    base = dict(max_batch=max_batch, max_seq=args.max_seq,
                max_new_tokens=args.max_new, paged=True,
                page_size=args.page_size,
                num_pages=max_batch * per_req + 1, chunked=True,
                prefill_chunk=chunk, tick_token_budget=budget)
    short_uids = set(range(1, n_short + 1))

    print(f"# arch={cfg.name} shorts={n_short}x{short_len} "
          f"burst={n_long}x{long_len} chunk={chunk} budget={budget} "
          f"max_prefill_fraction=0.25")
    print("mode,requests,seconds,decode_tbt_work_p95,max_tick_tokens,"
          "preemptions,resumes,pages_reclaimed")
    rows = {}
    for mode, extra in (("shaping_off", {}),
                        ("shaping_on", dict(decode_priority=True,
                                            max_prefill_fraction=0.25))):
        done, eng, dt = run_preempt_replay(
            model, params, ServeConfig(**base, **extra), arrivals)
        st = eng.stats()
        rows[mode] = {"requests": len(done), "seconds": dt,
                      "decode_tbt_work_p95": _decode_tbt_p95(done,
                                                             short_uids),
                      "max_tick_tokens": st["max_tick_tokens"],
                      "preemptions": st["preemptions"],
                      "resumes": st["resumes"],
                      "pages_reclaimed": st["pages_reclaimed"]}
        r = rows[mode]
        print(f"{mode},{r['requests']},{r['seconds']:.2f},"
              f"{r['decode_tbt_work_p95']:.0f},{r['max_tick_tokens']},"
              f"{r['preemptions']},{r['resumes']},{r['pages_reclaimed']}")

    off, on = rows["shaping_off"], rows["shaping_on"]
    print(f"# decode p95 TBT (work-clock): {on['decode_tbt_work_p95']:.0f} "
          f"shaped vs {off['decode_tbt_work_p95']:.0f} unshaped")
    assert on["decode_tbt_work_p95"] < off["decode_tbt_work_p95"], \
        "decode-priority shaping must lower decode p95 work-clock TBT " \
        "under a prefill burst"

    # ---- part 2: preemption against a capacity cap --------------------
    lo = [(0, rng.integers(1, cfg.vocab_size, size=long_len).tolist(),
           args.max_new, 0) for _ in range(2)]
    hi = [(6, rng.integers(1, cfg.vocab_size, size=short_len * 2).tolist(),
           args.max_new, 5)]
    trace = sorted(lo + hi)
    pre_base = dict(base, max_batch=3, preemption=True,
                    max_chunks_per_tick=1,
                    tick_token_budget=3 + chunk)
    cap = 2 * per_req + 2                  # fits the background, not the burst
    done_o, eng_o, _ = run_preempt_replay(model, params,
                                          ServeConfig(**pre_base), trace)
    done_p, eng_p, _ = run_preempt_replay(
        model, params, ServeConfig(**pre_base, usable_pages=cap), trace)
    st = eng_p.stats()
    outs_o = {r.uid: r.out_tokens for r in done_o}
    outs_p = {r.uid: r.out_tokens for r in done_p}
    print(f"# preemption leg: preemptions={st['preemptions']} "
          f"resumes={st['resumes']} pages_reclaimed={st['pages_reclaimed']} "
          f"(capacity cap {cap} of {pre_base['num_pages']} pages)")
    assert st["preemptions"] >= 1 and st["resumes"] >= 1, \
        "capacity cap never forced a preemption - trace too easy"
    assert outs_p == outs_o, \
        "preempt/resume changed greedy outputs vs the uninterrupted run"
    rows["preemption"] = {"preemptions": st["preemptions"],
                          "resumes": st["resumes"],
                          "pages_reclaimed": st["pages_reclaimed"],
                          "identical_greedy_outputs": True,
                          "usable_pages": cap,
                          "tbt_shaping_ratio":
                          on["decode_tbt_work_p95"]
                          / max(off["decode_tbt_work_p95"], 1e-9)}
    if out_json:
        Path(out_json).write_text(json.dumps(rows, indent=2))
        print(f"# wrote {out_json}")
    return rows


def run_default_trace(args, out_json):
    """Mixed-length trace through the dense vs the paged engine."""
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=args.lens[i % len(args.lens)]).tolist()
               for i in range(args.requests)]

    num_pages = args.num_pages
    if num_pages == 0:
        # size the pool to the trace: the longest request fully resident on
        # every slot would be dense-equivalent; halving it is what paging
        # buys on a mixed trace (short requests hold few pages)
        per_req = pages_needed(max(args.lens) + args.max_new, args.page_size)
        num_pages = max(args.max_batch * per_req // 2,
                        2 * per_req) + 1

    dense_cfg = ServeConfig(max_batch=args.max_batch, max_seq=args.max_seq,
                            max_new_tokens=args.max_new)
    paged_cfg = ServeConfig(max_batch=args.max_batch, max_seq=args.max_seq,
                            max_new_tokens=args.max_new, paged=True,
                            page_size=args.page_size, num_pages=num_pages)

    print(f"# arch={cfg.name} max_batch={args.max_batch} "
          f"max_seq={args.max_seq} lens={args.lens} "
          f"requests={args.requests} max_new={args.max_new}")
    print(f"# capacity math: dense {dense_kv_bytes(cfg, dense_cfg)} B, "
          f"paged pool {paged_kv_bytes(cfg, paged_cfg, num_pages)} B "
          f"({num_pages} pages x {args.page_size} tok)")
    print("mode,requests,tokens,seconds,tok_per_s,kv_bytes,"
          "peak_pages,pool_pages")
    rows = {}
    for mode, scfg in (("dense", dense_cfg), ("paged", paged_cfg)):
        r = run_mode(model, params, scfg, prompts, args.max_new)
        rows[mode] = r
        print(f"{mode},{r['requests']},{r['tokens']},{r['seconds']:.2f},"
              f"{r['tok_per_s']:.1f},{r['kv_bytes']},{r['peak_pages']},"
              f"{r['pool_pages']}")
    saved = 1 - rows["paged"]["kv_bytes"] / rows["dense"]["kv_bytes"]
    print(f"# paged peak KV bytes {rows['paged']['kv_bytes']} "
          f"vs dense {rows['dense']['kv_bytes']} "
          f"({saved:.0%} smaller)")
    assert rows["paged"]["kv_bytes"] < rows["dense"]["kv_bytes"], \
        "paged pool must be strictly smaller than the dense cache"
    if out_json:
        Path(out_json).write_text(json.dumps(rows, indent=2))
        print(f"# wrote {out_json}")
    return rows


# ===========================================================================
# chaos trace (kill 1 of N replicas mid-trace; latency cost of recovery)
# ===========================================================================

def make_chaos_trace(rng, vocab, lens, requests, spread):
    """Timed-arrival mixed trace: `requests` prompts cycling `lens`,
    arrival ticks spread over [0, spread]."""
    arrivals = []
    for i in range(requests):
        n = lens[i % len(lens)]
        arrivals.append((int(rng.integers(0, spread + 1)),
                         rng.integers(1, vocab, size=n).tolist()))
    arrivals.sort(key=lambda a: a[0])
    return arrivals


def run_chaos_mode(model, params, scfg, fcfg, arrivals, max_new,
                   kill_tick=None, victim=None):
    """Serve a timed-arrival trace through the fleet, optionally killing
    `victim` at fleet tick `kill_tick`.  TTFT is measured in FLEET TICKS
    (first-token tick minus submit tick) - the one clock that spans a
    redispatch, since per-engine work clocks restart on the survivor.
    Asserts router invariants every tick and that every request
    completes."""
    router = FleetRouter(model, params, scfg, fcfg)
    pending = list(arrivals)
    submit_tick, first_tok = {}, {}
    done = []
    t0 = time.time()
    tick = 0
    while pending or not router.idle:
        if kill_tick is not None and tick == kill_tick:
            router.fail(victim)
        while pending and pending[0][0] <= tick:
            _, prompt = pending.pop(0)
            uid = router.submit(prompt, max_new_tokens=max_new)
            submit_tick[uid] = tick
        done.extend(router.tick())
        router.check_invariants()
        for uid, req in router.requests.items():
            if uid not in first_tok and req.out_tokens:
                first_tok[uid] = tick
        tick += 1
        assert tick < 500_000, "chaos trace did not drain"
    dt = time.time() - t0
    statuses = router.statuses()
    assert set(statuses.values()) == {"done"}, \
        f"chaos trace left non-done requests: {statuses}"
    assert len(done) == len(arrivals), (len(done), len(arrivals))
    ttft = sorted(first_tok[u] - submit_tick[u] for u in submit_tick)
    st = router.fleet_stats()
    outs = {u: list(r.out_tokens) for u, r in router.requests.items()}
    row = {"requests": len(done), "ticks": tick, "seconds": dt,
           "ttft_ticks_p50": float(np.percentile(ttft, 50)),
           "ttft_ticks_p95": float(np.percentile(ttft, 95)),
           "redispatches": st["redispatches"],
           "failures": st["failures"],
           "replica_states": st["replica_states"],
           "dispatch": st["dispatch"]}
    return outs, row, router


def run_chaos_trace(args, out_json):
    """Kill 1 of N replicas mid-trace and price the recovery: the same
    timed-arrival trace runs fault-free and with a kill at --kill-tick,
    and the bench asserts (a) every request still completes, (b) greedy
    outputs are bit-identical to the fault-free run - replica death is
    invisible in the tokens - and (c) the p95 first-token latency (in
    fleet ticks, the clock that spans a redispatch) inflates by at most
    --chaos-ttft-bound x.  The latency cost of fault tolerance is the
    headline number; the conformance is the contract."""
    cfg = get_smoke_config(args.arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = args.chaos_replicas
    rng = np.random.default_rng(0)
    arrivals = make_chaos_trace(rng, cfg.vocab_size, args.lens,
                                args.requests * 2, spread=4)
    scfg = ServeConfig(max_batch=args.max_batch, max_seq=args.max_seq,
                       max_new_tokens=args.max_new, paged=True,
                       page_size=args.page_size, chunked=True,
                       batched=True, prefix_cache=True,
                       prefill_chunk=args.prefill_chunk,
                       tick_token_budget=args.tick_budget
                       or args.max_batch + 2 * args.prefill_chunk)
    fcfg = FleetConfig(n_replicas=n)
    print(f"# arch={cfg.name} replicas={n} requests={len(arrivals)} "
          f"lens={args.lens} max_new={args.max_new} "
          f"kill_tick={args.kill_tick} victim={args.victim}")
    print("mode,requests,ticks,ttft_ticks_p50,ttft_ticks_p95,"
          "redispatches,dispatch")
    rows = {}
    base_out, rows["fault_free"], _ = run_chaos_mode(
        model, params, scfg, fcfg, arrivals, args.max_new)
    chaos_out, rows["kill_one"], router = run_chaos_mode(
        model, params, scfg, fcfg, arrivals, args.max_new,
        kill_tick=args.kill_tick, victim=args.victim)
    for key in ("fault_free", "kill_one"):
        r = rows[key]
        print(f"{key},{r['requests']},{r['ticks']},"
              f"{r['ttft_ticks_p50']:.1f},{r['ttft_ticks_p95']:.1f},"
              f"{r['redispatches']},\"{r['dispatch']}\"")
    assert chaos_out == base_out, \
        "kill-one run changed greedy outputs vs the fault-free run"
    assert rows["kill_one"]["failures"] == 1
    assert rows["kill_one"]["redispatches"] > 0, \
        "the kill moved no requests - pick an earlier --kill-tick"
    p95_base = max(rows["fault_free"]["ttft_ticks_p95"], 1.0)
    p95_chaos = rows["kill_one"]["ttft_ticks_p95"]
    inflation = p95_chaos / p95_base
    bound = args.chaos_ttft_bound
    print(f"# p95 first-token latency: {p95_base:.1f} -> {p95_chaos:.1f} "
          f"ticks ({inflation:.2f}x, bound {bound:.1f}x); "
          f"{rows['kill_one']['redispatches']} requests redispatched")
    assert inflation <= bound, \
        f"p95 TTFT inflated {inflation:.2f}x > bound {bound:.1f}x"
    rows["chaos_summary"] = {
        "identical_greedy_outputs": True,
        "all_requests_completed": True,
        "ttft_ticks_p95_inflation": inflation,
        "ttft_ticks_p95_bound": bound,
        "redispatches": rows["kill_one"]["redispatches"],
        "kill_tick": args.kill_tick, "victim": args.victim,
        "n_replicas": n}
    if out_json:
        Path(out_json).write_text(json.dumps(rows, indent=2))
        print(f"# wrote {out_json}")
    return rows


# ===========================================================================
# tensor-parallel trace (tp=1 vs tp=N: per-device data movement)
# ===========================================================================

def run_tp_mode(model, params, scfg, prompts, max_new):
    """Serve the trace and report the TP accounting alongside run_mode's
    throughput row: per-device KV bytes read, block-table replication
    bytes, and the movement breakdown's per_device section."""
    eng = make_engine(model, params, scfg)
    t0 = time.time()
    for p in prompts:
        eng.submit(p, max_new_tokens=max_new)
    done = eng.run_until_done(max_ticks=100_000)
    dt = time.time() - t0
    eng.check_invariants()
    assert len(done) == len(prompts), (len(done), len(prompts))
    outs = {r.uid: list(r.out_tokens) for r in done}
    toks = sum(len(t) for t in outs.values())
    tp = eng.tp_stats()
    row = {"tp_degree": tp["tp_degree"], "requests": len(done),
           "tokens": toks, "seconds": dt,
           "tok_per_s": toks / max(dt, 1e-9),
           "work_tokens": eng.stats()["work_tokens"],
           "kv_pages_read": tp["kv_pages_read"],
           "page_bytes": tp["page_bytes"],
           "shard_page_bytes": tp["shard_page_bytes"],
           "shard_kv_bytes_read": tp["shard_kv_bytes_read"],
           "table_bytes_replicated": tp["table_bytes_replicated"]}
    mv = eng.movement_stats()
    if "per_device" in mv:
        row["per_device_movement"] = mv["per_device"]
    return outs, row


def run_tp_trace(args, out_json):
    """The same mixed trace through the paged chunked batched engine at
    tp_degree=1 and tp_degree=--tp-degree (head-sharded KV pool + kernels,
    docs/tensor_parallel.md).  Asserted, never eyeballed: bit-identical
    greedy outputs (the all-gather restores the tp=1 summation order),
    equal work clocks and page reads, and the headline inequality - each
    device at tp=N streams at most 1/N of the single-device KV read bytes
    plus the block-table replication overhead.  Requires >= --tp-degree
    devices (on CPU: XLA_FLAGS=--xla_force_host_platform_device_count=N)."""
    cfg = get_smoke_config(args.arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=args.lens[i % len(args.lens)]).tolist()
               for i in range(args.requests)]

    def scfg(tp):
        return ServeConfig(max_batch=args.max_batch, max_seq=args.max_seq,
                           max_new_tokens=args.max_new, paged=True,
                           page_size=args.page_size, chunked=True,
                           batched=True, prefill_chunk=args.prefill_chunk,
                           tick_token_budget=args.tick_budget
                           or args.max_batch + 2 * args.prefill_chunk,
                           tp_degree=tp)

    n = args.tp_degree
    print(f"# arch={cfg.name} tp_degree=1 vs {n} requests={len(prompts)} "
          f"lens={args.lens} max_new={args.max_new} "
          f"devices={jax.device_count()}")
    print("mode,requests,tokens,tok_per_s,kv_pages_read,"
          "shard_kv_bytes_read,table_bytes_replicated")
    rows = {}
    base_out, rows["tp1"] = run_tp_mode(model, params, scfg(1), prompts,
                                        args.max_new)
    tp_out, rows[f"tp{n}"] = run_tp_mode(model, params, scfg(n), prompts,
                                         args.max_new)
    for key in ("tp1", f"tp{n}"):
        r = rows[key]
        print(f"{key},{r['requests']},{r['tokens']},{r['tok_per_s']:.1f},"
              f"{r['kv_pages_read']},{r['shard_kv_bytes_read']},"
              f"{r['table_bytes_replicated']}")
    assert tp_out == base_out, \
        f"tp={n} changed greedy outputs vs single-device"
    assert rows["tp1"]["work_tokens"] == rows[f"tp{n}"]["work_tokens"]
    assert rows["tp1"]["kv_pages_read"] == rows[f"tp{n}"]["kv_pages_read"], \
        "sharding must not change which pages decode reads"
    # the headline: per-device KV reads divide by the degree, and the
    # price is only the replicated scalar-prefetch state (block table)
    per_dev = rows[f"tp{n}"]["shard_kv_bytes_read"]
    single = rows["tp1"]["shard_kv_bytes_read"]
    overhead = rows[f"tp{n}"]["table_bytes_replicated"]
    assert per_dev <= single / n + overhead, \
        (f"per-device KV bytes {per_dev} > single-device/{n} "
         f"({single / n:.0f}) + table replication ({overhead})")
    ratio = per_dev / max(single, 1)
    print(f"# per-device KV read bytes: {single} -> {per_dev} "
          f"({ratio:.3f}x, ideal {1 / n:.3f}x); table replication "
          f"overhead {overhead} B; outputs bit-identical")
    rows["tp_summary"] = {
        "identical_greedy_outputs": True,
        "tp_degree": n,
        "per_device_kv_read_ratio": ratio,
        "ideal_ratio": 1.0 / n,
        "table_replication_bytes": overhead}
    if out_json:
        Path(out_json).write_text(json.dumps(rows, indent=2))
        print(f"# wrote {out_json}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=4096)
    ap.add_argument("--lens", type=int, nargs="+", default=[128, 1024, 3968],
                    help="mixed prompt lengths (cycled)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--page-size", type=int, default=64)
    ap.add_argument("--num-pages", type=int, default=0,
                    help="paged pool size (0 = sized to the trace: "
                         "max_batch * pages(longest request) / 2 + slack)")
    ap.add_argument("--prefix-trace", action="store_true",
                    help="shared-prefix trace: paged serving with prefix "
                         "caching off vs on")
    ap.add_argument("--chunked", action="store_true",
                    help="mixed trace: monolithic admission prefill vs the "
                         "token-budget chunked-prefill scheduler, with "
                         "p50/p95 TTFT and time-between-tokens")
    ap.add_argument("--speculative", action="store_true",
                    help="shared-prefix long-generation trace with self-"
                         "speculative decoding off vs on: bit-identical "
                         "greedy outputs, equal work clocks, and tokens-"
                         "per-launch speedup > 1.5x, all asserted")
    ap.add_argument("--spec-k", type=int, default=8,
                    help="speculative trace: max drafted tokens per "
                         "request per tick")
    ap.add_argument("--spec-max-new", type=int, default=512,
                    help="speculative trace: generation length (long "
                         "enough for self-drafting to engage)")
    ap.add_argument("--fleet", action="store_true",
                    help="shared-prefix trace through the fleet router: "
                         "replica-count sweep (--replicas) of prefix-aware "
                         "affinity dispatch vs round-robin; bit-identical "
                         "outputs across every size and strictly fewer "
                         "prefill tokens than round-robin, both asserted")
    ap.add_argument("--replicas", type=int, nargs="+", default=[1, 2, 4],
                    help="fleet trace: replica counts to sweep")
    ap.add_argument("--chaos", action="store_true",
                    help="fault-tolerance trace: the same timed-arrival "
                         "mixed trace through an N-replica fleet fault-"
                         "free and with 1 replica killed mid-trace; "
                         "asserts every request completes, outputs are "
                         "bit-identical to the fault-free run, and p95 "
                         "first-token latency inflates by at most "
                         "--chaos-ttft-bound x")
    ap.add_argument("--chaos-replicas", type=int, default=4,
                    help="chaos trace: fleet size (1 replica dies)")
    ap.add_argument("--kill-tick", type=int, default=4,
                    help="chaos trace: fleet tick at which the victim "
                         "replica is killed")
    ap.add_argument("--victim", type=int, default=1,
                    help="chaos trace: replica index to kill")
    ap.add_argument("--chaos-ttft-bound", type=float, default=3.0,
                    help="chaos trace: max allowed p95 first-token "
                         "latency inflation (kill-one / fault-free)")
    ap.add_argument("--tp", action="store_true",
                    help="tensor-parallel trace: the mixed trace at "
                         "tp_degree 1 vs --tp-degree (head-sharded KV "
                         "pool + kernels); asserts bit-identical greedy "
                         "outputs, equal work clocks, and per-device KV "
                         "read bytes <= single-device/N + block-table "
                         "replication overhead (needs >= N devices; on "
                         "CPU set XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N)")
    ap.add_argument("--tp-degree", type=int, default=2,
                    help="tp trace: tensor-parallel degree to compare "
                         "against single-device")
    ap.add_argument("--preempt-trace", action="store_true",
                    help="decode-priority shaping (decode p95 TBT with vs "
                         "without the prefill-share cap under a prefill "
                         "burst, asserted lower) + preemption under a "
                         "capacity cap (bit-identical outputs to the "
                         "uninterrupted run, preempt/resume counters)")
    ap.add_argument("--batched", action="store_true",
                    help="with --chunked: additionally run the sequential "
                         "per-chunk oracle and assert the one-launch tick "
                         "(exactly 2 jitted calls + 1 device->host "
                         "transfer per steady-state tick, identical greedy "
                         "outputs, fewer total launches)")
    ap.add_argument("--prefill-chunk", type=int, default=512,
                    help="chunked trace: tokens per prefill chunk (page "
                         "multiple)")
    ap.add_argument("--tick-budget", type=int, default=0,
                    help="chunked trace: tokens of work per tick "
                         "(0 = max_batch + 2 * prefill_chunk)")
    ap.add_argument("--groups", type=int, default=2,
                    help="prefix trace: distinct shared prefixes")
    ap.add_argument("--followers", type=int, default=3,
                    help="prefix trace: follower requests per prefix")
    ap.add_argument("--shared-len", type=int, default=256)
    ap.add_argument("--tail-len", type=int, default=64)
    ap.add_argument("--json", default="",
                    help="also write the metrics dict to this path")
    ap.add_argument("--emit-trace", default="",
                    help="write a Chrome trace-event JSON (open in "
                         "Perfetto) of the mode's final engine run; "
                         "enables ServeConfig.telemetry for the run")
    ap.add_argument("--emit-metrics", default="",
                    help="write the final engine's metrics snapshot + "
                         "per-launch-kind data-movement breakdown "
                         "(HBM/SRAM bytes, energy) to this path")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (max_seq=512, lens 64/128/448)")
    args = ap.parse_args(argv)
    if args.quick:
        args.max_seq, args.lens = 512, [64, 128, 448]
        args.max_new, args.page_size = 16, 16
        args.shared_len, args.tail_len = 128, 32
        args.prefill_chunk = 64

    _EMIT["trace"], _EMIT["metrics"] = args.emit_trace, args.emit_metrics
    _EMIT["eng"] = None

    if args.prefix_trace:
        rows = run_prefix_trace(args, args.json)
    elif args.chunked:
        rows = run_chunked_trace(args, args.json)
    elif args.fleet:
        rows = run_fleet_trace(args, args.json)
    elif args.chaos:
        rows = run_chaos_trace(args, args.json)
    elif args.tp:
        rows = run_tp_trace(args, args.json)
    elif args.speculative:
        rows = run_spec_trace(args, args.json)
    elif args.preempt_trace:
        rows = run_preempt_trace(args, args.json)
    else:
        rows = run_default_trace(args, args.json)
    emit_artifacts()
    return rows


if __name__ == "__main__":
    main()
