"""Fig 5: attention energy, all designs, N = 1K..64K, normalized to
2D-Unfused.  Paper: ours = 80.5%..93% reduction."""
import statistics as st

from repro.core import DESIGNS, normalized_energy, sweep
from repro.core.workloads import PAPER_SEQS, opt_6_7b, qwen_7b

from .common import emit, timed


def run():
    wls = [m(s).attn for m in (opt_6_7b, qwen_7b) for s in PAPER_SEQS]
    res, us = timed(sweep, list(DESIGNS), wls, reps=1)
    ne = normalized_energy(res)
    for design, cells in ne.items():
        for (wl, seq), v in sorted(cells.items()):
            emit(f"fig5/{design}/{wl}/N={seq}", us / len(res), f"{v:.4f}")
    ours = list(ne["3D-Flow"].values())
    emit("fig5/ours_reduction_pct_mean", 0.0,
         f"{100 * (1 - st.mean(ours)):.1f}")
    emit("fig5/ours_reduction_pct_range", 0.0,
         f"{100 * (1 - max(ours)):.1f}..{100 * (1 - min(ours)):.1f}"
         f" (paper: 80.5..93)")
    return ne


if __name__ == "__main__":
    run()
