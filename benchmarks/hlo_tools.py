"""HLO inspection helpers: largest per-device tensors, collective summary.

Used by the dry-run debugging loop and the S.Perf iteration log.
"""
from __future__ import annotations

import re
from collections import Counter

DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
               "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
               "f64": 8}

_SHAPE = re.compile(r"(\w+)\[([\d,]+)\]")


def shape_bytes(dt: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n * DTYPE_BYTES.get(dt, 0)


def top_tensors(hlo: str, min_bytes: int = 2 ** 27, top: int = 25):
    """(bytes, count, type, op, sample_op_name) rows for the largest tensors."""
    rows = Counter()
    names = {}
    for line in hlo.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        rhs = line.split("=", 1)[1]
        m = _SHAPE.search(rhs)
        if not m:
            continue
        dt, dims = m.groups()
        if dt not in DTYPE_BYTES:
            continue
        b = shape_bytes(dt, dims)
        if b < min_bytes:
            continue
        opm = re.search(r"[\}\]]\s+([\w-]+)\(", rhs)
        op = opm.group(1) if opm else "?"
        key = (dt, dims, op)
        rows[key] += 1
        if key not in names:
            mm = re.search(r'op_name="([^"]+)"', line)
            names[key] = mm.group(1)[:120] if mm else ""
    out = []
    for (dt, dims, op), cnt in rows.items():
        out.append((shape_bytes(dt, dims), cnt, f"{dt}[{dims}]", op,
                    names[(dt, dims, op)]))
    out.sort(key=lambda r: -r[0])
    return out[:top]


def print_top(hlo: str, **kw):
    for b, cnt, ty, op, name in top_tensors(hlo, **kw):
        print(f"{b/2**30:8.2f} GiB x{cnt:3d}  {ty:38s} {op:22s} {name}")
