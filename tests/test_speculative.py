"""Self-speculative decoding units: the drafter, config validation, and
engine-level drafting behavior (budget consumption, counters, rollback
bookkeeping).  Full differential conformance lives in
tests/test_conformance.py; this file tests the pieces in isolation."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.serve import ServeEngine, ngram_draft
from repro.serve.scheduler import Request, TokenBudgetScheduler

# ===========================================================================
# ngram_draft: suffix-shift prompt-lookup
# ===========================================================================


def test_draft_constant_run():
    """A constant tail is a period-1 cycle: the draft repeats it for the
    full max_draft, regardless of history length."""
    assert ngram_draft([1, 2, 5, 5, 5, 5], 4, 3) == [5, 5, 5, 5]
    assert ngram_draft([7, 7], 6, 3) == [7] * 6


def test_draft_period_cycle():
    """A period-p tail predicts cyclically - including past the end of
    recorded history (token[t] = token[t - p] wraps through the draft)."""
    h = [9, 1, 2, 3, 1, 2, 3, 1, 2]
    assert ngram_draft(h, 5, 3) == [3, 1, 2, 3, 1]


def test_draft_most_recent_match_wins():
    """Two occurrences of the trailing n-gram: the MOST RECENT one sets
    the period, so the freshest local pattern is continued."""
    #     [1, 2, X, ..., 1, 2, Y, ..., 1, 2] -> predicts Y (recent), not X
    h = [1, 2, 8, 0, 1, 2, 5, 0, 1, 2]
    assert ngram_draft(h, 1, 2)[0] == 5


def test_draft_longer_ngram_preferred():
    """When a longer suffix match exists it wins over a shorter one that
    would predict differently."""
    #  trailing 3-gram [4, 1, 2] occurs earlier followed by 9;
    #  the trailing 1-gram [2] also occurs at index 2 followed by 7
    h = [4, 1, 2, 7, 4, 1, 2, 9, 4, 1, 2]
    assert ngram_draft(h, 1, 3)[0] == 9


def test_draft_no_repetition_is_empty():
    assert ngram_draft([1, 2, 3, 4, 5, 6], 4, 3) == []


def test_draft_degenerate_inputs():
    assert ngram_draft([1, 1, 1], 0, 3) == []     # no room
    assert ngram_draft([5], 4, 3) == []           # too short to match
    assert ngram_draft([], 4, 3) == []


# ===========================================================================
# config validation + family gating
# ===========================================================================

_SPEC_KW = dict(max_batch=2, max_seq=128, page_size=16, paged=True,
                chunked=True, batched=True, prefill_chunk=16,
                tick_token_budget=32, max_new_tokens=8, speculative=True)


def test_speculative_requires_chunked_batched():
    with pytest.raises(ValueError, match="chunked"):
        ServeConfig(**{**_SPEC_KW, "chunked": False}).validate()
    with pytest.raises(ValueError, match="batched"):
        ServeConfig(**{**_SPEC_KW, "batched": False}).validate()
    with pytest.raises(ValueError, match="spec_k"):
        ServeConfig(**{**_SPEC_KW, "spec_k": 0}).validate()
    with pytest.raises(ValueError, match="spec_ngram"):
        ServeConfig(**{**_SPEC_KW, "spec_ngram": 0}).validate()


def test_speculative_rejects_non_attention_family():
    """Speculation verifies through the batched paged chunk kernel; an
    attention-free family has no such path and must fail loudly at
    engine construction, not at the first tick."""
    cfg = get_smoke_config("rwkv6-1.6b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="attention"):
        ServeEngine(m, params, ServeConfig(**_SPEC_KW))


# ===========================================================================
# scheduler drafting policy (host-side, no device work)
# ===========================================================================

def _req(uid, prompt, out, max_new=32):
    r = Request(uid=uid, prompt=list(prompt), max_new_tokens=max_new)
    for t in out:
        r.out_tokens.append(t)
    return r


def _sched(**kw):
    return TokenBudgetScheduler(ServeConfig(**{**_SPEC_KW, **kw}))


def test_plan_drafts_consumes_room():
    """Draft lengths are capped by the shared room: once the tick's
    leftover budget is spent, later slots draft nothing."""
    s = _sched(spec_k=6)
    reqs = [(i, _req(i, [3, 3, 3, 3], [3, 3])) for i in range(2)]
    tasks = s.plan_drafts(reqs, room=8)
    assert [len(t.draft) for t in tasks] == [6, 2]
    assert s.plan_drafts(reqs, room=0) == []


def test_plan_drafts_caps_at_remaining_new():
    """A request one token from its generation cap never drafts (the
    guaranteed token IS its last); nearly-done requests draft at most
    remaining_new - 1 so chain + bonus can't overrun the reservation."""
    s = _sched(spec_k=6)
    nearly = _req(0, [4, 4, 4, 4], [4, 4], max_new=4)   # 2 remaining
    done1 = _req(1, [4, 4, 4, 4], [4, 4, 4], max_new=4)  # 1 remaining
    tasks = s.plan_drafts([(0, nearly), (1, done1)], room=32)
    assert [(t.slot, len(t.draft)) for t in tasks] == [(0, 1)]


def test_plan_drafts_skips_non_repeating_history():
    s = _sched()
    tasks = s.plan_drafts([(0, _req(0, [1, 2, 3, 4], [5, 6]))], room=32)
    assert tasks == []


def test_pack_drafts_rows():
    """The packed verify batch: row = [pending, draft...] at the slot's
    current lens, true_len = offset + 1 + m, sentinel rows dead."""
    s = _sched(spec_k=6)
    req = _req(0, [9, 9, 9], [9, 9])
    (task,) = s.plan_drafts([(1, req)], room=32)
    lens = np.array([0, 5], np.int32)
    pack = s.pack_drafts([task], lens)
    assert pack.tokens[0, 0] == 9                 # pending = last emitted
    assert list(pack.tokens[0, 1:1 + 6]) == [9] * 6
    assert pack.offsets[0] == 5
    assert pack.true_lens[0] == 5 + 1 + 6
    assert pack.q_lens[0] == 7
    assert pack.draft_lens[0] == 6
    assert pack.row_slots[0] == 1
    # padding rows (bucketing) carry the max_batch sentinel slot
    assert all(r == s.scfg.max_batch for r in pack.row_slots[1:])


# ===========================================================================
# engine: drafting engages and stays within budget on a live run
# ===========================================================================

@pytest.fixture(scope="module")
def model_f32():
    cfg = get_smoke_config("granite-3-2b").replace(dtype="float32")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def test_engine_spec_counters_and_budget(model_f32):
    """A live speculative run on a repetitive prompt: drafting engages,
    acceptance is recorded, every tick stays within the token budget,
    and the emitted stream matches the non-speculative engine's."""
    m, params = model_f32
    scfg = dict(max_batch=2, max_seq=256, page_size=16, paged=True,
                chunked=True, batched=True, prefill_chunk=16,
                tick_token_budget=32, max_new_tokens=48, spec_k=4)
    rng = np.random.default_rng(11)
    base = rng.integers(1, m.cfg.vocab_size, size=4).tolist()
    prompt = base * 6                              # repetitive by design

    def run(speculative):
        eng = ServeEngine(m, params,
                          ServeConfig(speculative=speculative, **scfg))
        eng.submit(prompt)
        eng.run_until_done()
        return eng

    eng_off, eng_on = run(False), run(True)
    s = eng_on.stats()
    assert s["spec_drafted"] > 0 and s["spec_accepted"] >= 0
    assert [r.out_tokens for r in eng_on.sched.finished] == \
        [r.out_tokens for r in eng_off.sched.finished]
    budget = eng_on.scfg.tick_token_budget
    for d, p in eng_on.sched.tick_log:
        assert d + p <= budget
    assert s["ticks"] <= eng_off.stats()["ticks"]
