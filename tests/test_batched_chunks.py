"""One-launch ticks: the batched ragged prefill-chunk kernel and the
host-overhead-free serve loop.

Covers: batched-vs-single-row kernel parity (ref + pallas interpret,
ragged rows, shuffled tables, dead rows, sliding windows), the
scheduler's pack step (power-of-two bucketing, sentinel slots), engine
parity batched-vs-sequential (mixed traffic, prefix cache on/off,
windowed gemma3 models, the K=1 degenerate case), a hypothesis property
over random chunk packings / bucket sizes, the one-launch dispatch
accounting (one batched prefill launch + one decode launch + one
device->host transfer per busy tick), and a recompile guard: a
steady-state tick triggers ZERO new XLA compilations (jax.log_compiles)."""
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ServeConfig
from repro.kernels import ops
from repro.models import build_model
from repro.serve import ServeEngine, RequestState, TokenBudgetScheduler
from repro.serve.scheduler import ChunkTask, Request, bucket_rows

# shared traffic-replay harness (tests/traffic.py)
from traffic import MIXED_LENS, mixed_prompts as _mixed_prompts, \
    serve_all as _serve


@pytest.fixture(scope="module")
def model_f32():
    # float32 keeps greedy argmax ties out of the parity comparisons
    cfg = get_smoke_config("granite-3-2b").replace(dtype="float32")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _base(**over):
    base = dict(max_batch=3, max_seq=256, max_new_tokens=6, paged=True,
                page_size=8, num_pages=3 * 29 + 1, chunked=True,
                prefill_chunk=16, tick_token_budget=32)
    base.update(over)
    return ServeConfig(**base)


# ===========================================================================
# kernel level: batched ragged rows == single-row launches, row by row
# ===========================================================================

@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("window", [0, 12])
def test_batched_kernel_matches_single_rows(impl, window, rng):
    """Each row of one batched launch must equal its own single-row
    launch - different offsets, ragged true lengths, shuffled per-row
    tables, and a dead padding row returning exactly zero."""
    S, Hq, Hkv, D, ps, n_pages, n_max = 8, 4, 2, 16, 4, 24, 8
    ks = jax.random.split(rng, 3)
    k_pages = jax.random.normal(ks[0], (n_pages, ps, Hkv, D))
    v_pages = jax.random.normal(ks[1], (n_pages, ps, Hkv, D))
    q = jax.random.normal(ks[2], (4, S, Hq, D))
    perm = np.random.default_rng(0).permutation(
        np.arange(1, n_pages)).astype(np.int32)
    tables = np.zeros((4, n_max), np.int32)
    tables[0, :6] = perm[:6]
    tables[1, :8] = perm[6:14]
    tables[2, :4] = perm[14:18]
    offs = np.array([4, 17, 0, 0], np.int32)
    # rows 0/2 full chunks, row 1 ragged (3 real tokens), row 3 DEAD
    tls = np.array([4 + S, 17 + 3, 0 + S, 0], np.int32)
    got = ops.batched_paged_prefill_attention(
        q, k_pages, v_pages, jnp.asarray(tables), jnp.asarray(offs),
        jnp.asarray(tls), window=window, impl=impl)
    for r in range(3):
        want = ops.paged_prefill_attention(
            q[r:r + 1], k_pages, v_pages, jnp.asarray(tables[r]),
            int(offs[r]), window=window, impl=impl)
        n_real = int(tls[r] - offs[r])
        err = float(jnp.abs(got[r, :n_real] - want[0, :n_real]).max())
        assert err <= 1e-5, (r, err)
    assert float(jnp.abs(got[3]).max()) == 0.0      # dead row: exact zero


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_same_sequence_two_chunks_one_launch(impl, rng):
    """Two chunks of the SAME sequence packed into one batch (ordered
    offsets) must together equal the rows of one monolithic causal
    attention - the property that lets the engine fold a whole tick's
    plan, including multi-chunk requests, into one launch."""
    S, Hq, Hkv, D, ps = 32, 4, 2, 16, 8
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (1, S, Hq, D))
    k = jax.random.normal(ks[1], (1, S, Hkv, D))
    v = jax.random.normal(ks[2], (1, S, Hkv, D))
    want = ops.flash_attention(q, k, v, causal=True, impl="ref")
    n_pages = S // ps
    k_pages = jnp.zeros((n_pages + 1, ps, Hkv, D))
    v_pages = jnp.zeros((n_pages + 1, ps, Hkv, D))
    for j in range(n_pages):
        k_pages = k_pages.at[j + 1].set(k[0, j * ps:(j + 1) * ps])
        v_pages = v_pages.at[j + 1].set(v[0, j * ps:(j + 1) * ps])
    row = np.arange(1, n_pages + 1, dtype=np.int32)
    tables = np.stack([row, row])
    half = S // 2
    qb = jnp.stack([q[0, :half], q[0, half:]])
    got = ops.batched_paged_prefill_attention(
        qb, k_pages, v_pages, jnp.asarray(tables),
        jnp.asarray([0, half], jnp.int32),
        jnp.asarray([half, S], jnp.int32), impl=impl)
    err = float(jnp.abs(jnp.concatenate([got[0], got[1]])[None] - want).max())
    assert err <= 1e-5


# ===========================================================================
# the pack step
# ===========================================================================

def test_bucket_rows_powers_of_two():
    assert [bucket_rows(k) for k in (1, 2, 3, 4, 5, 7, 8, 9)] \
        == [1, 2, 4, 4, 8, 8, 8, 16]


def test_pack_chunks_layout():
    scfg = ServeConfig(max_batch=4, prefill_chunk=8, tick_token_budget=64,
                      paged=True, chunked=True, page_size=8)
    sched = TokenBudgetScheduler(scfg)
    a = Request(1, list(range(100, 120)), 4)   # 20 tokens
    b = Request(2, list(range(200, 209)), 4)   # 9 tokens
    tasks = [ChunkTask(a, 0, 0, 8), ChunkTask(b, 1, 0, 8),
             ChunkTask(b, 1, 8, 1)]            # b's final 1-token tail
    pack = sched.pack_chunks(tasks)
    assert pack.k_real == 3
    assert pack.tokens.shape == (4, 8)         # 3 tasks -> bucket of 4
    assert pack.tokens[0].tolist() == list(range(100, 108))
    assert pack.tokens[2].tolist() == [208, 0, 0, 0, 0, 0, 0, 0]
    assert pack.offsets.tolist() == [0, 0, 8, 0]
    assert pack.true_lens.tolist() == [8, 8, 9, 0]
    # only b's tail COMPLETES a prompt; everything else is the sentinel
    assert pack.final_slots.tolist() == [4, 4, 1, 4]
    assert pack.row_slots.tolist() == [0, 1, 1, -1]


# ===========================================================================
# engine parity: batched one-launch tick == sequential per-chunk oracle
# ===========================================================================

@pytest.mark.parametrize("prefix_cache", [False, True])
def test_batched_matches_sequential_mixed_traffic(prefix_cache, model_f32):
    m, params = model_f32
    prompts = _mixed_prompts(m.cfg.vocab_size)
    seq, _ = _serve(m, params,
                    _base(prefix_cache=prefix_cache, batched=False), prompts)
    bat, eng = _serve(m, params,
                      _base(prefix_cache=prefix_cache, batched=True),
                      prompts)
    assert bat == seq
    st = eng.stats()
    assert st["packs_run"] > 0
    assert st["chunks_run"] > st["packs_run"]   # batching actually batched
    assert st["max_tick_tokens"] <= 32
    assert st["jit_calls_per_tick_max"] <= 2
    assert st["host_syncs_per_tick_max"] <= 1


def test_batched_matches_sequential_windowed_model(rng):
    """Local/global sliding-window layers (gemma3 pattern): the per-row
    window mask must survive the batching."""
    cfg = get_smoke_config("gemma3-4b").replace(dtype="float32")
    m = build_model(cfg)
    params = m.init(rng)
    prompts = _mixed_prompts(cfg.vocab_size, lens=(40, 9, 100))
    seq, _ = _serve(m, params, _base(max_batch=2, batched=False), prompts)
    bat, _ = _serve(m, params, _base(max_batch=2, batched=True), prompts)
    assert bat == seq


def test_k1_degenerate_case(model_f32):
    """One slot, one request: the batched path runs K=1 packs and must
    still match the sequential oracle and the monolithic engine."""
    m, params = model_f32
    prompts = _mixed_prompts(m.cfg.vocab_size, lens=(70,))
    # budget 17 = max_batch + prefill_chunk: exactly one chunk per tick
    kw = dict(max_batch=1, tick_token_budget=17)
    mono, _ = _serve(m, params, _base(max_batch=1, chunked=False), prompts)
    seq, _ = _serve(m, params, _base(batched=False, **kw), prompts)
    bat, eng = _serve(m, params, _base(batched=True, **kw), prompts)
    assert bat == seq == mono
    assert eng.stats()["packs_run"] == eng.stats()["chunks_run"]  # all K=1


def test_batched_stop_tokens_and_temperature(model_f32):
    """Stop tokens finish the same tick through the deferred emission, and
    seeded temperature sampling stays reproducible through the fused
    device-side sampler."""
    m, params = model_f32
    prompts = _mixed_prompts(m.cfg.vocab_size, lens=(20, 33))
    ref, _ = _serve(m, params, _base(max_new_tokens=12), prompts)
    stop = ref[min(ref)][4]
    out, eng = _serve(m, params, _base(max_new_tokens=12), prompts,
                      stop_tokens=[stop])
    for uid, toks in out.items():
        full = ref[uid]
        if stop in full:
            assert toks == full[:full.index(stop) + 1]
        else:
            assert toks == full
    assert eng.allocator.used_pages == 0
    kw = dict(temperature=0.7, seed=11, max_new_tokens=10)
    t1, _ = _serve(m, params, _base(**kw), prompts)
    t2, _ = _serve(m, params, _base(**kw), prompts)
    assert t1 == t2
    assert t1 != ref    # sampling actually happened


def test_work_clock_stats_match_sequential(model_f32):
    """Deferred emission must not shift the work-clock accounting: TTFT
    and TBT stamps are identical to the per-chunk oracle's."""
    m, params = model_f32
    prompts = _mixed_prompts(m.cfg.vocab_size)

    def stamps(batched):
        _, eng = _serve(m, params, _base(batched=batched), prompts)
        return sorted((r.uid, r.token_work, r.token_tick)
                      for r in eng.sched.finished)

    assert stamps(True) == stamps(False)


# ===========================================================================
# dispatch accounting: the acceptance criterion
# ===========================================================================

def test_one_launch_per_busy_tick(model_f32):
    """A steady-state tick with K prefilling + M decoding requests issues
    exactly ONE batched prefill launch + ONE decode launch + ONE
    device->host transfer; no tick ever exceeds that."""
    m, params = model_f32
    eng = ServeEngine(m, params, _base(max_batch=3, max_new_tokens=40,
                                       tick_token_budget=35))
    eng.submit([5, 7, 11, 13])
    while not any(r is not None and r.state is RequestState.DECODING
                  for r in eng.slots):
        eng.tick()
    eng.submit(list(range(1, 161)))            # 10 chunks of 16
    eng.submit(list(range(1, 81)))             # 5 chunks of 16
    busy = 0
    while eng.queue or any(r is not None
                           and r.state is RequestState.PREFILLING
                           for r in eng.slots):
        eng.tick()
        calls, syncs, _wall, n_chunks, n_decode = eng.launch_log[-1]
        if n_chunks and n_decode:
            busy += 1
            assert calls == 2, eng.launch_log[-1]
            assert syncs == 1, eng.launch_log[-1]
        assert calls <= 2 and syncs <= 1
    assert busy >= 3          # the steady-state shape really occurred
    eng.run_until_done(max_ticks=10_000)
    assert all(r[0] <= 2 and r[1] <= 1 for r in eng.launch_log)


def test_monolithic_tick_single_sync(model_f32):
    """Satellite: the NON-chunked tick's decode phase is one fused launch
    + one device->host transfer, not per-slot int() syncs."""
    m, params = model_f32
    eng = ServeEngine(m, params, _base(chunked=False, max_new_tokens=12))
    for p in _mixed_prompts(m.cfg.vocab_size, lens=(12, 20, 9)):
        eng.submit(p)
    eng.tick()                                  # admissions + first decode
    for _ in range(4):                          # pure decode ticks
        eng.tick()
        calls, syncs, _wall, _c, n_decode = eng.launch_log[-1]
        assert n_decode == 3
        assert calls == 1 and syncs == 1, eng.launch_log[-1]
    eng.run_until_done(max_ticks=10_000)


# ===========================================================================
# recompile guard: steady-state ticks compile nothing
# ===========================================================================

class _CompileCounter(logging.Handler):
    def __init__(self):
        super().__init__()
        self.compiles = []

    def emit(self, record):
        msg = record.getMessage()
        if "Finished XLA compilation" in msg:
            self.compiles.append(msg)


def test_steady_state_tick_zero_recompiles(model_f32):
    """With jax.log_compiles on, warmed-up ticks (same K bucket, same
    shapes) must trigger ZERO new XLA compilations - the compile-cache
    guard that keeps the one-launch tick actually one launch."""
    m, params = model_f32
    eng = ServeEngine(m, params, _base(max_batch=2, max_new_tokens=60,
                                       tick_token_budget=18))
    eng.submit([5, 7, 11, 13])
    while not any(r is not None and r.state is RequestState.DECODING
                  for r in eng.slots):
        eng.tick()
    eng.submit(list(range(1, 193)))            # 12 chunks of 16
    for _ in range(4):                         # warm the K=1 pack + decode
        eng.tick()
    assert any(r is not None and r.state is RequestState.PREFILLING
               for r in eng.slots)             # still mid-prefill: steady
    handler = _CompileCounter()
    loggers = [logging.getLogger("jax._src.dispatch"),
               logging.getLogger("jax._src.interpreters.pxla")]
    cache0 = eng.compile_cache_size()
    for lg in loggers:
        lg.addHandler(handler)
    try:
        with jax.log_compiles(True):
            for _ in range(5):                 # steady-state ticks
                eng.tick()
    finally:
        for lg in loggers:
            lg.removeHandler(handler)
    assert handler.compiles == []
    assert eng.compile_cache_size() == cache0
    assert all(r is not None for r in eng.slots)   # nothing finished: the
    eng.run_until_done(max_ticks=10_000)           # ticks were truly steady


# ===========================================================================
# hypothesis: batched == sequential over random packings / bucket sizes
# ===========================================================================

def test_property_random_packings(model_f32):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    m, params = model_f32
    prompts = _mixed_prompts(m.cfg.vocab_size, lens=(28, 9, 60))
    mono, _ = _serve(m, params, _base(max_batch=2, chunked=False), prompts)

    @settings(max_examples=8, deadline=None)
    @given(chunk_mult=st.integers(1, 4), extra=st.integers(0, 40),
           policy=st.sampled_from(["fifo", "sjf"]))
    def check(chunk_mult, extra, policy):
        chunk = 8 * chunk_mult
        budget = 2 + chunk + extra
        out, eng = _serve(
            m, params,
            _base(max_batch=2, prefill_chunk=chunk,
                  tick_token_budget=budget, admission_policy=policy),
            prompts)
        assert out == mono
        st_ = eng.stats()
        assert st_["max_tick_tokens"] <= budget
        assert st_["jit_calls_per_tick_max"] <= 2
        assert st_["host_syncs_per_tick_max"] <= 1
        assert eng.prefill_tokens == sum(len(p) for p in prompts)
        assert eng.allocator.used_pages == 0

    check()
