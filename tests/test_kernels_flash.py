import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.flash_decode import flash_decode as fd_pallas


def rand(key, *shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


CASES = [
    # B, Sq, Skv, Hq, Hkv, D, causal, window, softcap
    (1, 256, 256, 2, 2, 64, True, 0, 0.0),
    (2, 256, 256, 4, 2, 64, True, 0, 0.0),        # GQA
    (1, 256, 256, 2, 1, 128, False, 0, 0.0),      # non-causal
    (1, 384, 384, 2, 2, 64, True, 128, 0.0),      # sliding window
    (1, 256, 256, 2, 2, 64, True, 0, 30.0),       # softcap
    (1, 200, 200, 2, 2, 64, True, 0, 0.0),        # padding
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_flash_vs_oracle(case, dtype, rng):
    B, Sq, Skv, Hq, Hkv, D, causal, window, cap = case
    ks = jax.random.split(rng, 3)
    q = rand(ks[0], B, Sq, Hq, D, dtype=dtype)
    k = rand(ks[1], B, Skv, Hkv, D, dtype=dtype)
    v = rand(ks[2], B, Skv, Hkv, D, dtype=dtype)
    o_p, lse = flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   logit_softcap=cap, block_q=128,
                                   block_kv=128)
    o_n = ref.naive_attention(q, k, v, causal=causal, window=window,
                              logit_softcap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o_p, np.float32),
                               np.asarray(o_n, np.float32), atol=tol)
    assert bool(jnp.isfinite(lse).all())


@pytest.mark.parametrize("S,window", [(1024, 0), (777, 0), (1024, 256)])
def test_pallas_decode_vs_oracle(S, window, rng):
    B, Hq, Hkv, D = 2, 4, 2, 64
    ks = jax.random.split(rng, 3)
    q = rand(ks[0], B, 1, Hq, D)
    kc = rand(ks[1], B, S, Hkv, D)
    vc = rand(ks[2], B, S, Hkv, D)
    lens = jnp.array([S - 3, S // 2])
    o_p = fd_pallas(q, kc, vc, lens, window=window)
    o_r = ref.flash_decode(q, kc, vc, lens, window=window)
    np.testing.assert_allclose(np.asarray(o_p, np.float32),
                               np.asarray(o_r, np.float32), atol=2e-5)


def test_flash_ref_vs_naive_long(rng):
    ks = jax.random.split(rng, 3)
    q = rand(ks[0], 1, 700, 4, 32)
    k = rand(ks[1], 1, 700, 4, 32)
    v = rand(ks[2], 1, 700, 4, 32)
    o1 = ref.flash_attention(q, k, v, causal=True, block_kv=128)
    o2 = ref.naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5)


def test_custom_vjp_matches_autodiff(rng):
    from repro.kernels import ops
    B, S, Hq, Hkv, D = 2, 128, 4, 2, 32
    ks = jax.random.split(rng, 3)
    q = rand(ks[0], B, S, Hq, D, dtype=jnp.bfloat16)
    k = rand(ks[1], B, S, Hkv, D, dtype=jnp.bfloat16)
    v = rand(ks[2], B, S, Hkv, D, dtype=jnp.bfloat16)

    def f_ours(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, causal=True)
                       .astype(jnp.float32) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.naive_attention(q, k, v, causal=True)
                       .astype(jnp.float32) ** 2)

    g1 = jax.grad(f_ours, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        a32, b32 = np.asarray(a, np.float32), np.asarray(b, np.float32)
        assert np.abs(a32 - b32).max() / (np.abs(b32).max() + 1e-6) < 0.06


def test_vjp_with_window_and_softcap(rng):
    from repro.kernels import ops
    ks = jax.random.split(rng, 3)
    q = rand(ks[0], 1, 96, 2, 32)
    k = rand(ks[1], 1, 96, 2, 32)
    v = rand(ks[2], 1, 96, 2, 32)
    for kw in ({"window": 32}, {"logit_softcap": 20.0}):
        def f(q):
            return jnp.sum(ops.flash_attention(q, k, v, causal=True, **kw)
                           .astype(jnp.float32) ** 2)
        def fr(q):
            return jnp.sum(ref.naive_attention(q, k, v, causal=True, **kw)
                           .astype(jnp.float32) ** 2)
        g1, g2 = jax.grad(f)(q), jax.grad(fr)(q)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   atol=1e-3, rtol=2e-2)
