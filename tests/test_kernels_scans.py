import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.mamba2_scan import mamba2_scan as m2_pallas
from repro.kernels.rwkv6_scan import rwkv6_scan as rw_pallas


def _mk_mamba(rng, B=2, S=96, H=3, P=16, N=8):
    ks = jax.random.split(rng, 4)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = jnp.abs(jax.random.normal(ks[2], (H,))) + 0.1
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(jax.random.fold_in(rng, 9), (B, S, N))
    return x, dt, A, Bm, Cm


def _mk_rwkv(rng, B=2, S=96, H=3, K=16):
    ks = jax.random.split(rng, 5)
    r = jax.random.normal(ks[0], (B, S, H, K))
    k = jax.random.normal(ks[1], (B, S, H, K))
    v = jax.random.normal(ks[2], (B, S, H, K))
    w = jnp.exp(-jnp.exp(jnp.clip(jax.random.normal(ks[3], (B, S, H, K)),
                                  -8, 0.75)))
    u = jax.random.normal(ks[4], (H, K)) * 0.1
    return r, k, v, w, u


@pytest.mark.parametrize("chunk", [16, 32])
def test_mamba2_pallas_vs_naive(chunk, rng):
    x, dt, A, Bm, Cm = _mk_mamba(rng)
    y_p = m2_pallas(x, dt, A, Bm, Cm, chunk=chunk)
    y_r = ref.mamba2_scan(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_r), atol=5e-4)


def test_mamba2_chunked_vs_naive(rng):
    x, dt, A, Bm, Cm = _mk_mamba(rng, S=100)
    y_c = ref.mamba2_scan_chunked(x, dt, A, Bm, Cm, chunk=32)
    y_r = ref.mamba2_scan(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r), atol=5e-4)


def test_mamba2_step_matches_scan(rng):
    x, dt, A, Bm, Cm = _mk_mamba(rng, S=8)
    y_scan = ref.mamba2_scan(x, dt, A, Bm, Cm)
    B, S, H, P = x.shape
    h = jnp.zeros((B, H, P, Bm.shape[-1]), jnp.float32)
    ys = []
    for t in range(S):
        h, y = ref.mamba2_step(h, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        ys.append(y)
    y_step = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_scan),
                               atol=1e-4)


@pytest.mark.parametrize("chunk", [16, 32])
def test_rwkv6_pallas_vs_naive(chunk, rng):
    r, k, v, w, u = _mk_rwkv(rng)
    y_p = rw_pallas(r, k, v, w, u, chunk=chunk)
    y_r = ref.rwkv6_scan(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_r), atol=2e-3)


def test_rwkv6_chunked_vs_naive(rng):
    r, k, v, w, u = _mk_rwkv(rng, S=100)
    y_c = ref.rwkv6_scan_chunked(r, k, v, w, u, chunk=32)
    y_r = ref.rwkv6_scan(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r), atol=2e-3)


def test_rwkv6_step_matches_scan(rng):
    r, k, v, w, u = _mk_rwkv(rng, S=8)
    y_scan = ref.rwkv6_scan(r, k, v, w, u)
    B, S, H, K = r.shape
    st = jnp.zeros((B, H, K, K), jnp.float32)
    ys = []
    for t in range(S):
        st, y = ref.rwkv6_step(st, r[:, t], k[:, t], v[:, t], w[:, t], u)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_scan), atol=1e-4)


def test_scans_linear_in_v(rng):
    """Both recurrences are linear in v: scan(2v) == 2 scan(v)."""
    r, k, v, w, u = _mk_rwkv(rng, S=32)
    y1 = ref.rwkv6_scan(r, k, 2.0 * v, w, u)
    y2 = 2.0 * ref.rwkv6_scan(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
