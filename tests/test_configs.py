import pytest

from repro.configs import (ARCH_MODULES, ASSIGNED_ARCHS, SHAPES, get_config,
                           get_smoke_config)

EXPECT_PARAMS_B = {
    "llava-next-34b": (30, 40), "granite-3-2b": (2, 3.2), "gemma3-4b": (2.4, 4.4),
    "granite-8b": (7, 9.5), "olmo-1b": (0.9, 1.5), "whisper-base": (0.05, 0.12),
    "zamba2-2.7b": (1.8, 3.5), "qwen3-moe-235b-a22b": (200, 260),
    "olmoe-1b-7b": (5.5, 8.5), "rwkv6-1.6b": (1.1, 2.0),
    "opt-6.7b": (6, 7.4), "qwen-7b": (6.5, 8.5),
}


@pytest.mark.parametrize("arch", list(ARCH_MODULES))
def test_config_loads_and_param_count(arch):
    cfg = get_config(arch)
    lo, hi = EXPECT_PARAMS_B[arch]
    p = cfg.param_count() / 1e9
    assert lo <= p <= hi, f"{arch}: {p:.2f}B outside [{lo},{hi}]"
    assert cfg.n_heads % cfg.n_kv_heads == 0


@pytest.mark.parametrize("arch", list(ARCH_MODULES))
def test_smoke_config_is_reduced(arch):
    full, smoke = get_config(arch), get_smoke_config(arch)
    assert smoke.param_count() < full.param_count() / 100
    assert smoke.family == full.family


def test_moe_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    active = cfg.active_param_count() / 1e9
    assert 18 <= active <= 28, active     # ~22B active


def test_shapes_table():
    assert SHAPES["train_4k"].kind == "train"
    assert SHAPES["decode_32k"].is_decode
    assert SHAPES["long_500k"].seq_len == 524_288
    assert len(ASSIGNED_ARCHS) == 10


def test_subquadratic_gating():
    assert get_config("rwkv6-1.6b").subquadratic
    assert get_config("zamba2-2.7b").subquadratic
    assert not get_config("gemma3-4b").subquadratic   # 1-in-6 global layers
    assert not get_config("granite-8b").subquadratic
