"""Chaos suite: deterministic fault injection against the fleet router.

Every scenario drives a fleet through a registered traffic trace while a
seeded FaultPlan kills, drains, wedges, or page-starves replicas at
fixed tick indices, and asserts the full invariant sweep every tick
(survivor page conservation, dispatch/redispatch ledger, work-clock
monotonicity, no duplicated terminals) plus the chaos conformance
contract: every request that finishes DONE produces output identical to
a fault-free run of the same trace - replica death is invisible in the
tokens, visible only in telemetry and latency.
"""
import jax
import pytest

from chaos import (Fault, FaultPlan, assert_chaos_conformance,
                   random_fault_plan, replay_fleet_chaos)
from conformance import TRACES, make_scfg
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import FleetConfig, FleetRouter, ReplicaState
from traffic import TrafficItem, replay_fleet


@pytest.fixture(scope="module")
def model_f32():
    # float32 keeps greedy argmax ties out of the conformance comparisons
    cfg = get_smoke_config("granite-3-2b").replace(dtype="float32")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _fleet(model, params, scfg, n, **fcfg_kw):
    return FleetRouter(model, params, scfg,
                       FleetConfig(n_replicas=n, **fcfg_kw))


def _baseline(model, params, spec, n=2, **scfg_kw):
    """Fault-free reference run: same trace, same fleet size."""
    scfg = make_scfg(spec, False, max_new_tokens=12, **scfg_kw)
    router = _fleet(model, params, scfg, n)
    out, _ = replay_fleet(router, spec.build(model.cfg.vocab_size),
                          check=True)
    return out, scfg


# ===========================================================================
# the tentpole: kill one replica mid-trace, every registered trace
# ===========================================================================

@pytest.mark.parametrize("trace", sorted(TRACES))
def test_kill_replica_mid_trace_is_invisible_in_outputs(trace, model_f32):
    """Replica death mid-flight must not change a single token: queued
    and in-flight requests redispatch to the survivor through the resume
    path (prompt + generated-so-far re-prefilled through the chunk path)
    and every request completes with output identical to the fault-free
    run.  Invariants sweep every tick inside replay_fleet_chaos."""
    m, params = model_f32
    spec = TRACES[trace]
    base, scfg = _baseline(m, params, spec)
    router = _fleet(m, params, scfg, 2)
    plan = FaultPlan([Fault(2, "kill", 1)])
    out, done = replay_fleet_chaos(router, spec.build(m.cfg.vocab_size),
                                   plan)
    # nothing lost, nothing timed out: every request completed DONE
    assert set(router.statuses().values()) == {"done"}
    done_uids = assert_chaos_conformance(m, params, router, done, base)
    assert done_uids == base.keys()
    s = router.fleet_stats()
    assert s["failures"] == 1
    assert s["replica_states"] == ["healthy", "dead"]


def test_kill_redispatches_queued_and_in_flight(model_f32):
    """fail() moves EVERYTHING the dead replica owed: requests still
    queued and requests mid-prefill/mid-decode - each keeps its fleet
    uid, lands on a survivor, and carries its redispatch count."""
    m, params = model_f32
    spec = TRACES["mixed"]
    scfg = make_scfg(spec, False, max_new_tokens=12)
    router = _fleet(m, params, scfg, 2)
    for p in [it.prompt for it in spec.build(m.cfg.vocab_size)]:
        router.submit(p)
    for _ in range(2):
        router.tick()
    victims = sorted(f for f, r in router.placement.items()
                     if r == 1 and not router.requests[f].done)
    assert victims, "trace never placed work on replica 1"
    moved = router.fail(1)
    assert moved == victims
    for fuid in moved:
        assert router.placement[fuid] == 0
        assert router.requests[fuid].n_redispatches == 1
    # idempotent: a second fail of the corpse is a no-op
    assert router.fail(1) == []
    router.run_until_done()
    assert set(router.statuses().values()) == {"done"}
    router.check_invariants()


def test_no_healthy_replica_raises(model_f32):
    """Dispatch with every replica dead/draining must fail loudly, not
    hang or place work on a corpse."""
    m, params = model_f32
    scfg = make_scfg(TRACES["mixed"], False, max_new_tokens=4)
    router = _fleet(m, params, scfg, 2)
    router.fail(0)
    router.drain(1)
    with pytest.raises(RuntimeError, match="no healthy replica"):
        router.submit([1, 2, 3])
    # and a dead replica cannot drain or rejoin
    with pytest.raises(ValueError):
        router.drain(0)
    with pytest.raises(ValueError):
        router.undrain(0)


# ===========================================================================
# drain lifecycle
# ===========================================================================

def test_drain_to_empty_then_undrain_conformance(model_f32):
    """A drain mid-trace stops new dispatch to the replica, lets it
    empty in place, and changes no output; once empty the drain duration
    lands in the histogram and the replica stays parked DRAINING until
    undrain returns it to rotation."""
    m, params = model_f32
    spec = TRACES["mixed"]
    base, scfg = _baseline(m, params, spec)
    router = _fleet(m, params, scfg, 2)
    plan = FaultPlan([Fault(1, "drain", 0)])
    out, done = replay_fleet_chaos(router, spec.build(m.cfg.vocab_size),
                                   plan)
    assert set(router.statuses().values()) == {"done"}
    assert_chaos_conformance(m, params, router, done, base)
    s = router.fleet_stats()
    assert s["drains"] == 1
    assert s["replica_states"] == ["draining", "healthy"]
    hist = router.metrics.get("fleet_drain_duration_ticks")
    assert hist.count == 1
    router.undrain(0)
    assert router.states[0] is ReplicaState.HEALTHY
    # back in rotation: the undrained replica can take new work
    uid = router.submit([7, 8, 9, 10])
    router.run_until_done()
    assert router.statuses()[uid] == "done"


# ===========================================================================
# watchdog: stuck tick -> declared dead -> redispatch
# ===========================================================================

def test_stuck_tick_trips_watchdog_and_recovers(model_f32):
    """A replica that holds work but stops making progress (tick stubbed
    to a no-op, work clock frozen) is declared DEAD after watchdog_ticks
    stale fleet ticks; its requests redispatch and the trace completes
    with fault-free outputs."""
    m, params = model_f32
    spec = TRACES["mixed"]
    base, scfg = _baseline(m, params, spec)
    router = _fleet(m, params, scfg, 2, watchdog_ticks=3)
    plan = FaultPlan([Fault(2, "stuck", 1)])
    out, done = replay_fleet_chaos(router, spec.build(m.cfg.vocab_size),
                                   plan)
    assert set(router.statuses().values()) == {"done"}
    assert_chaos_conformance(m, params, router, done, base)
    assert int(router.metrics.get("fleet_watchdog_trips_total").value) == 1
    assert router.states[1] is ReplicaState.DEAD
    assert router.fleet_stats()["redispatches"] >= 1


def test_watchdog_ignores_idle_replicas(model_f32):
    """An EMPTY replica with a frozen work clock is idle, not wedged -
    the watchdog must never kill it."""
    m, params = model_f32
    scfg = make_scfg(TRACES["mixed"], False, max_new_tokens=4)
    router = _fleet(m, params, scfg, 2, watchdog_ticks=2)
    router.submit([1, 2, 3, 4])           # lands on one replica only
    router.run_until_done()
    for _ in range(6):                    # idle ticks, clocks frozen
        router.tick()
    assert router.states == [ReplicaState.HEALTHY, ReplicaState.HEALTHY]
    assert int(router.metrics.get("fleet_watchdog_trips_total").value) == 0


# ===========================================================================
# page-pool exhaustion (sanctioned quarantine)
# ===========================================================================

def test_pool_squeeze_under_preemption_conformance(model_f32):
    """Quarantining free pages mid-trace (deterministic pool exhaustion)
    squeezes the replica exactly like a smaller pool: preemption absorbs
    the pressure, allocator invariants hold THROUGH the squeeze (the
    conservation sum counts quarantined pages), and outputs match the
    fault-free run after the restore."""
    m, params = model_f32
    spec = TRACES["mixed"]
    base, scfg = _baseline(m, params, spec, preemption=True,
                           prefix_cache=True)
    router = _fleet(m, params, scfg, 2)
    plan = FaultPlan([Fault(2, "pool_squeeze", 0, pages=10),
                      Fault(8, "pool_restore", 0)])
    out, done = replay_fleet_chaos(router, spec.build(m.cfg.vocab_size),
                                   plan)
    assert set(router.statuses().values()) == {"done"}
    assert_chaos_conformance(m, params, router, done, base)
    assert router.engines[0].allocator.quarantined_pages == 0


# ===========================================================================
# deadlines and retry budgets under chaos
# ===========================================================================

def test_deadline_expiry_is_terminal_not_a_hang(model_f32):
    """A request whose work-clock deadline lands mid-prefill finishes
    TIMEOUT - pages freed, terminal status surfaced - while unrelated
    traffic completes untouched."""
    m, params = model_f32
    scfg = make_scfg(TRACES["mixed"], False, max_new_tokens=12)
    items = [TrafficItem(0, list(range(1, 129)), deadline=140),
             TrafficItem(0, list(range(200, 232)))]
    router = _fleet(m, params, scfg, 1)
    out, done = replay_fleet_chaos(router, items, FaultPlan([]))
    assert router.statuses() == {1: "timeout", 2: "done"}
    timed_out = router.requests[1]
    assert timed_out.finish_reason == "timeout"
    assert router.fleet_stats()["timeouts"] == 1


def test_retry_budget_exhaustion_goes_failed(model_f32):
    """max_retries=0 requests on a killed replica go terminal FAILED
    (no redispatch), surface in statuses()/outputs() and the finished
    stream, and the retries-exhausted counter accounts for each."""
    m, params = model_f32
    scfg = make_scfg(TRACES["mixed"], False, max_new_tokens=8)
    items = [TrafficItem(0, list(range(1 + i, 33 + i)), max_retries=0)
             for i in range(3)]
    router = _fleet(m, params, scfg, 2)
    plan = FaultPlan([Fault(1, "kill", 0)])
    out, done = replay_fleet_chaos(router, items, plan)
    statuses = router.statuses()
    failed = {f for f, s in statuses.items() if s == "failed"}
    assert failed, "the kill never caught a max_retries=0 request"
    assert set(statuses.values()) <= {"done", "failed"}
    assert int(router.metrics.get(
        "fleet_retries_exhausted_total").value) == len(failed)
    # FAILED requests still appear exactly once in the finished stream
    assert sorted(r.fleet_uid for r in done) == sorted(statuses)
    for f in failed:
        assert router.requests[f].finish_reason == "failed"


def test_retry_budget_allows_n_redispatches(model_f32):
    """max_retries=1 survives one kill (redispatch) and dies on the
    second: the budget counts moves, not submissions."""
    m, params = model_f32
    scfg = make_scfg(TRACES["mixed"], False, max_new_tokens=64)
    router = _fleet(m, params, scfg, 3)
    uid = router.submit(list(range(1, 200)), max_retries=1)
    router.tick()
    router.fail(router.placement[uid])
    assert router.requests[uid].n_redispatches == 1
    assert router.statuses()[uid] != "failed"
    router.tick()
    router.fail(router.placement[uid])
    assert router.statuses()[uid] == "failed"


# ===========================================================================
# seeded random chaos soak
# ===========================================================================

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_chaos_soak(seed, model_f32):
    """Seeded random FaultPlans (kills, drains, pool squeezes - always
    leaving a healthy survivor) over a registered trace: the fleet must
    drain with every request terminal, invariants green every tick, and
    every DONE output identical to the fault-free run."""
    m, params = model_f32
    spec = TRACES["mixed"]
    base, scfg = _baseline(m, params, spec, n=3)
    plan = random_fault_plan(seed, n_replicas=3, max_tick=10)
    router = _fleet(m, params, scfg, 3, watchdog_ticks=4)
    out, done = replay_fleet_chaos(router, spec.build(m.cfg.vocab_size),
                                   plan)
    assert_chaos_conformance(m, params, router, done, base)
    # same seed -> same plan: the soak is replayable, not flaky
    again = random_fault_plan(seed, n_replicas=3, max_tick=10)
    assert again.faults == plan.faults


# ===========================================================================
# tensor-parallel replicas under chaos (docs/tensor_parallel.md)
# ===========================================================================

@pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
def test_kill_tp_replica_mid_trace_is_invisible_in_outputs(model_f32):
    """The TP chaos contract: killing one head-sharded (tp_degree=2)
    replica mid-trace leaves the survivors' outputs bit-identical to a
    fault-free run of the same TP fleet - redispatch, resume, and the
    sharded kernels compose.  The per-shard byte accounting sweeps every
    tick (engine check_invariants inside replay_fleet_chaos) and must
    still hold on the survivor after the drain."""
    from conformance import assert_tp_shard_accounting

    m, params = model_f32
    spec = TRACES["mixed"]
    base, scfg = _baseline(m, params, spec, tp_degree=2)
    router = _fleet(m, params, scfg, 2)
    plan = FaultPlan([Fault(2, "kill", 1)])
    out, done = replay_fleet_chaos(router, spec.build(m.cfg.vocab_size),
                                   plan)
    assert set(router.statuses().values()) == {"done"}
    done_uids = assert_chaos_conformance(m, params, router, done, base)
    assert done_uids == base.keys()
    survivor = router.engines[0]
    assert survivor.tp_stats()["tp_degree"] == 2
    assert_tp_shard_accounting(survivor)
    s = router.fleet_stats()
    assert s["failures"] == 1
    assert s["replica_states"] == ["healthy", "dead"]
