"""Speculative-decoding conformance: spec-on == spec-off, on every trace.

The differential layer (tests/conformance.py) drives the traffic-replay
harness through paired engines; this suite asserts the contract from the
engine docs: speculation changes HOW tokens are produced (chains verified
through the batched chunk kernel, rollback by lens), never WHAT is
produced - greedy bit-parity, sampled support, work-clock totals, page
refcount conservation, and per-tick budget bounds all hold with
speculation on.
"""
import jax
import pytest

from conformance import (TRACES, assert_pages_conserved,
                         assert_sampled_support, assert_spec_conformance,
                         make_scfg, replay_trace)
from repro.configs import get_smoke_config
from repro.models import build_model


@pytest.fixture(scope="module")
def model_f32():
    # float32 keeps greedy argmax ties out of the parity comparisons
    cfg = get_smoke_config("granite-3-2b").replace(dtype="float32")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


@pytest.mark.parametrize("trace", sorted(TRACES))
def test_greedy_conformance(trace, model_f32):
    """Bit-identical greedy outputs, equal work clocks, pages conserved,
    per-tick invariants (replay checks them every tick) - on every
    registered traffic shape, including preemption interleaved with
    speculation (priority_burst)."""
    m, params = model_f32
    assert_spec_conformance(m, params, TRACES[trace])


def test_spec_budget_respected(model_f32):
    """Drafted tokens consume tick budget: no tick's total work (decode +
    accepted drafts + prefill chunks) may exceed tick_token_budget, and
    the per-tick decode+prefill split the scheduler logs stays within
    budget with speculation on."""
    m, params = model_f32
    trace = TRACES["mixed"]
    _, eng = replay_trace(m, params, trace, True)
    budget = eng.scfg.tick_token_budget
    for d, p in eng.sched.tick_log:
        assert d + p <= budget, (d, p, budget)
    assert eng.stats()["spec_drafted"] > 0


def test_spec_acceptance_emits_chains(model_f32):
    """On the shared-prefix trace with long generations (the attractor
    shape) acceptance is nonzero and the speculative run needs strictly
    fewer ticks - chains really do emit multiple tokens per launch."""
    m, params = model_f32
    trace = TRACES["shared_prefix"]
    kw = dict(max_new_tokens=96, max_seq=1024, tick_token_budget=96)
    _, eng_off = replay_trace(m, params, trace, False, **kw)
    _, eng_on = replay_trace(m, params, trace, True, **kw)
    s_on = eng_on.stats()
    assert s_on["spec_accepted"] > 0
    assert s_on["ticks"] < eng_off.stats()["ticks"]
    assert s_on["tokens_per_kv_page"] > \
        eng_off.stats()["tokens_per_kv_page"]


def test_sampled_conformance_fixed_seed(model_f32):
    """Sampled decoding (temperature + top-k + top-p) with speculation:
    a fixed seed reproduces the trace exactly, every emitted token lies
    in the support of the target's own filtered distribution at its
    position (teacher-forced), and both runs emit identical token
    COUNTS (the work clock never depends on acceptance luck)."""
    m, params = model_f32
    trace = TRACES["mixed"]
    kw = dict(temperature=0.8, top_k=20, top_p=0.95, seed=7)
    out1, eng1 = replay_trace(m, params, trace, True, **kw)
    out2, eng2 = replay_trace(m, params, trace, True, **kw)
    assert out1 == out2                      # fixed-seed reproducibility
    _, eng0 = replay_trace(m, params, trace, False, **kw)
    assert {u: len(t) for u, t in out1.items()} == \
        {r.uid: len(r.out_tokens) for r in eng0.sched.finished}
    assert eng0.stats()["work_tokens"] == eng1.stats()["work_tokens"]
    assert_pages_conserved(eng1)
    assert_sampled_support(m, params, eng1.scfg, eng1.sched.finished)


def test_sampled_support_spec_off_oracle(model_f32):
    """The support checker itself is validated against the baseline
    engine: a non-speculative sampled run must pass it (the check tests
    the sampler contract, not speculation)."""
    m, params = model_f32
    trace = TRACES["shared_prefix"]
    kw = dict(temperature=1.0, top_k=12, top_p=0.9, seed=3)
    _, eng = replay_trace(m, params, trace, False, **kw)
    assert_sampled_support(m, params, eng.scfg, eng.sched.finished)


def test_work_clock_stamps_identical_single_stream(model_f32):
    """The accepted-tokens-only work clock, asserted at token
    granularity: for a single-request trace (no concurrent prefill to
    re-plan around) the speculative run's per-token work stamps are
    BIT-identical to the baseline's - a chain of n_acc + 1 tokens
    advances the clock exactly as n_acc + 1 sequential decode ticks
    would, so work-clock TTFT and every TBT interval match exactly."""
    import numpy as np

    from conformance import TraceSpec
    from traffic import TrafficItem

    m, params = model_f32
    rng = np.random.default_rng(5)
    prompt = rng.integers(1, m.cfg.vocab_size, size=40).tolist()
    trace = TraceSpec("single", lambda v: [TrafficItem(0, prompt)])
    kw = dict(max_new_tokens=48)
    _, eng_off = replay_trace(m, params, trace, False, **kw)
    _, eng_on = replay_trace(m, params, trace, True, **kw)
    (r_off,), (r_on,) = eng_off.sched.finished, eng_on.sched.finished
    assert r_on.token_work == r_off.token_work
    assert r_on.ttft_work() == r_off.ttft_work()
    assert r_on.tbt_work() == r_off.tbt_work()
    assert eng_on.stats()["spec_accepted"] > 0   # chains actually emitted
