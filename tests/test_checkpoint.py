import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.train.checkpoint import (all_checkpoints, restore_checkpoint,
                                    restore_latest, save_checkpoint)
from repro.train.trainer import Trainer


def test_roundtrip(tmp_path):
    state = {"a": jnp.arange(8, dtype=jnp.float32),
             "nested": {"b": jnp.ones((2, 3), jnp.bfloat16)},
             "step": jnp.array(7)}
    save_checkpoint(str(tmp_path), 7, state)
    template = jax.eval_shape(lambda: state)
    restored, step = restore_checkpoint(str(tmp_path), 7, template)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_retention(tmp_path):
    state = {"a": jnp.zeros(4)}
    for s in range(6):
        save_checkpoint(str(tmp_path), s, state, keep=3)
    assert all_checkpoints(str(tmp_path)) == [3, 4, 5]


def test_corrupt_checkpoint_skipped(tmp_path):
    state = {"a": jnp.arange(4.0)}
    save_checkpoint(str(tmp_path), 1, state)
    save_checkpoint(str(tmp_path), 2, state)
    # corrupt the newest
    (tmp_path / "step_2" / "arrays.npz").write_bytes(b"garbage")
    template = jax.eval_shape(lambda: state)
    restored = restore_latest(str(tmp_path), template)
    assert restored is not None and restored[1] == 1


def test_crash_resume_end_to_end(tmp_path):
    cfg = get_smoke_config("olmo-1b")
    tcfg = TrainConfig(global_batch=2, seq_len=32, total_steps=8,
                       warmup_steps=1, checkpoint_every=3,
                       checkpoint_dir=str(tmp_path), log_every=2)
    tr = Trainer(cfg, tcfg, fail_at_step=5)
    with pytest.raises(RuntimeError):
        tr.run()
    tr2 = Trainer(cfg, tcfg)
    assert tr2.start_step == 3                 # resumed from step_2
    out = tr2.run()
    assert out["final_step"] == 7


def test_elastic_restore_same_values(tmp_path):
    """Restore places leaves with whatever sharding tree is supplied -
    restoring onto a different mesh is the same code path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_debug_mesh
    state = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 0, state)
    mesh = make_debug_mesh(1, 1)
    shard = {"w": NamedSharding(mesh, P(None, None))}
    template = jax.eval_shape(lambda: state)
    restored, _ = restore_checkpoint(str(tmp_path), 0, template,
                                     mesh=mesh, sharding_tree=shard)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
