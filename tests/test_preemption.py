"""Preemptive, decode-priority scheduling under page-pool pressure.

Covers the tentpole of ISSUE 5: decode-priority budget shaping (the
prefill share of a tick is capped so queued prefill depth cannot inflate
decode TBT), victim preemption when the page pool cannot place a
higher-priority admission (PREFILLING most-recently-admitted first, then
DECODING longest-remaining; equal priority NEVER preempts), the
QUEUED->RESUMING park/resume lifecycle, page-refcount conservation across
preempt/resume, resume-via-prefix-cache page survival, monotone TTFT/TBT
work-clock stamps across a preemption, and the preemption counters in
stats().

Parity methodology: greedy outputs of a preempted run are compared
bit-for-bit against an uninterrupted full-capacity oracle run in the SAME
process.  Three pins make that comparison structural rather than lucky:
engines share jitted steps per model (serve/engine.py _shared_steps);
oracle and pressured runs share num_pages (pressure comes from the
usable_pages capacity cap) so array shapes and compiled executables
match; and max_chunks_per_tick=1 keeps every chunk pack in the K=1
kernel bucket across schedules.  traffic.assert_greedy_equivalent backs
the bit comparison with an epsilon-greedy teacher-forced check, so a
genuine last-ulp argmax tie cannot flake the suite while real KV
corruption (which shifts logits by orders of magnitude more) still fails.
The soak exercises random arrival traffic (mixed lengths, shared
prefixes, priorities, bursts - tests/traffic.py) against a deliberately
tiny usable-page cap, asserting the engine never deadlocks, allocator
invariants hold after EVERY tick, and final outputs bit-match the
full-capacity oracle.
"""
import copy

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.serve import ServeEngine, RequestState, TokenBudgetScheduler
from repro.serve.scheduler import Request

from traffic import (assert_greedy_equivalent, priority_burst,
                     random_arrivals, replay)

PAGE = 8


@pytest.fixture(scope="module")
def model_f32():
    # float32 keeps greedy argmax ties out of the parity comparisons
    cfg = get_smoke_config("granite-3-2b").replace(dtype="float32")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _cfg(**over):
    # deterministic-replay configuration: num_pages stays FIXED across
    # every engine in this file and pressure comes from usable_pages (a
    # host-side capacity cap), so a pressured run and its full-capacity
    # oracle share identical array shapes - and therefore identical
    # compiled executables; max_chunks_per_tick=1 additionally pins every
    # pack to the K=1 kernel bucket, so every schedule (oracle, pressured,
    # resumed) rebuilds KV through the same executable.  Under those two
    # pins, bit-parity with the oracle is structural, not luck.
    base = dict(max_batch=3, max_seq=256, max_new_tokens=8, paged=True,
                page_size=PAGE, num_pages=200, chunked=True,
                prefill_chunk=16, tick_token_budget=24, preemption=True,
                max_chunks_per_tick=1)
    base.update(over)
    return ServeConfig(**base)


def _replay(model, params, scfg, items):
    eng = ServeEngine(model, params, scfg)
    out, done = replay(eng, copy.deepcopy(items))
    return out, eng


def _assert_parity(model, params, eng, out, oracle):
    """Bit equality against the oracle, with the epsilon-greedy fallback
    for genuine fp near-ties (traffic.assert_greedy_equivalent)."""
    if out != oracle:
        assert_greedy_equivalent(model, params, eng.sched.finished, oracle)


# ===========================================================================
# budget shaper
# ===========================================================================

def test_prefill_budget_unit():
    sched = TokenBudgetScheduler(_cfg(tick_token_budget=40))
    assert sched.prefill_budget(0) == 40
    assert sched.prefill_budget(3) == 37
    shaped = TokenBudgetScheduler(_cfg(tick_token_budget=40,
                                       decode_priority=True,
                                       max_prefill_fraction=0.5))
    assert shaped.prefill_budget(0) == 20          # capped at 0.5 * budget
    assert shaped.prefill_budget(3) == 20
    assert shaped.prefill_budget(39) == 1          # decode always fits first
    assert shaped.prefill_budget(40) == 0


def test_decode_priority_validation():
    with pytest.raises(ValueError, match="decode_priority"):
        ServeConfig(decode_priority=True).validate()
    with pytest.raises(ValueError, match="max_prefill_fraction"):
        _cfg(decode_priority=True, max_prefill_fraction=1.5).validate()
    with pytest.raises(ValueError, match="prefill_chunk"):
        _cfg(decode_priority=True, max_prefill_fraction=0.1,
             tick_token_budget=40).validate()
    with pytest.raises(ValueError, match="preemption"):
        ServeConfig(preemption=True).validate()
    _cfg(decode_priority=True, max_prefill_fraction=0.7).validate()


def test_decode_priority_bounds_decode_tbt(model_f32):
    """The tentpole property at test scale: under a burst of queued long
    prefills, decode-priority shaping caps per-tick work, so the
    work-clock TBT of an in-flight decode is strictly lower than with
    shaping off - with byte-identical greedy outputs."""
    m, params = model_f32
    rng = np.random.default_rng(0)
    short = rng.integers(1, m.cfg.vocab_size, size=8).tolist()
    longs = [rng.integers(1, m.cfg.vocab_size, size=96).tolist()
             for _ in range(3)]

    def run(shaped):
        scfg = _cfg(max_batch=4, max_new_tokens=24, tick_token_budget=52,
                    preemption=False, decode_priority=shaped,
                    max_prefill_fraction=0.5, max_chunks_per_tick=0)
        eng = ServeEngine(m, params, scfg)
        uid = eng.submit(short)
        while not any(r is not None and r.state is RequestState.DECODING
                      for r in eng.slots):
            eng.tick()
        for p in longs:                            # the prefill burst
            eng.submit(p)
        done = eng.run_until_done(max_ticks=10_000)
        eng.check_invariants()
        req = next(r for r in done if r.uid == uid)
        tbt = req.tbt_work()
        outs = {r.uid: r.out_tokens for r in done}
        return outs, max(tbt), float(np.percentile(tbt, 95)), eng

    out_off, max_off, p95_off, _ = run(False)
    out_on, max_on, p95_on, eng = run(True)
    # same requests complete with full budgets either way (shaping changes
    # the schedule, not completion; bit-parity is asserted in the K=1
    # matched-bucket scenarios below)
    assert {u: len(t) for u, t in out_on.items()} \
        == {u: len(t) for u, t in out_off.items()}
    # shaped: a tick carries at most n_decode + 0.5 * budget work
    assert max_on <= 4 + 26
    assert max_off > max_on                        # burst inflated unshaped TBT
    assert p95_on < p95_off
    for d, p in eng.tick_log:
        assert p <= 26                             # prefill share hard-capped


def test_chunk_floor_goes_to_highest_priority_class():
    """The guaranteed-progress chunk goes to the oldest request OF THE
    HIGHEST PRESENT PRIORITY: a high-priority admission (e.g. one that
    just preempted its way in) must not wait out a lower-priority
    neighbor's prefill, while within a class the oldest still wins."""
    sched = TokenBudgetScheduler(_cfg())
    lo = Request(1, list(range(96)), 4, priority=0)
    hi = Request(2, list(range(64)), 4, priority=5)
    tasks = sched.plan_chunks([(0, lo), (1, hi)], budget=16)
    assert [(t.req.uid, t.length) for t in tasks] == [(2, 16)]
    # equal priority: oldest keeps the floor (anti-starvation unchanged)
    hi0 = Request(3, list(range(64)), 4, priority=0)
    tasks = sched.plan_chunks([(0, lo), (1, hi0)], budget=16)
    assert [(t.req.uid, t.length) for t in tasks] == [(1, 16)]


# ===========================================================================
# victim selection + lifecycle
# ===========================================================================

def test_preempt_victim_choice_prefilling_most_recent(model_f32):
    """Two low-priority requests mid-prefill; a high-priority arrival that
    does not fit must shed the MOST RECENTLY admitted one."""
    m, params = model_f32
    eng = ServeEngine(m, params, _cfg(usable_pages=28))
    a = eng.submit(list(range(1, 97)))             # 13 pages
    b = eng.submit(list(range(1, 97)))             # 13 pages (3 free left)
    eng.tick()                                     # both admitted, prefilling
    reqs = {r.uid: r for r in eng.slots if r is not None}
    assert reqs[a].state is reqs[b].state is RequestState.PREFILLING
    hi = eng.submit(list(range(1, 65)), priority=5)
    eng.tick()
    assert reqs[b].state is RequestState.RESUMING  # newer admission shed
    assert reqs[a].state is RequestState.PREFILLING
    assert reqs[b].slot is None and reqs[b] in eng.queue
    assert reqs[b].n_preemptions == 1
    hi_req = next(r for r in eng.slots if r is not None and r.uid == hi)
    assert hi_req.priority == 5
    st = eng.stats()
    assert st["preemptions"] == 1 and st["pages_reclaimed"] == 13
    eng.check_invariants()
    done = eng.run_until_done(max_ticks=20_000)
    assert sorted(r.uid for r in done) == [a, b, hi]
    assert reqs[b].n_resumes == 1
    assert eng.stats()["resumes"] == 1


def test_preempt_victim_choice_decoding_longest_remaining(model_f32):
    """With only DECODING candidates, the victim is the one with the most
    generation budget left (it would hold its pages longest)."""
    m, params = model_f32
    eng = ServeEngine(m, params, _cfg(max_batch=2, usable_pages=18))
    a = eng.submit(list(range(1, 49)), max_new_tokens=12)   # 8 pages
    b = eng.submit(list(range(1, 49)), max_new_tokens=24)   # 9 pages
    for _ in range(40):
        eng.tick()
        reqs = {r.uid: r for r in eng.slots if r is not None}
        if len(reqs) == 2 and all(r.state is RequestState.DECODING
                                  for r in reqs.values()):
            break
    else:
        pytest.fail("background requests never reached DECODING")
    hi = eng.submit(list(range(1, 49)), priority=1)          # 7 pages
    eng.tick()
    assert reqs[b].state is RequestState.RESUMING            # longest remaining
    assert reqs[a].state is RequestState.DECODING
    # a mid-decode victim resumes from prompt + generated-so-far
    assert reqs[b].resume_tokens == reqs[b].prompt + reqs[b].out_tokens
    eng.check_invariants()
    done = eng.run_until_done(max_ticks=20_000)
    assert sorted(r.uid for r in done) == [a, b, hi]


def test_equal_priority_never_preempts(model_f32):
    """The priority-inversion guard, half one: equal-priority pressure
    backpressures exactly like preemption=False - nothing is shed."""
    m, params = model_f32
    eng = ServeEngine(m, params, _cfg(max_batch=2, usable_pages=15))
    for _ in range(3):
        eng.submit(list(range(1, 81)))             # 11 pages each, pool 15
    done = eng.run_until_done(max_ticks=20_000)
    assert len(done) == 3
    st = eng.stats()
    assert st["preemptions"] == 0 and st["resumes"] == 0
    assert st["pages_reclaimed"] == 0


def test_lower_priority_never_preempts_higher(model_f32):
    """The priority-inversion guard, half two: a queued low-priority
    request must wait out a running high-priority one."""
    m, params = model_f32
    eng = ServeEngine(m, params, _cfg(max_batch=2, usable_pages=15))
    hi = eng.submit(list(range(1, 81)), priority=5)
    eng.tick()
    lo = eng.submit(list(range(1, 81)), priority=0)
    done = eng.run_until_done(max_ticks=20_000)
    assert eng.stats()["preemptions"] == 0
    assert [r.uid for r in done] == [hi, lo]       # hi ran to completion first


def test_preempt_headroom_guard(model_f32):
    """A candidate that could not fit even after shedding every eligible
    victim must NOT shed anyone (backpressure, work preserved)."""
    m, params = model_f32
    eng = ServeEngine(m, params, _cfg(max_batch=3, usable_pages=19))
    anchor = eng.submit(list(range(1, 57)), priority=9)        # 8 pages, pinned
    lo = eng.submit(list(range(1, 41)), priority=0)            # 6 pages
    eng.tick()
    # mid needs 13 pages; free = 19 - 14 = 5; only lo (6 pages) is
    # sheddable (anchor outranks mid): 5 + 6 = 11 < 13 -> refuse, park
    mid = eng.submit(list(range(1, 89)), priority=5,
                     max_new_tokens=16)                        # 13 pages
    eng.tick()
    assert eng.stats()["preemptions"] == 0        # shedding lo would not
    done = eng.run_until_done(max_ticks=20_000)   # cover mid's 13 pages
    assert sorted(r.uid for r in done) == [anchor, lo, mid]


# ===========================================================================
# preempt/resume parity vs an uninterrupted large-pool oracle
# ===========================================================================

@pytest.mark.parametrize("batched", [True, False])
@pytest.mark.parametrize("prefix_cache", [False, True])
def test_resume_parity_prefilling_victim(batched, prefix_cache, model_f32):
    """A victim shed mid-PREFILL re-prefills from its cursor (or the
    surviving cached prefix) and must produce byte-identical greedy
    outputs to a run that was never preempted.  The oracle shares the
    victim run's full configuration (only the capacity cap differs), so
    both runs execute the same code paths on the same executables."""
    m, params = model_f32
    items = priority_burst(m.cfg.vocab_size, (96, 96), (64,), 1,
                           burst_priority=5, seed=1)
    oracle, _ = _replay(m, params, _cfg(batched=batched,
                                        prefix_cache=prefix_cache), items)
    out, eng = _replay(m, params, _cfg(usable_pages=28, batched=batched,
                                       prefix_cache=prefix_cache), items)
    _assert_parity(m, params, eng, out, oracle)
    st = eng.stats()
    assert st["preemptions"] >= 1 and st["resumes"] >= 1
    assert st["pages_reclaimed"] >= 1


@pytest.mark.parametrize("batched", [True, False])
@pytest.mark.parametrize("prefix_cache", [False, True])
def test_resume_parity_decoding_victim(batched, prefix_cache, model_f32):
    """A victim shed MID-DECODE re-prefills prompt + generated-so-far; the
    final resume chunk's logits sample the next token exactly as the
    uninterrupted decode would - byte-identical outputs, monotone
    work-clock stamps."""
    m, params = model_f32
    items = priority_burst(m.cfg.vocab_size, (96,), (64,), 9,
                           burst_priority=5, seed=0)
    oracle, _ = _replay(m, params, _cfg(max_batch=2, batched=batched,
                                        prefix_cache=prefix_cache), items)
    out, eng = _replay(m, params,
                       _cfg(max_batch=2, usable_pages=15, batched=batched,
                            prefix_cache=prefix_cache), items)
    _assert_parity(m, params, eng, out, oracle)
    victim = next(r for r in eng.sched.finished if r.n_preemptions)
    assert victim.resume_tokens is not None       # preempted while decoding
    assert len(victim.out_tokens) > len(victim.resume_tokens) \
        - len(victim.prompt)                      # kept generating after
    # TTFT/TBT accounting stays monotone across the preempt/resume: stamps
    # are carried, never reset
    assert victim.token_work == sorted(victim.token_work)
    assert victim.ttft_work() > 0
    assert all(d >= 0 for d in victim.tbt_work())
    assert eng.stats()["preemptions"] >= 1


def test_resume_via_prefix_cache_reuses_survivors(model_f32):
    """Pages the tree references survive a preemption (refcounts), so a
    resuming victim re-matches them and only re-prefills the remainder."""
    m, params = model_f32
    prompt = list(range(1, 97))                    # 12 full pages
    eng = ServeEngine(m, params, _cfg(max_batch=2,
                                      prefix_cache=True))
    # a finished warmup publishes the prompt's pages into the tree
    eng.submit(prompt, max_new_tokens=1)
    eng.run_until_done(max_ticks=10_000)
    published = eng.prefix.cached_pages
    assert published == 12
    # the same prompt re-admits (attaching cached pages) and gets shed
    uid = eng.submit(prompt)
    eng.tick()
    req = next(r for r in eng.slots if r is not None and r.uid == uid)
    prefill_before = eng.prefill_tokens
    eng._preempt(req)
    eng.check_invariants()
    # the attached pages survived the shed: still cached, refcount back to 1
    assert eng.prefix.cached_pages == published
    survivors = eng.prefix.cached_prefix_len(req.target)
    assert survivors >= 88                         # all but the COW'd tail
    done = eng.run_until_done(max_ticks=10_000)
    assert done[0].uid == uid and done[0].n_resumes == 1
    # the resume recomputed at most the non-surviving remainder per pass
    assert eng.prefill_tokens - prefill_before \
        <= 2 * (len(prompt) - survivors + PAGE)
    eng.check_invariants()


def test_preempt_publishes_victim_pages(model_f32):
    """Publish-on-preempt: shedding a victim with a prefix cache active
    PARKS its computed KV pages in the tree (refcounted) instead of
    discarding them, so the resume re-attaches them as cache hits and
    only recomputes the unparked tail.  Refcounts conserve throughout:
    right after the shed the tree is the sole owner of every parked
    page, and the drained engine balances used == cached."""
    m, params = model_f32
    eng = ServeEngine(m, params, _cfg(max_batch=2, prefix_cache=True))
    uid = eng.submit(list(range(1, 97)), max_new_tokens=8)   # 12 pages
    req = None
    for _ in range(12):                            # prefill -> decoding
        eng.tick()
        req = next((r for r in eng.slots if r is not None), None)
        if req is not None and req.state is RequestState.DECODING:
            break
    assert req is not None and req.state is RequestState.DECODING
    parked0, cached0 = eng.sched.pages_parked, eng.prefix.cached_pages
    eng._preempt(req)
    eng.check_invariants()
    parked = eng.sched.pages_parked - parked0
    assert parked >= 12                            # whole prompt parked
    assert eng.prefix.cached_pages - cached0 == parked
    # the tree is now the sole owner: nothing else maps pages
    assert eng.allocator.used_pages == eng.prefix.cached_pages
    assert eng.stats()["pages_parked"] == eng.sched.pages_parked
    # the resume re-attaches the parked pages as prefix hits
    hit0 = eng.prefill_tokens
    done = eng.run_until_done(max_ticks=10_000)
    assert done[-1].uid == uid and done[-1].n_resumes == 1
    assert len(done[-1].out_tokens) == 8
    assert eng.prefix_hit_tokens >= parked * PAGE - PAGE   # COW'd tail
    assert eng.prefill_tokens - hit0 <= len(req.target) + 2 * PAGE \
        - eng.prefix_hit_tokens + req.max_new_tokens
    eng.check_invariants()
    assert eng.allocator.used_pages == eng.prefix.cached_pages


def test_refcount_conservation_across_preempt_cycles(model_f32):
    """Repeated forced preempt/resume cycles conserve page accounting:
    after every cycle the allocator balances and no page leaks."""
    m, params = model_f32
    eng = ServeEngine(m, params, _cfg(max_batch=2,
                                      prefix_cache=True))
    uid = eng.submit(list(range(1, 81)), max_new_tokens=12)
    free0 = None
    for cycle in range(3):
        for _ in range(3):
            eng.tick()
        req = next((r for r in eng.slots if r is not None), None)
        if req is None:
            break
        if free0 is None:
            free0 = eng.allocator.free_pages + len(
                eng.allocator.slot_pages(req.slot))
        eng._preempt(req)
        eng.check_invariants()
        assert req.state is RequestState.RESUMING
    done = eng.run_until_done(max_ticks=20_000)
    assert done and done[-1].uid == uid
    eng.check_invariants()
    assert eng.allocator.live_pages() == 0         # nothing left mapped
    st = eng.stats()
    assert st["preemptions"] == st["resumes"] >= 2


# ===========================================================================
# stats / gauges
# ===========================================================================

def test_priority_queue_depth_gauges(model_f32):
    m, params = model_f32
    eng = ServeEngine(m, params, _cfg(max_batch=1))
    eng.submit([1, 2, 3])                          # admits immediately
    eng.tick()
    eng.submit([4, 5, 6], priority=2)
    eng.submit([7, 8, 9], priority=2)
    eng.submit([1, 1, 1], priority=-1)
    st = eng.stats()
    assert st["queue_depth"] == 3
    assert st["queue_depth_by_priority"] == {"2": 2, "-1": 1}
    # higher priority admits first even under FIFO
    done = eng.run_until_done(max_ticks=20_000)
    uid_order = [r.uid for r in done]
    assert uid_order.index(2) < uid_order.index(4)
    assert uid_order.index(3) < uid_order.index(4)


def test_stats_expose_preemption_counters(model_f32):
    m, params = model_f32
    out, eng = _replay(m, params, _cfg(usable_pages=28),
                       priority_burst(m.cfg.vocab_size, (96, 96), (64,), 1,
                                      burst_priority=5, seed=3))
    st = eng.stats()
    for key in ("preemptions", "resumes", "pages_reclaimed",
                "queue_depth", "queue_depth_by_priority"):
        assert key in st
    assert st["preemptions"] >= 1
    assert st["resumes"] == st["preemptions"]      # everything resumed
    assert st["queue_depth"] == 0                  # drained


# ===========================================================================
# soak: random traffic against a tiny pool
# ===========================================================================

def _soak_case(model, params, seed: int):
    """One soak example: random arrivals (mixed lengths, shared prefixes,
    priorities, bursts) against a deliberately tiny page pool, with
    invariants checked after EVERY tick (traffic.replay).  The engine must
    drain without deadlock and bit-match the large-pool oracle."""
    items = random_arrivals(model.cfg.vocab_size, 10, seed)
    for prefix_cache in (False, True):
        # oracle and pressured run share the FULL configuration (only the
        # capacity cap differs): same code paths, same executables
        oracle, _ = _replay(model, params,
                            _cfg(max_new_tokens=4,
                                 prefix_cache=prefix_cache), items)
        out, eng = _replay(model, params,
                           _cfg(max_new_tokens=4, usable_pages=17,
                                prefix_cache=prefix_cache), items)
        _assert_parity(model, params, eng, out, oracle)
        assert len(out) == len(items)
        st = eng.stats()
        assert st["queue_depth"] == 0
        assert eng.allocator.live_pages() == 0
    return st


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_soak_fixed_seeds(seed, model_f32):
    """The CI fixed-seed soak profile: always runs, no hypothesis
    dependency - the same cases the hypothesis property starts from."""
    m, params = model_f32
    _soak_case(m, params, seed)


def test_soak_preemptions_actually_occur(model_f32):
    """The soak pool is genuinely tiny: across the fixed seed profile the
    preemption path fires (otherwise the soak proves nothing)."""
    m, params = model_f32
    total = 0
    for seed in (0, 1, 2):
        items = random_arrivals(m.cfg.vocab_size, 10, seed)
        _, eng = _replay(m, params,
                         _cfg(max_new_tokens=4, usable_pages=17), items)
        total += eng.stats()["preemptions"]
    assert total >= 1


def test_soak_hypothesis_random_traffic(model_f32):
    """Property: ANY random arrival trace against the tiny pool drains
    without deadlock, keeps allocator invariants after every tick, and
    bit-matches the large-pool oracle.  Derandomized so CI runs a fixed
    example set."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    m, params = model_f32

    @settings(max_examples=6, deadline=None, derandomize=True)
    @given(seed=st.integers(0, 19))
    def check(seed):
        _soak_case(m, params, seed)

    check()
