"""Every internal link in README.md / docs/*.md must resolve.

This is the docs check CI runs (.github/workflows/ci.yml): file targets
must exist, and #anchors (same-file or cross-file) must match a heading.
"""
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
DOCS = sorted([ROOT / "README.md", *(ROOT / "docs").glob("*.md")])
LINK = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")


def _slug(heading: str) -> str:
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return re.sub(r"\s+", "-", s)


def _anchors(md: Path):
    return {_slug(m.group(1))
            for m in re.finditer(r"^#+\s+(.+)$", md.read_text(), re.M)}


@pytest.mark.parametrize("md", DOCS, ids=lambda p: str(p.relative_to(ROOT)))
def test_internal_links_resolve(md):
    text = md.read_text()
    for target in LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path, _, anchor = target.partition("#")
        dest = md if not path else (md.parent / path).resolve()
        assert dest.exists(), f"{md.name}: broken link -> {target}"
        if anchor and dest.suffix == ".md":
            assert _slug(anchor) in _anchors(dest), \
                f"{md.name}: missing anchor -> {target}"


def test_docs_tree_complete():
    for name in ("architecture.md", "kernels.md", "serving.md"):
        assert (ROOT / "docs" / name).exists(), f"docs/{name} missing"
