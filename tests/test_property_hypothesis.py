"""Hypothesis property tests on system invariants.

Skipped (not errored) when hypothesis is absent so `pytest -x` still runs
the rest of the suite; `pip install -r requirements-dev.txt` enables them.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (balance_chain, balanced_ii, choose_block_config,
                        is_bubble_free, threed_flash_schedule)
from repro.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.lists(st.floats(0.1, 100.0), min_size=1, max_size=24),
       st.integers(1, 6))
def test_balance_chain_partitions_everything(costs, k):
    groups, mx = balance_chain(costs, k)
    flat = [i for g in groups for i in g]
    assert flat == list(range(len(costs)))       # contiguous, complete
    # max group cost equals reported II
    gm = max((sum(costs[i] for i in g) for g in groups if g), default=0.0)
    assert abs(gm - mx) < 1e-9
    # balancing never exceeds the single-tier cost
    assert mx <= sum(costs) + 1e-9


@given(st.lists(st.floats(0.1, 100.0), min_size=2, max_size=16))
def test_more_tiers_never_hurts(costs):
    assert balanced_ii(costs, 4) <= balanced_ii(costs, 2) + 1e-9
    assert balanced_ii(costs, 2) <= balanced_ii(costs, 1) + 1e-9


@given(st.integers(5, 10), st.integers(7, 12))
def test_block_config_fits_and_aligned(log_seq, log_d):
    seq = 2 ** log_seq
    d = min(2 ** (log_d - 4), 256)
    bc = choose_block_config(d, seq)
    assert bc.block_q % 128 == 0 and bc.block_kv % 128 == 0
    assert bc.vmem_bytes <= 32 * 1024 * 1024


def test_paper_schedule_is_bubble_free():
    stages = threed_flash_schedule()
    assert is_bubble_free(stages, 128)


@given(st.integers(1, 4), st.integers(2, 5))
@settings(max_examples=10)
def test_causal_prefix_invariance(b, lh):
    """Causal attention: outputs at position t do not depend on tokens > t."""
    key = jax.random.PRNGKey(b * 7 + lh)
    B, S, H, D = 1, 32, 2, 16
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    o_full = ref.flash_attention(q, k, v, causal=True, block_kv=16)
    t = 10
    o_pre = ref.flash_attention(q[:, :t], k[:, :t], v[:, :t], causal=True,
                                block_kv=16)
    np.testing.assert_allclose(np.asarray(o_full[:, :t]),
                               np.asarray(o_pre), atol=2e-5)


@given(st.integers(0, 5))
@settings(max_examples=6)
def test_gqa_equals_repeated_kv(seed):
    key = jax.random.PRNGKey(seed)
    B, S, Hq, Hkv, D = 1, 24, 4, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    o_gqa = ref.flash_attention(q, k, v, causal=True, block_kv=8)
    k_rep = jnp.repeat(k, Hq // Hkv, axis=2)
    v_rep = jnp.repeat(v, Hq // Hkv, axis=2)
    o_mha = ref.flash_attention(q, k_rep, v_rep, causal=True, block_kv=8)
    np.testing.assert_allclose(np.asarray(o_gqa), np.asarray(o_mha),
                               atol=2e-5)


@given(st.integers(1, 8), st.integers(1, 4))
@settings(max_examples=10)
def test_partial_softmax_combine(n_parts, seed):
    """Sharded partial-softmax merge == monolithic softmax attention."""
    key = jax.random.PRNGKey(seed)
    B, H, G, D, S = 1, 2, 1, 8, 8 * n_parts
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H * G, D))
    kc = jax.random.normal(ks[1], (B, S, H, D))
    vc = jax.random.normal(ks[2], (B, S, H, D))
    from repro.kernels.ops import _decode_partials
    parts = []
    for i in range(n_parts):
        sl = slice(i * 8, (i + 1) * 8)
        parts.append(_decode_partials(q, kc[:, sl], vc[:, sl], 8))
    m = jnp.stack([p[0] for p in parts])
    l = jnp.stack([p[1] for p in parts])
    o = jnp.stack([p[2] for p in parts])
    mc, lc, oc = ref.combine_partial_softmax(m, l, o)
    o_combined = oc / jnp.maximum(lc, 1e-20)[..., None]
    o_ref = ref.flash_decode(q, kc, vc, S)
    np.testing.assert_allclose(np.asarray(o_combined.reshape(o_ref.shape)),
                               np.asarray(o_ref), atol=2e-5)
