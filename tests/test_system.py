"""End-to-end behaviour tests for the paper's system."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ServeConfig, TrainConfig
from repro.models import build_model
from repro.serve.engine import ServeEngine
from repro.train.trainer import Trainer


def test_train_loss_decreases(tmp_path):
    """~40 steps on learnable synthetic data: loss must fall measurably."""
    cfg = get_smoke_config("granite-3-2b")
    tcfg = TrainConfig(global_batch=8, seq_len=64, total_steps=40,
                       warmup_steps=4, learning_rate=2e-2,
                       checkpoint_every=50, checkpoint_dir=str(tmp_path),
                       log_every=5)
    out = Trainer(cfg, tcfg).run()
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0] - 0.3, losses


def test_train_with_remat_matches_no_remat(tmp_path):
    cfg = get_smoke_config("olmo-1b")
    from repro.train.train_step import init_train_state, make_train_step
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
    outs = []
    for remat in ("none", "full"):
        tcfg = TrainConfig(global_batch=2, seq_len=32, remat=remat,
                           checkpoint_dir=str(tmp_path))
        state = init_train_state(m, key, tcfg)
        _, metrics = make_train_step(m, tcfg)(state, batch)
        outs.append(float(metrics["loss"]))
    assert abs(outs[0] - outs[1]) < 1e-2


def test_generate_end_to_end(rng):
    cfg = get_smoke_config("gemma3-4b")
    m = build_model(cfg)
    params = m.init(rng)
    eng = ServeEngine(m, params, ServeConfig(max_batch=2, max_seq=96,
                                             max_new_tokens=6))
    eng.submit([1, 2, 3, 4])
    done = eng.run_until_done()
    assert len(done) == 1 and len(done[0].out_tokens) == 6
    assert all(0 <= t < cfg.vocab_size for t in done[0].out_tokens)


def test_straggler_watchdog_counts():
    cfg = get_smoke_config("olmo-1b")
    tcfg = TrainConfig(global_batch=2, seq_len=16, total_steps=3,
                       checkpoint_every=100, checkpoint_dir="/tmp/_wd")
    tr = Trainer(cfg, tcfg)
    for i in range(10):
        tr._watchdog(i, 0.1)
    tr._watchdog(10, 10.0)
    assert tr.straggler_events == 1
