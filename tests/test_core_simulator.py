import pytest

from repro.core import (DESIGNS, EnergyTable, get_spec, simulate_attention,
                        simulate_model)
from repro.core.workloads import opt_6_7b, qwen_7b


@pytest.mark.parametrize("design", list(DESIGNS))
@pytest.mark.parametrize("seq", [1024, 4096])
def test_simulator_runs_all_designs(design, seq):
    r = simulate_attention(design, opt_6_7b(seq).attn)
    assert r.cycles > 0 and r.total_energy > 0
    assert 0.0 < r.utilization <= 1.0
    a = r.activity
    assert a.macs > 0 and a.sram_bytes > 0 and a.dram_bytes > 0


@pytest.mark.parametrize("design", list(DESIGNS))
def test_cycles_superlinear_in_seq(design):
    """Attention is quadratic: 4x seq -> >4x cycles."""
    r1 = simulate_attention(design, opt_6_7b(1024).attn)
    r2 = simulate_attention(design, opt_6_7b(4096).attn)
    assert r2.cycles > 4.0 * r1.cycles


def test_ours_beats_all_baselines_everywhere():
    for mk in (opt_6_7b, qwen_7b):
        for seq in (1024, 4096, 16384, 65536):
            ours = simulate_attention("3D-Flow", mk(seq).attn)
            for d in DESIGNS:
                if d == "3D-Flow":
                    continue
                base = simulate_attention(d, mk(seq).attn)
                assert ours.cycles <= base.cycles, (d, seq)
                assert ours.total_energy <= base.total_energy, (d, seq)


def test_gqa_reduces_offchip_traffic():
    """Qwen (GQA) moves less K/V off-chip per q-head than OPT (MHA)."""
    mha = simulate_attention("3D-Flow", opt_6_7b(4096).attn)
    gqa = simulate_attention("3D-Flow", qwen_7b(4096).attn)
    mha_per = mha.activity.dram_bytes / mha.activity.macs
    gqa_per = gqa.activity.dram_bytes / gqa.activity.macs
    assert gqa_per < mha_per


def test_3dflow_has_no_intermediate_sram_traffic():
    """SRAM bytes for ours = operand staging only; 3D-Base adds round-trips."""
    ours = simulate_attention("3D-Flow", opt_6_7b(4096).attn).activity
    base = simulate_attention("3D-Base", opt_6_7b(4096).attn).activity
    assert base.sram_bytes > 1.5 * ours.sram_bytes
    assert ours.tsv_bytes > 0 and base.noc_bytes == 0


def test_model_level_includes_gemm():
    attn_only = simulate_attention("3D-Flow", opt_6_7b(4096).attn)
    full = simulate_model("3D-Flow", opt_6_7b(4096))
    assert full.activity.macs > 2.0 * attn_only.activity.macs
    assert full.total_energy > attn_only.total_energy


def test_energy_table_ratios_documented():
    t = EnergyTable.default16nm()
    assert t.e_tsv_byte == 1.35e-12          # fixed at the paper's number
    assert t.e_sram_byte > t.e_reg_byte
    assert t.e_dram_byte > t.e_sram_byte


def test_thermal_feasibility_section_iii_c():
    """Paper Section III-C: 3.3 W/tier, 13.1 W stack, small internal rise,
    junction temperature within limits.  (Two errata in the paper's own
    arithmetic are documented in core/thermal.py; our faithful evaluation
    yields Tj ~ 61 C < the paper's 83 C < the 105 C limit.)"""
    from repro.core.thermal import report
    r = report()
    assert abs(r["tier_power_w"] - 3.3) < 0.1
    assert abs(r["total_power_w"] - 13.1) < 0.2
    assert 1.5 <= r["internal_rise_c"] <= 4.0        # paper: ~2.8 C
    assert r["junction_temp_c"] < 83.0               # paper's own bound
    assert r["feasible_105c"]


def test_end_to_end_energy_savings():
    """Paper: 'reducing overall energy by 32.7% to 64.2% on average compared
    to baselines' (full inference incl. projection/FFN GEMMs).

    Partially reproduced: our end-to-end model streams the full parameter
    set from DRAM every forward at batch=1, which dilutes the short-sequence
    savings more than the paper's accounting (its absolute constants are
    unpublished).  We assert (a) positive mean savings vs every baseline,
    and (b) long-sequence (N>=16K) savings inside/above the published band,
    where attention dominates as the paper argues."""
    import statistics as st
    from repro.core import DESIGNS, simulate_model
    from repro.core.workloads import opt_6_7b, qwen_7b
    for d in DESIGNS:
        if d == "3D-Flow":
            continue
        all_vals, long_vals = [], []
        for mk in (opt_6_7b, qwen_7b):
            for s in (1024, 4096, 16384, 65536):
                v = 1.0 - (simulate_model("3D-Flow", mk(s)).total_energy
                           / simulate_model(d, mk(s)).total_energy)
                all_vals.append(v)
                if s >= 16384:
                    long_vals.append(v)
        assert st.mean(all_vals) > 0.10, (d, st.mean(all_vals))
        assert st.mean(long_vals) >= 0.25, (d, st.mean(long_vals))
