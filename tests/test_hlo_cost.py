"""Unit tests for the corrected HLO static analyzer."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze, parse_hlo


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_dot_flops_exact():
    r = analyze(_hlo(lambda a, b: a @ b, jnp.ones((64, 128)),
                     jnp.ones((128, 256))))
    assert r["flops"] == 2 * 64 * 128 * 256


def test_scan_multiplies_by_trip_count():
    def scanned(x, p):
        return jax.lax.scan(lambda x, pl: (x @ pl, None), x, p)[0]
    r = analyze(_hlo(scanned, jnp.ones((64, 64)), jnp.ones((8, 64, 64))))
    assert r["flops"] == 8 * 2 * 64 ** 3
    assert 8 in r["while_trip_counts"]


def test_nested_scan():
    def inner(x, p):
        return jax.lax.scan(lambda x, pl: (x @ pl, None), x, p)[0]
    def outer(x, p):
        return jax.lax.scan(lambda x, ps: (inner(x, ps), None), x, p)[0]
    r = analyze(_hlo(outer, jnp.ones((32, 32)), jnp.ones((3, 4, 32, 32))))
    assert r["flops"] == 3 * 4 * 2 * 32 ** 3


def test_conditional_branches_averaged():
    def f(x, flag):
        return jax.lax.cond(flag > 0, lambda: x @ x, lambda: x * 2.0)
    r = analyze(_hlo(f, jnp.ones((64, 64)), jnp.array(1)))
    assert r["flops"] == pytest.approx(0.5 * 2 * 64 ** 3)


def test_grad_counts_fwd_and_bwd_dots():
    def loss(a, b):
        return jnp.sum((a @ b) ** 2)
    r = analyze(_hlo(jax.grad(loss, argnums=(0, 1)),
                     jnp.ones((32, 64)), jnp.ones((64, 16))))
    # fwd dot + two transpose dots = 3x the base dot flops
    assert r["flops"] == 3 * 2 * 32 * 64 * 16


def test_parse_hlo_finds_entry():
    comps = parse_hlo(_hlo(lambda x: x + 1.0, jnp.ones((4,))))
    assert "__entry__" in comps
