"""Dry-run machinery at smoke scale on the host's real device(s)."""
import jax
import jax.numpy as jnp
import pytest

from repro.compat import use_mesh
from repro.configs import get_smoke_config
from repro.configs.base import ShapeSpec, TrainConfig
from repro.launch.dryrun import parse_collectives
from repro.launch.mesh import make_debug_mesh
from repro.launch.specs import prefill_cell, serve_cell, train_cell


def test_collective_parser():
    hlo = """
  %ag = bf16[16,128]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[4,4]{1,0} all-reduce(%y), to_apply=%sum
  %rs = f32[8]{0} reduce-scatter(%z), dimensions={0}
  %nothing = f32[2,2]{1,0} add(%a, %b)
"""
    c = parse_collectives(hlo)
    assert c["all-gather"]["count"] == 1
    assert c["all-gather"]["bytes"] == 16 * 128 * 2
    assert c["all-reduce"]["bytes"] == 64
    assert c["reduce-scatter"]["bytes"] == 32
    assert c["total_bytes"] == 16 * 128 * 2 + 64 + 32


@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_cells_lower_on_debug_mesh(kind):
    cfg = get_smoke_config("granite-3-2b")
    mesh = make_debug_mesh(1, 1)
    shape = ShapeSpec("t", 32, 2, kind)
    with use_mesh(mesh):
        if kind == "train":
            tcfg = TrainConfig(global_batch=2, seq_len=32, remat="full")
            step, args, shardings = train_cell(cfg, shape, mesh, tcfg)
        elif kind == "prefill":
            step, args, shardings = prefill_cell(cfg, shape, mesh)
        else:
            step, args, shardings = serve_cell(cfg, shape, mesh)
        compiled = jax.jit(step, in_shardings=shardings).lower(*args).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        assert float(cost.get("flops", 0)) > 0
