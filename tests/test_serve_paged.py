"""Paged KV-cache serving: kernel parity, allocator churn, backpressure."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ServeConfig
from repro.kernels import ops
from repro.models import build_model
from repro.serve import OutOfPages, PageAllocator, ServeEngine
from repro.serve.paged_cache import (dense_kv_bytes, paged_kv_bytes,
                                     pages_needed)
from traffic import mixed_prompts, serve_all


def _paged_from_dense(kc, vc, page_size, seed=0):
    """Scatter a dense (B, S, Hkv, D) cache into a SHUFFLED page pool and
    the matching block table (page 0 kept as the null page)."""
    B, S, Hkv, D = kc.shape
    n_max = S // page_size
    n_pool = B * n_max + 1
    rng = np.random.default_rng(seed)
    perm = rng.permutation(np.arange(1, n_pool))
    bt = perm.reshape(B, n_max).astype(np.int32)
    k_pages = np.zeros((n_pool, page_size, Hkv, D), np.float32)
    v_pages = np.zeros((n_pool, page_size, Hkv, D), np.float32)
    for b in range(B):
        for j in range(n_max):
            k_pages[bt[b, j]] = np.asarray(kc[b, j*page_size:(j+1)*page_size])
            v_pages[bt[b, j]] = np.asarray(vc[b, j*page_size:(j+1)*page_size])
    return jnp.asarray(k_pages), jnp.asarray(v_pages), jnp.asarray(bt)


# ===========================================================================
# kernel parity: paged (ref + pallas interpret) vs dense flash decode
# ===========================================================================

@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("window", [0, 10])
def test_paged_decode_matches_dense(impl, window, rng):
    B, S, Hq, Hkv, D, ps = 3, 64, 4, 2, 16, 8
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, D))
    kc = jax.random.normal(ks[1], (B, S, Hkv, D))
    vc = jax.random.normal(ks[2], (B, S, Hkv, D))
    lens = jnp.array([S - 5, S // 2, 1])
    k_pages, v_pages, bt = _paged_from_dense(kc, vc, ps)

    o_dense = ops.flash_decode(q, kc, vc, lens, window=window, impl="ref")
    o_paged = ops.paged_flash_decode(q, k_pages, v_pages, bt, lens,
                                     window=window, impl=impl)
    assert float(jnp.abs(o_paged - o_dense).max()) <= 1e-5


def test_paged_decode_gqa_single_head(rng):
    """MHA (G=1) and degenerate one-page sequences still match."""
    B, S, H, D, ps = 2, 32, 2, 8, 32          # one page per sequence
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, 1, H, D))
    kc = jax.random.normal(ks[1], (B, S, H, D))
    vc = jax.random.normal(ks[2], (B, S, H, D))
    lens = jnp.array([S, 3])
    k_pages, v_pages, bt = _paged_from_dense(kc, vc, ps)
    o_dense = ops.flash_decode(q, kc, vc, lens, impl="ref")
    o_paged = ops.paged_flash_decode(q, k_pages, v_pages, bt, lens,
                                     impl="pallas")
    assert float(jnp.abs(o_paged - o_dense).max()) <= 1e-5


# ===========================================================================
# engine parity: same trace, dense vs paged, identical greedy tokens
# ===========================================================================

@pytest.mark.parametrize("arch", ["granite-3-2b", "gemma3-4b"])
def test_engine_paged_matches_dense(arch, rng):
    cfg = get_smoke_config(arch).replace(dtype="float32")
    m = build_model(cfg)
    params = m.init(rng)
    prompts = mixed_prompts(cfg.vocab_size, lens=(3, 2, 4, 18, 2))

    def run(scfg):
        return serve_all(m, params, scfg, prompts, check=True)

    dense_out, _ = run(ServeConfig(max_batch=2, max_seq=64, max_new_tokens=5))
    paged_out, eng = run(ServeConfig(max_batch=2, max_seq=64,
                                     max_new_tokens=5, paged=True,
                                     page_size=8, num_pages=11))
    assert dense_out == paged_out
    assert eng.allocator.used_pages == 0          # everything freed
    assert eng.peak_pages > 0
    assert eng.kv_cache_bytes() < dense_kv_bytes(cfg, ServeConfig(
        max_batch=2, max_seq=64))


# ===========================================================================
# allocator: churn, free-list accounting, backpressure
# ===========================================================================

def test_allocator_churn_no_leak_no_double_alloc():
    rng = np.random.default_rng(0)
    alloc = PageAllocator(num_pages=33, page_size=8, max_batch=4,
                          max_seq=256)
    total = alloc.free_pages
    live = {}
    for step in range(200):
        slot = int(rng.integers(0, 4))
        if slot in live:
            alloc.free_slot(slot)
            del live[slot]
        else:
            n = int(rng.integers(1, 6))
            if alloc.can_alloc(n):
                pages = alloc.alloc(slot, n)
                assert 0 not in pages                 # null page never leaves
                live[slot] = pages
        # no page owned twice
        owned = [p for ps in live.values() for p in ps]
        assert len(owned) == len(set(owned))
        assert alloc.free_pages + len(owned) == total
        # block table mirrors ownership
        for s, ps in live.items():
            assert list(alloc.table[s, :len(ps)]) == ps
    for slot in list(live):
        alloc.free_slot(slot)
    assert alloc.free_pages == total
    assert (alloc.table == 0).all()


def test_allocator_out_of_pages_raises():
    alloc = PageAllocator(num_pages=5, page_size=8, max_batch=2, max_seq=256)
    alloc.alloc(0, 3)
    with pytest.raises(OutOfPages):
        alloc.alloc(1, 2)
    alloc.free_slot(0)
    assert alloc.can_alloc(4)


def test_engine_backpressure_out_of_pages(rng):
    """A pool too small for two concurrent requests serves them anyway -
    sequentially, via admission backpressure - and never errors."""
    cfg = get_smoke_config("granite-3-2b")
    m = build_model(cfg)
    params = m.init(rng)
    # each request: 8-token prompt + 4 new = 2 pages of 8; pool of 3 usable
    # pages fits ONE request at a time (2 pages) but never two
    prompts = mixed_prompts(cfg.vocab_size, lens=(8, 8, 8))
    out, eng = serve_all(m, params,
                         ServeConfig(max_batch=2, max_seq=64,
                                     max_new_tokens=4, paged=True,
                                     page_size=8, num_pages=4),
                         prompts, check=True)
    assert len(out) == 3
    assert all(len(toks) == 4 for toks in out.values())
    assert eng.peak_pages <= 3
    assert eng.allocator.used_pages == 0


def test_engine_validates_config_and_requests(rng):
    """max_seq must be a page multiple; requests must fit max_seq; the
    degenerate submissions fail at submit() with a clear error, never deep
    inside prefill or the allocator."""
    cfg = get_smoke_config("granite-3-2b")
    m = build_model(cfg)
    params = m.init(rng)
    with pytest.raises(ValueError, match="multiple of"):
        ServeEngine(m, params, ServeConfig(max_seq=60, page_size=8,
                                           paged=True))
    eng = ServeEngine(m, params,
                      ServeConfig(max_batch=2, max_seq=32, max_new_tokens=4))
    with pytest.raises(ValueError, match="does not fit"):
        eng.submit(list(range(1, 40)))
    with pytest.raises(ValueError, match="empty"):
        eng.submit([])
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit([1, 2, 3], max_new_tokens=-1)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit([1, 2, 3], max_new_tokens=0)   # 0 is NOT "use default"
    assert not eng.queue                  # nothing bad got enqueued


def test_engine_rejects_unsatisfiable_reservation(rng):
    """A reservation larger than the whole pool can never be backpressured
    into fitting - it must fail fast AT SUBMIT TIME, not queue forever or
    die inside the allocator."""
    cfg = get_smoke_config("granite-3-2b")
    m = build_model(cfg)
    params = m.init(rng)
    eng = ServeEngine(m, params,
                      ServeConfig(max_batch=2, max_seq=64, max_new_tokens=8,
                                  paged=True, page_size=8, num_pages=4))
    with pytest.raises(ValueError, match="pages"):
        eng.submit(list(range(1, 25)))    # needs 4 pages; pool grants 3
    assert not eng.queue


# ===========================================================================
# decode-path logit softcap: decode must match prefill (ROADMAP item)
# ===========================================================================

@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_decode_softcap_kernel_parity(impl, rng):
    """flash_decode / paged_flash_decode with softcap vs a naive oracle."""
    B, S, Hq, Hkv, D, ps, cap = 2, 32, 4, 2, 16, 8, 7.5
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, D))
    kc = jax.random.normal(ks[1], (B, S, Hkv, D))
    vc = jax.random.normal(ks[2], (B, S, Hkv, D))
    lens = jnp.array([S - 3, 5])

    G = Hq // Hkv
    qf = (q.astype(jnp.float32) / jnp.sqrt(D)).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, kc.astype(jnp.float32))
    s = cap * jnp.tanh(s / cap)
    mask = jnp.arange(S)[None, :] < lens[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, -1)
    want = jnp.einsum("bhgk,bkhd->bhgd", p, vc.astype(jnp.float32)
                      ).reshape(B, 1, Hq, D)

    got = ops.flash_decode(q, kc, vc, lens, logit_softcap=cap, impl=impl)
    assert float(jnp.abs(got - want).max()) <= 1e-5
    k_pages, v_pages, bt = _paged_from_dense(kc, vc, ps)
    got_p = ops.paged_flash_decode(q, k_pages, v_pages, bt, lens,
                                   logit_softcap=cap, impl=impl)
    assert float(jnp.abs(got_p - want).max()) <= 1e-5
    # softcap must actually change the result (guard against silent no-op)
    plain = ops.flash_decode(q, kc, vc, lens, impl=impl)
    assert float(jnp.abs(got - plain).max()) > 1e-3


@pytest.mark.parametrize("paged", [False, True])
def test_decode_softcap_matches_prefill(paged, rng):
    """With attn_logit_softcap > 0, decoding token t must produce the same
    logits prefill produced at position t (dense AND paged decode path)."""
    cfg = get_smoke_config("granite-3-2b").replace(dtype="float32",
                                                   attn_logit_softcap=12.0)
    m = build_model(cfg)
    params = m.init(rng)
    toks = jnp.array([[5, 7, 11, 13, 17, 19, 23, 2]])
    logits_full, _ = m.forward(params, {"tokens": toks})
    if paged:
        cache = m.init_cache(1, 16, page_size=4, num_pages=9)
        cache["block_table"] = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
        page_ids = jnp.asarray([1, 2], jnp.int32)   # 7 tokens pad to 8
        batch = {"tokens": jnp.pad(toks[:, :7], ((0, 0), (0, 1))),
                 "true_lens": jnp.asarray([7])}
        _, cache, lens = m.prefill_paged(params, batch, cache, page_ids)
    else:
        cache = m.init_cache(1, 16)
        _, cache, lens = m.prefill(params, {"tokens": toks[:, :7]}, cache)
    logits_dec, _ = m.decode_step(params, toks[:, 7:8], lens, cache)
    err = float(jnp.abs(logits_dec[:, 0] - logits_full[:, 7]).max())
    assert err <= 1e-4, err


def test_capacity_math_mixed_lengths():
    """The documented sizing: a paged pool covering a mixed trace is
    strictly smaller than the dense cache (the acceptance shape: 128 / 1k /
    4k prompts at max_seq = 4k)."""
    cfg = get_smoke_config("granite-3-2b")
    scfg = ServeConfig(max_batch=4, max_seq=4096, max_new_tokens=32,
                       paged=True, page_size=64)
    per_req = pages_needed(3968 + 32, 64)
    pool = scfg.max_batch * per_req // 2 + 1
    assert paged_kv_bytes(cfg, scfg, pool) < dense_kv_bytes(cfg, scfg)
    # degenerate sizing (0 = dense-equivalent) is never SMALLER than dense
    assert paged_kv_bytes(cfg, scfg, 0) >= dense_kv_bytes(cfg, scfg)
