import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         compress_grads, cosine_schedule, ef_init, global_norm)


def test_adamw_first_step_is_lr_sized():
    p = {"w": jnp.ones((4,), jnp.float32)}
    g = {"w": jnp.full((4,), 0.5)}
    st = adamw_init(p)
    new_p, st = adamw_update(g, st, p, lr=1e-2, weight_decay=0.0)
    # first adam step ~= lr * sign(g)
    np.testing.assert_allclose(np.asarray(p["w"] - new_p["w"]),
                               np.full(4, 1e-2), rtol=1e-3)


def test_adamw_converges_quadratic():
    p = {"w": jnp.array([5.0, -3.0])}
    st = adamw_init(p)
    for _ in range(300):
        g = {"w": 2.0 * p["w"]}
        p, st = adamw_update(g, st, p, lr=5e-2, weight_decay=0.0)
    assert float(jnp.abs(p["w"]).max()) < 0.15


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0), "b": jnp.full((4,), -10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0


def test_cosine_schedule_shape():
    lr0 = cosine_schedule(jnp.array(0), base_lr=1.0, warmup=10, total=100)
    lr_w = cosine_schedule(jnp.array(10), base_lr=1.0, warmup=10, total=100)
    lr_end = cosine_schedule(jnp.array(100), base_lr=1.0, warmup=10, total=100)
    assert float(lr0) == 0.0
    assert abs(float(lr_w) - 1.0) < 1e-5
    assert float(lr_end) <= 0.11


def test_compression_error_feedback_unbiased():
    """Sum of dequantized grads + final residual == sum of true grads."""
    key = jax.random.PRNGKey(0)
    g_true = {"w": jax.random.normal(key, (64,))}
    ef = ef_init(g_true)
    total_sent = jnp.zeros((64,))
    for i in range(20):
        sent, ef = compress_grads(g_true, ef)
        total_sent = total_sent + sent["w"]
    # accumulated transmitted signal tracks 20x the true gradient
    np.testing.assert_allclose(np.asarray(total_sent + ef["w"]),
                               np.asarray(20.0 * g_true["w"]), rtol=1e-3,
                               atol=1e-3)
