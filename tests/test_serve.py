import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.serve.engine import ServeEngine
from repro.serve.serve_step import make_serve_step, sample_token
from traffic import mixed_prompts, serve_all


@pytest.mark.parametrize("arch", ["granite-3-2b", "rwkv6-1.6b"])
def test_engine_continuous_batching(arch, rng):
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(rng)
    # 3 mixed-length requests through 2 slots: the third waits for a slot
    prompts = mixed_prompts(cfg.vocab_size, lens=(3, 2, 4))
    out, eng = serve_all(m, params,
                         ServeConfig(max_batch=2, max_seq=64,
                                     max_new_tokens=4),
                         prompts, check=True)
    assert len(out) == len(prompts)
    assert all(len(toks) == 4 for toks in out.values())


def test_greedy_decode_deterministic(rng):
    cfg = get_smoke_config("granite-3-2b")
    m = build_model(cfg)
    params = m.init(rng)
    step = jax.jit(make_serve_step(m))
    cache = m.init_cache(1, 32)
    lens = jnp.zeros((1,), jnp.int32)
    tok = jnp.array([[3]], jnp.int32)
    l1, _ = step(params, cache, tok, lens)
    l2, _ = step(params, cache, tok, lens)
    assert jnp.array_equal(sample_token(l1), sample_token(l2))


def test_sampled_token_in_vocab(rng):
    logits = jax.random.normal(rng, (2, 1, 11))
    t = sample_token(logits, temperature=1.0, key=rng)
    assert t.shape == (2, 1)
    assert int(t.min()) >= 0 and int(t.max()) < 11
