import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.serve.engine import ServeEngine
from repro.serve.serve_step import make_serve_step, sample_token


@pytest.mark.parametrize("arch", ["granite-3-2b", "rwkv6-1.6b"])
def test_engine_continuous_batching(arch, rng):
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(rng)
    eng = ServeEngine(m, params, ServeConfig(max_batch=2, max_seq=64,
                                             max_new_tokens=4))
    uids = [eng.submit([1, 2, 3]), eng.submit([4, 5]),
            eng.submit([6, 7, 8, 9])]          # 3 requests, 2 slots
    done = eng.run_until_done()
    assert sorted(r.uid for r in done) == sorted(uids)
    assert all(len(r.out_tokens) == 4 for r in done)


def test_greedy_decode_deterministic(rng):
    cfg = get_smoke_config("granite-3-2b")
    m = build_model(cfg)
    params = m.init(rng)
    step = jax.jit(make_serve_step(m))
    cache = m.init_cache(1, 32)
    lens = jnp.zeros((1,), jnp.int32)
    tok = jnp.array([[3]], jnp.int32)
    l1, _ = step(params, cache, tok, lens)
    l2, _ = step(params, cache, tok, lens)
    assert jnp.array_equal(sample_token(l1), sample_token(l2))


def test_sampled_token_in_vocab(rng):
    logits = jax.random.normal(rng, (2, 1, 11))
    t = sample_token(logits, temperature=1.0, key=rng)
    assert t.shape == (2, 1)
    assert int(t.min()) >= 0 and int(t.max()) < 11
