"""Prefix cache: radix tree, refcount/COW correctness, eviction churn,
allocator invariants, and greedy parity cache-on vs cache-off."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.serve import PageAllocator, RadixPrefixCache, ServeEngine


def _tree(num_pages=33, ps=4, max_batch=4, max_seq=64):
    alloc = PageAllocator(num_pages, ps, max_batch, max_seq)
    return alloc, RadixPrefixCache(alloc, ps)


# ===========================================================================
# radix tree: match / publish / split / dedupe
# ===========================================================================

def test_radix_match_publish_and_split():
    alloc, tree = _tree(ps=2)
    toks = [1, 2, 3, 4, 5, 6]
    pages = alloc.alloc(0, 3)
    tree.release(0, toks)                       # publish all 3 full pages
    assert tree.match(toks) == pages
    assert tree.match([1, 2, 3, 4, 9, 9]) == pages[:2]   # mid-edge partial
    assert tree.match([9] * 6) == []
    assert tree.match([1]) == []                # shorter than one page
    # a divergent prompt splits the edge; shared pages are deduped
    toks2 = [1, 2, 3, 4, 7, 8]
    pages2 = alloc.alloc(1, 3)
    free_before = alloc.free_pages
    tree.release(1, toks2)
    assert alloc.free_pages == free_before + 2  # 2 duplicate pages freed
    m = tree.match(toks2)
    assert m[:2] == pages[:2] and m[2] == pages2[2]
    assert tree.match(toks) == pages            # original path intact
    assert tree.cached_pages == 4
    tree.check_invariants()


def test_radix_publish_identical_prompt_dedupes():
    """Two requests that computed the same prefix independently (both in
    flight before either finished) publish once; the loser's pages free."""
    alloc, tree = _tree(ps=2)
    toks = [5, 6, 7, 8]
    pa = alloc.alloc(0, 2)
    pb = alloc.alloc(1, 2)
    tree.release(0, toks)
    tree.release(1, toks)
    assert tree.match(toks) == pa
    assert set(pb).issubset(set(alloc._free))   # duplicates returned
    assert tree.cached_pages == 2
    tree.check_invariants()


def test_radix_partial_tail_page_not_published():
    """Only FULL prompt pages enter the tree; the partial tail page (which
    decode keeps writing into) is freed on completion."""
    alloc, tree = _tree(ps=4)
    pages = alloc.alloc(0, 3)                   # 9 prompt + gen reservation
    tree.release(0, list(range(9)))             # 9 tokens -> 2 full pages
    assert tree.cached_pages == 2
    assert tree.match(list(range(9))) == pages[:2]
    assert alloc.refcount(pages[2]) == 0
    tree.check_invariants()


# ===========================================================================
# refcounts + copy-on-write
# ===========================================================================

def test_refcount_attach_release_interleaved_divergent():
    """Two live requests share cached prefix pages (refcount 3: tree + two
    slots); divergent tails stay private; releases unwind cleanly."""
    alloc, tree = _tree(ps=2)
    base = [1, 2, 3, 4]
    seed = alloc.alloc(0, 2)
    tree.release(0, base)                       # tree now owns the prefix
    shared = tree.match(base + [7, 8])
    assert shared == seed
    alloc.attach(1, shared)
    alloc.alloc(1, 2)                           # slot 1 tail
    alloc.attach(2, tree.match(base + [9, 9]))
    alloc.alloc(2, 2)                           # slot 2 divergent tail
    for p in shared:
        assert alloc.refcount(p) == 3           # tree + slot 1 + slot 2
    tree.check_invariants()
    tree.release(1, base + [7, 8])
    for p in shared:
        assert alloc.refcount(p) == 2
    tree.release(2, base + [9, 9])
    for p in shared:
        assert alloc.refcount(p) == 1           # only the tree
    # both 3-page prompts are now fully cached; the two tails both hang
    # off the shared prefix
    assert tree.match(base + [7, 8]) != tree.match(base + [9, 9])
    assert tree.match(base + [7, 8])[:2] == shared
    tree.check_invariants()


def test_cow_bookkeeping():
    """allocator.cow swaps in a private page and drops the shared ref;
    no page is ever both free and referenced along the way."""
    alloc, tree = _tree(ps=2)
    pages = alloc.alloc(0, 2)
    tree.release(0, [1, 2, 3, 4])
    shared = tree.match([1, 2, 3, 4])
    alloc.attach(1, shared)
    old, new = alloc.cow(1, 1)
    assert old == shared[1] and new not in shared
    assert alloc.refcount(old) == 1             # tree keeps its copy
    assert alloc.refcount(new) == 1             # slot's private copy
    assert alloc.table[1, 1] == new
    tree.check_invariants()
    alloc.free_slot(1)
    assert alloc.refcount(new) == 0
    tree.check_invariants()


def test_engine_full_cover_prompt_cows_and_matches(rng):
    """A prompt that is ENTIRELY cached recomputes only its last token,
    COWs the final shared page, and still produces cache-off tokens."""
    cfg = get_smoke_config("granite-3-2b").replace(dtype="float32")
    m = build_model(cfg)
    params = m.init(rng)
    base = list(range(1, 17))                   # exactly 2 pages of 8

    def run(prefix):
        eng = ServeEngine(m, params,
                          ServeConfig(max_batch=2, max_seq=64, paged=True,
                                      page_size=8, num_pages=33,
                                      prefix_cache=prefix))
        out = {}
        for wave in ([base], [base, base]):     # repeat => full cover twice
            for p in wave:
                eng.submit(p, max_new_tokens=5)
            for r in eng.run_until_done():
                out[r.uid] = r.out_tokens
        return out, eng

    out_off, _ = run(False)
    out_on, eng = run(True)
    assert out_off == out_on
    assert eng.cow_copies == 2
    assert eng.prefix_hit_tokens == 2 * 15      # all but the last token
    eng.prefix.check_invariants()


# ===========================================================================
# engine parity: greedy tokens identical with the cache on vs off
# ===========================================================================

@pytest.mark.parametrize("arch", ["granite-3-2b", "gemma3-4b"])
def test_engine_prefix_parity(arch, rng):
    """Shared prefixes, divergence inside and across pages, full-cover
    repeats, sub-page prompts: greedy outputs must be identical with
    prefix caching on and off (gemma3 adds sliding windows + QK norm)."""
    cfg = get_smoke_config(arch).replace(dtype="float32")
    m = build_model(cfg)
    params = m.init(rng)
    base = list(range(1, 17))
    waves = [[base + [30, 31, 32, 33, 34]],
             [base + [40, 41], base, [9, 9, 9, 9],
              base + [30, 31, 32, 33, 34]]]

    def run(prefix):
        eng = ServeEngine(m, params,
                          ServeConfig(max_batch=2, max_seq=64, paged=True,
                                      page_size=8, num_pages=33,
                                      prefix_cache=prefix))
        out = {}
        for wave in waves:
            for p in wave:
                eng.submit(p, max_new_tokens=5)
            for r in eng.run_until_done():
                out[r.uid] = r.out_tokens
        return out, eng

    out_off, eng_off = run(False)
    out_on, eng_on = run(True)
    assert out_off == out_on
    assert eng_on.prefix_hit_tokens > 0
    assert eng_on.prefill_tokens < eng_off.prefill_tokens
    assert eng_off.allocator.used_pages == 0
    # with the cache on, only tree pages remain in use at the end
    assert eng_on.allocator.used_pages == eng_on.prefix.cached_pages
    eng_on.prefix.check_invariants()


# ===========================================================================
# eviction: LRU churn under pool pressure never corrupts anything
# ===========================================================================

def test_evict_respects_refcounts_and_lru():
    alloc, tree = _tree(num_pages=33, ps=2)
    alloc.alloc(0, 2)
    tree.release(0, [1, 2, 3, 4])               # older
    alloc.alloc(0, 2)
    tree.release(0, [5, 6, 7, 8])               # newer
    pinned = tree.match([1, 2, 3, 4])           # bumps LRU, then pin
    alloc.attach(1, pinned)
    # evict everything evictable: only the (now older) second prompt goes
    freed = tree.evict(100)
    assert freed == 2
    assert tree.match([5, 6, 7, 8]) == []
    assert tree.match([1, 2, 3, 4]) == pinned   # pinned prefix survived
    tree.check_invariants()
    alloc.free_slot(1)
    assert tree.evict(100) == 2                 # unpinned -> evictable
    assert tree.cached_pages == 0
    assert alloc.used_pages == 0
    tree.check_invariants()


def test_evict_tail_first_keeps_valid_prefix():
    """Partial eviction trims pages off the END of a cached prompt; the
    surviving front must still match (prefix property)."""
    alloc, tree = _tree(ps=2)
    pages = alloc.alloc(0, 4)
    tree.release(0, [1, 2, 3, 4, 5, 6, 7, 8])
    assert tree.evict(1) == 1                   # trim one tail page
    assert tree.match([1, 2, 3, 4, 5, 6, 7, 8]) == pages[:3]
    tree.check_invariants()


def test_engine_eviction_churn_parity(rng):
    """A pool too small to cache every distinct prefix forces eviction
    between waves while requests are in flight; outputs still match the
    cache-off engine and invariants hold after every wave."""
    cfg = get_smoke_config("granite-3-2b").replace(dtype="float32")
    m = build_model(cfg)
    params = m.init(rng)
    rng_np = np.random.default_rng(0)
    prefixes = [list(rng_np.integers(1, 200, size=16)) for _ in range(4)]
    waves = []
    for i, pre in enumerate(prefixes):          # revisit each prefix twice
        waves.append([pre + [200 + i], pre + [210 + i, 211]])
    for i, pre in enumerate(prefixes):
        waves.append([pre + [220 + i]])

    def run(prefix, watermark=0.0):
        # 16 usable pages: two live requests need 2 * 3 = 6, so at most
        # ~3 cached prefixes (2 pages each) fit - churn guaranteed
        eng = ServeEngine(m, params,
                          ServeConfig(max_batch=2, max_seq=32, paged=True,
                                      page_size=8, num_pages=17,
                                      prefix_cache=prefix,
                                      prefix_evict_watermark=watermark))
        out = {}
        for wave in waves:
            for p in wave:
                eng.submit(p, max_new_tokens=4)
            for r in eng.run_until_done():
                out[r.uid] = r.out_tokens
            if eng.prefix is not None:
                eng.prefix.check_invariants()
        return out, eng

    out_off, _ = run(False)
    out_on, eng = run(True)
    assert out_off == out_on
    assert eng.prefix_hit_tokens > 0            # some reuse survived churn
    # watermark mode proactively keeps headroom free and still matches
    out_wm, eng_wm = run(True, watermark=0.5)
    assert out_wm == out_off
    assert eng_wm.allocator.free_pages >= 8     # 50% of 16 usable


# ===========================================================================
# peek: the read-only lookup the fleet router probes with
# ===========================================================================

def _all_nodes(tree):
    out, stack = [], [tree.root]
    while stack:
        nd = stack.pop()
        out.append(nd)
        stack.extend(nd.children.values())
    return out


def test_peek_returns_match_result_without_any_side_effect():
    """peek must return exactly what match would - and leave NOTHING
    behind: no LRU stamp bumps, no tree-clock advance, no refcount
    changes, no lookup/hit counters, no events.  The fleet router peeks
    every replica per submit; a probe that perturbed LRU order or
    hit-rate accounting on the N-1 losing replicas would skew both
    eviction and metrics."""
    alloc, tree = _tree(ps=2)
    alloc.alloc(0, 2)
    tree.release(0, [1, 2, 3, 4])
    alloc.alloc(0, 2)
    tree.release(0, [5, 6, 7, 8])
    events = []
    tree.event_cb = lambda name, **kw: events.append(name)
    want = tree.match([1, 2, 3, 4])     # bump: [5,6,7,8] is now the LRU
    events.clear()
    clock0 = tree._clock
    stamps0 = [(id(nd), nd.last_used) for nd in _all_nodes(tree)]
    refs0 = {p: alloc.refcount(p) for p in tree._pages}
    metrics0 = tree.metrics.snapshot()
    # peek agrees with match on hits, partial hits, and misses...
    assert tree.peek([1, 2, 3, 4]) == want
    assert tree.peek([5, 6, 7, 8]) == tree._walk([5, 6, 7, 8], touch=False)
    assert len(tree.peek([5, 6, 7, 8])) == 2
    assert tree.peek([5, 6, 9, 9]) == tree.peek([5, 6, 7, 8])[:1]
    assert tree.peek([9, 9, 9, 9]) == []
    assert tree.peek([1]) == []         # shorter than one page
    # ... and none of it left a trace
    assert tree._clock == clock0, "peek advanced the LRU clock"
    assert [(id(nd), nd.last_used) for nd in _all_nodes(tree)] == stamps0, \
        "peek reordered LRU stamps"
    assert {p: alloc.refcount(p) for p in tree._pages} == refs0, \
        "peek touched refcounts"
    assert tree.metrics.snapshot() == metrics0, \
        "peek recorded lookup/hit metrics"
    assert events == [], "peek emitted trace events"
    tree.check_invariants()


def test_peek_does_not_change_eviction_order():
    """Hammering peek at one cached prompt must not rescue it from LRU
    eviction: evict still takes the least-recently-MATCHED prompt, even
    if it was the most-recently-peeked one."""
    alloc, tree = _tree(ps=2)
    alloc.alloc(0, 2)
    tree.release(0, [1, 2, 3, 4])
    alloc.alloc(0, 2)
    tree.release(0, [5, 6, 7, 8])
    kept = tree.match([1, 2, 3, 4])     # [5,6,7,8] is now the LRU tail
    for _ in range(25):
        assert len(tree.peek([5, 6, 7, 8])) == 2
    assert tree.evict(2) == 2
    assert tree.match([5, 6, 7, 8]) == [], \
        "peeks rescued the LRU victim - peek is not side-effect-free"
    assert tree.match([1, 2, 3, 4]) == kept
    tree.check_invariants()


# ===========================================================================
# allocator guard rails
# ===========================================================================

def test_allocator_refcount_guard_rails():
    alloc = PageAllocator(9, 4, 2, 32)
    pages = alloc.alloc(0, 2)
    with pytest.raises(ValueError):
        alloc.attach(1, [alloc._free[-1]])      # can't share a free page
    with pytest.raises(ValueError):
        alloc.unref(0)                          # null page untouchable
    alloc.attach(1, pages)
    alloc.free_slot(0)
    assert all(alloc.refcount(p) == 1 for p in pages)   # slot 1 keeps them
    alloc.free_slot(1)
    assert alloc.used_pages == 0
    alloc.check_invariants()


def test_prefix_cache_requires_paged(rng):
    cfg = get_smoke_config("granite-3-2b")
    m = build_model(cfg)
    params = m.init(rng)
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(m, params, ServeConfig(prefix_cache=True))
