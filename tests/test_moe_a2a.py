"""Explicit all-to-all MoE dispatch (shard_map) vs the SPMD-auto path."""
import subprocess
import sys
import textwrap

import pytest


def run_sub(code: str):
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True,
        env={"PYTHONPATH": "src",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
             "JAX_PLATFORMS": "cpu", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests/", 1)[0], timeout=420)
    assert out.returncode == 0, f"STDOUT:{out.stdout}\nSTDERR:{out.stderr}"


def test_a2a_moe_matches_dense_dispatch():
    """On a (1, 4) mesh with generous capacity (no drops), the explicit
    all-to-all dispatch must equal the auto-SPMD capacity dispatch."""
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.models.moe import moe_init, moe_ffn
from repro.models.moe_a2a import make_sharded_moe

cfg = get_smoke_config("olmoe-1b-7b").replace(moe_capacity_factor=8.0)
params = moe_init(jax.random.PRNGKey(0), cfg)
B, S = 2, 32
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)
                      ).astype(jnp.bfloat16)
y_ref, aux_ref = moe_ffn(params, x, cfg)

from repro.compat import make_mesh
mesh = make_mesh((1, 4), ("data", "model"))
fn = make_sharded_moe(cfg, mesh)
y_a2a, aux_a2a = jax.jit(fn)(params, x)
err = float(jnp.abs(y_a2a.astype(jnp.float32)
                    - y_ref.astype(jnp.float32)).max())
scale = float(jnp.abs(y_ref.astype(jnp.float32)).max())
assert err / (scale + 1e-6) < 0.05, (err, scale)
assert abs(float(aux_a2a) - float(aux_ref)) < 0.05
print("a2a matches dense:", err, scale)
""")
