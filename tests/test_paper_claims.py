"""Validates the reproduction against the paper's published claims.

Primary bands (abstract + Section V):
  * energy reduction vs 2D-Unfused: 80.5%..93%
  * energy saving vs advanced 2D fusion (FuseMax/Dual-SA): 54.2%..66.7%
  * energy saving vs 3D-Base: ~46.8%
  * speedups: 7.62x / 1.46x / 2.36x / 1.43x (2D-Unfused / 2D-Fused /
    Dual-SA / 3D-Base)
  * PE utilization ~87%
  * Fig 1: fused-2D SRAM share > 60% of energy for N >= 2k
  * Fig 6: ours cuts SRAM traffic ~76.6% vs fusion baselines
"""
import statistics as st

import pytest

from repro.core import DESIGNS, normalized_energy, simulate_attention, sweep
from repro.core.simulator import data_movement, mean_utilization, speedups
from repro.core.workloads import PAPER_SEQS, opt_6_7b, qwen_7b

WLS = [m(s).attn for m in (opt_6_7b, qwen_7b) for s in PAPER_SEQS]


@pytest.fixture(scope="module")
def results():
    return sweep(list(DESIGNS), WLS)


def test_speedup_bands(results):
    sp = speedups(results)
    assert 6.8 <= sp["2D-Unfused"] <= 8.4, sp     # paper: 7.62
    assert 1.30 <= sp["2D-Fused"] <= 1.62, sp     # paper: 1.46
    assert 2.05 <= sp["Dual-SA"] <= 2.65, sp      # paper: 2.36
    assert 1.28 <= sp["3D-Base"] <= 1.58, sp      # paper: 1.43


def test_energy_reduction_vs_unfused(results):
    ne = normalized_energy(results)
    ours = list(ne["3D-Flow"].values())
    # paper: every cell in [0.07, 0.195] (= 80.5%..93% reduction)
    assert max(ours) <= 0.195, max(ours)
    assert min(ours) >= 0.07, min(ours)
    assert 0.10 <= st.mean(ours) <= 0.17


def test_energy_vs_fusion_baselines(results):
    ne = normalized_energy(results)
    for d in ("2D-Fused", "Dual-SA"):
        r = st.mean([ne["3D-Flow"][k] / ne[d][k] for k in ne[d]])
        assert 0.333 <= r <= 0.47, (d, r)         # paper: 54.2-66.7% saving


def test_energy_vs_3d_base(results):
    ne = normalized_energy(results)
    r = st.mean([ne["3D-Flow"][k] / ne["3D-Base"][k] for k in ne["3D-Base"]])
    assert 0.45 <= r <= 0.62, r                   # paper: 46.8% saving


def test_pe_utilization(results):
    util = mean_utilization(results)
    assert 0.82 <= util["3D-Flow"] <= 0.92        # paper: 87%
    for d in DESIGNS:
        if d != "3D-Flow":
            assert util[d] < util["3D-Flow"]


def test_fig1_sram_dominates_fused_2d():
    for seq in (4096, 16384, 65536):
        sh = simulate_attention("2D-Fused", opt_6_7b(seq).attn).energy.shares()
        assert sh["SRAM"] > 0.60, (seq, sh["SRAM"])


def test_fig6_data_movement(results):
    dm = data_movement(results)
    cut_fused = 1 - dm["3D-Flow"]["sram"] / dm["2D-Fused"]["sram"]
    assert 0.70 <= cut_fused <= 0.85              # paper: 76.6%
    # fused eliminates nearly all off-chip intermediate traffic
    assert dm["2D-Fused"]["dram"] < 0.3 * dm["2D-Unfused"]["dram"]
    # only 3D designs use vertical links
    assert dm["3D-Flow"]["tsv"] > 0 and dm["2D-Fused"]["tsv"] == 0


def test_table2_trends():
    """Ours: memory-dominated breakdown; DRAM share falls with seq len."""
    shares = {s: simulate_attention("3D-Flow", opt_6_7b(s).attn)
              .energy.shares() for s in PAPER_SEQS}
    for s, sh in shares.items():
        mem = sh["SRAM"] + sh["DRAM"] + sh["Reg"]
        assert mem > 0.5, (s, mem)                # memory access dominates
        assert sh["MAC"] < 0.25
        assert 0.03 <= sh["3D-IC"] <= 0.12        # paper: 5.3-10.1%
    assert shares[65536]["DRAM"] < shares[1024]["DRAM"]
    assert shares[65536]["Reg"] > shares[1024]["Reg"]
