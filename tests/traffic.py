"""Seeded traffic-replay harness for the serve-path test suites.

One place for the request generators and the replay loop every serve test
used to hand-roll: mixed-length prompt sets, shared-prefix groups, wave
traces (a long prompt at the head of each wave with shorts queued behind
it), priority bursts, and fully random arrival traffic for soak tests.
Everything is seeded - the same arguments always produce the same trace -
so parity assertions across engines stay deterministic.

`replay` is the serve-path fixture driver: it submits each item at its
arrival tick, ticks the engine until the trace drains, and calls
`ServeEngine.check_invariants()` after EVERY tick (allocator refcount
conservation, block-table mirroring, prefix-tree consistency, queue/slot
bookkeeping) so any tick that corrupts page accounting fails at the tick
that did it, not at the end of the run.
"""
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve import ServeEngine
from repro.serve.scheduler import Request

# mixed traffic in the acceptance shape (128 / 1k / 4k scaled to smoke
# scale): short prompts interleaved with ones long enough to need many
# prefill chunks
MIXED_LENS = (16, 64, 224, 9, 130, 40)


def mixed_prompts(vocab: int, lens: Sequence[int] = MIXED_LENS,
                  seed: int = 0) -> List[List[int]]:
    """The standard mixed-length prompt set (seeded)."""
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=n).tolist() for n in lens]


def shared_prefix_prompts(vocab: int, shared_len: int,
                          tail_lens: Sequence[int],
                          seed: int = 0) -> List[List[int]]:
    """One prompt per tail, all sharing one `shared_len`-token prefix
    (the prefix-cache traffic shape)."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(1, vocab, size=shared_len).tolist()
    return [shared + rng.integers(1, vocab, size=t).tolist()
            for t in tail_lens]


@dataclass
class TrafficItem:
    """One replayed request: submitted at `tick` with the given knobs."""
    tick: int
    prompt: List[int]
    max_new: Optional[int] = None
    priority: int = 0
    stop_tokens: Optional[Sequence[int]] = None
    deadline: Optional[int] = None       # work-clock deadline tokens
    max_retries: Optional[int] = None    # redispatch budget (fleet only)
    uid: Optional[int] = None      # filled in by replay() at submit time


def wave_arrivals(vocab: int, lens: Sequence[int], waves: int,
                  period: int = 4, seed: int = 0) -> List[TrafficItem]:
    """`waves` arrival waves, each [longest, *shorter lens] submitted the
    same tick - the bubble-inducing shape: every wave's long prompt lands
    at the head of the queue while earlier waves are mid-decode and the
    wave's short prompts queue behind it."""
    rng = np.random.default_rng(seed)
    order = sorted(lens, reverse=True)
    return [TrafficItem(w * period,
                        rng.integers(1, vocab, size=n).tolist())
            for w in range(waves) for n in order]


def priority_burst(vocab: int, background_lens: Sequence[int],
                   burst_lens: Sequence[int], burst_tick: int,
                   burst_priority: int = 5,
                   seed: int = 0) -> List[TrafficItem]:
    """Low-priority background traffic at tick 0 followed by a burst of
    high-priority arrivals at `burst_tick` - the preemption-forcing shape
    when the page pool only fits the background."""
    rng = np.random.default_rng(seed)
    items = [TrafficItem(0, rng.integers(1, vocab, size=n).tolist())
             for n in background_lens]
    items += [TrafficItem(burst_tick,
                          rng.integers(1, vocab, size=n).tolist(),
                          priority=burst_priority)
              for n in burst_lens]
    return items


def random_arrivals(vocab: int, n_requests: int, seed: int,
                    max_len: int = 100, max_new: int = 4,
                    max_tick: int = 20, priorities: Sequence[int] = (0, 1, 2),
                    shared_prefix_frac: float = 0.3) -> List[TrafficItem]:
    """Fully random soak traffic: arrival ticks, mixed lengths, random
    priorities, and a fraction of requests sharing a common prefix (so
    prefix-cache survival paths get exercised under preemption)."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(1, vocab, size=max_len // 2).tolist()
    items = []
    for _ in range(n_requests):
        n = int(rng.integers(1, max_len + 1))
        if rng.random() < shared_prefix_frac:
            prompt = shared[:max(1, n // 2)] \
                + rng.integers(1, vocab, size=max(1, n // 2)).tolist()
        else:
            prompt = rng.integers(1, vocab, size=n).tolist()
        items.append(TrafficItem(int(rng.integers(0, max_tick + 1)), prompt,
                                 max_new=int(rng.integers(1, max_new + 1)),
                                 priority=int(rng.choice(priorities))))
    items.sort(key=lambda it: it.tick)
    return items


def submit_item(eng: ServeEngine, item: TrafficItem) -> int:
    item.uid = eng.submit(item.prompt, max_new_tokens=item.max_new,
                          stop_tokens=item.stop_tokens,
                          priority=item.priority,
                          deadline=item.deadline,
                          max_retries=item.max_retries)
    return item.uid


def replay(eng: ServeEngine, items: Sequence[TrafficItem],
           max_ticks: int = 50_000, check: bool = True
           ) -> Tuple[Dict[int, List[int]], List[Request]]:
    """Drive `eng` through a timed-arrival trace.  Submits each item at
    its arrival tick, ticks until everything drains, and - with `check`
    (default) - runs ServeEngine.check_invariants() after every tick.
    Returns ({uid: out_tokens}, finished Requests in completion order).
    Raises RuntimeError if the trace does not drain in max_ticks (a
    deadlocked scheduler must fail loudly, not hang the suite)."""
    pending = sorted(items, key=lambda it: it.tick)
    pending_q = list(pending)
    done: List[Request] = []
    tick = 0
    while pending_q or eng.queue or any(s is not None for s in eng.slots):
        while pending_q and pending_q[0].tick <= tick:
            submit_item(eng, pending_q.pop(0))
        done.extend(eng.tick())
        if check:
            eng.check_invariants()
        tick += 1
        if tick >= max_ticks:
            raise RuntimeError(
                f"replay: {max_ticks} ticks exhausted with "
                f"{len(pending_q)} unsubmitted, {len(eng.queue)} queued, "
                f"{sum(s is not None for s in eng.slots)} in flight")
    return {r.uid: r.out_tokens for r in done}, done


def replay_fleet(router, items: Sequence[TrafficItem],
                 max_ticks: int = 50_000, check: bool = True
                 ) -> Tuple[Dict[int, List[int]], List[Request]]:
    """Drive a FleetRouter through a timed-arrival trace - the fleet
    analog of replay().  Submits each item at its arrival tick (the
    router scores and places it), ticks the whole fleet until it drains,
    and - with `check` (default) - runs FleetRouter.check_invariants()
    after EVERY tick, which sweeps every replica's engine invariants
    (allocator refcount conservation, block-table mirroring, prefix-tree
    consistency) plus the router's placement/dispatch accounting.  After
    the drain it asserts cross-replica page conservation
    (assert_fleet_pages_drained).  Returns ({fleet uid: out_tokens},
    finished Requests in completion order) - fleet uids are issued in
    submit order, so the same trace keys identically through any fleet
    size, which is what the 1-vs-N differential tests compare on."""
    pending_q = sorted(items, key=lambda it: it.tick)
    done: List[Request] = []
    tick = 0
    while pending_q or not router.idle:
        while pending_q and pending_q[0].tick <= tick:
            item = pending_q.pop(0)
            item.uid = router.submit(item.prompt,
                                     max_new_tokens=item.max_new,
                                     stop_tokens=item.stop_tokens,
                                     priority=item.priority,
                                     deadline=item.deadline,
                                     max_retries=item.max_retries)
        done.extend(router.tick())
        if check:
            router.check_invariants()
        tick += 1
        if tick >= max_ticks:
            pending = sum(len(e.queue) for e in router.engines)
            flight = sum(sum(s is not None for s in e.slots)
                         for e in router.engines)
            raise RuntimeError(
                f"replay_fleet: {max_ticks} ticks exhausted with "
                f"{len(pending_q)} unsubmitted, {pending} queued, "
                f"{flight} in flight")
    if check:
        assert_fleet_pages_drained(router)
    return {r.fleet_uid: r.out_tokens for r in done}, done


def assert_fleet_pages_drained(router):
    """Cross-replica page conservation after a drained trace: every
    SURVIVING replica's pool holds ONLY its prefix tree's pages (or
    nothing with caching off) - page pools are strictly per-replica, so a
    page leaked on one replica cannot be hidden by headroom on another.
    DEAD replicas are skipped: a failed engine's state is abandoned
    wholesale, so its pool is frozen mid-flight by design."""
    states = getattr(router, "states", None)
    for i, eng in enumerate(router.engines):
        if states is not None and states[i].value == "dead":
            continue
        if not eng.paged:
            continue
        assert all(s is None for s in eng.slots), \
            f"replica {i} still holds in-flight slots"
        cached = eng.prefix.cached_pages if eng.prefix is not None else 0
        assert eng.allocator.used_pages == cached, \
            (f"replica {i}: {eng.allocator.used_pages} pages in use vs "
             f"{cached} cached - pages leaked or double-freed")
        if eng.prefix is not None:
            eng.prefix.check_invariants()


def assert_greedy_equivalent(model, params, done, want: Dict[int, List[int]],
                             tol: float = 2e-3):
    """Assert a run's outputs match the oracle's, tolerating only genuine
    floating-point argmax near-ties.

    Fast path: bit equality.  Fallback for requests that diverge: the
    request's emitted trace is TEACHER-FORCED through model.forward and
    every generated token's logit must be within `tol` of that position's
    max logit - i.e., the trace is a valid greedy trace up to the ~1e-5
    kernel-level rounding wobble different schedules legitimately exhibit
    (different chunk-batch bucket shapes, prefill- vs decode-written KV
    positions after a preemption resume).  A scheduling bug that corrupts
    KV (stale page, lost chunk, wrong offset) shifts logits by O(1) and
    still fails loudly; a near-tie flip passes instead of making the
    suite a per-process coin flip."""
    import jax.numpy as jnp

    # fleet runs key by the router-issued fleet uid (replica-local uids
    # collide across replicas); single-engine runs fall back to req.uid
    got = {getattr(r, "fleet_uid", r.uid): r.out_tokens for r in done}
    assert got.keys() == want.keys()
    by_uid = {getattr(r, "fleet_uid", r.uid): r for r in done}
    for uid, toks in got.items():
        if toks == want[uid]:
            continue
        assert len(toks) == len(want[uid]), \
            f"uid {uid}: {len(toks)} tokens vs oracle {len(want[uid])}"
        req = by_uid[uid]
        seq = req.prompt + toks
        out = model.forward(params, {"tokens": jnp.asarray([seq],
                                                           jnp.int32)})
        logits = np.asarray(out[0] if isinstance(out, tuple) else out)[0]
        for i, tok in enumerate(toks):
            row = logits[len(req.prompt) - 1 + i]
            gap = float(row.max() - row[tok])
            assert gap <= tol, \
                f"uid {uid} token {i}: emitted {tok} sits {gap:.2e} below " \
                f"the argmax - not a near-tie, the trace is corrupted"


def serve_all(model, params, scfg, prompts, check: bool = False,
              **submit_kw):
    """Submit every prompt up front and run to completion (the untimed
    harness the parity tests use).  Returns ({uid: out_tokens}, engine)."""
    eng = ServeEngine(model, params, scfg)
    for p in prompts:
        eng.submit(p, **submit_kw)
    items_done = eng.run_until_done(max_ticks=50_000)
    if check:
        eng.check_invariants()
    return {r.uid: r.out_tokens for r in items_done}, eng
