"""Fleet router: prefix-aware dispatch over N serve-engine replicas.

Differential conformance in the style of tests/conformance.py: every
registered traffic trace replays through a 1-replica fleet and an
N-replica fleet, and the fleet must be observationally identical -
bit-identical per-request greedy outputs (replicas share jitted steps,
so the comparison is exact, with the teacher-forced near-tie fallback),
per-replica page conservation after every tick and after the drain
(replay_fleet), and work-clock comparability (equal generated tokens on
every trace; byte-equal work totals on traces where no prefix cache or
preemption can legitimately shift executed work between topologies).

Plus the router's own policy surface: affinity routing follows cached
prefixes (via the side-effect-free peek), round-robin ignores them,
spill-to-next-best under the per-replica admission cap, deterministic
tie-breaking (bit-reproducible replays), and the fleet telemetry view
(summed registries, dispatch/spill/affinity counters, merged Perfetto
trace with one track group per replica).
"""
import json

import jax
import pytest

from conformance import TRACES, make_scfg
from repro.configs import get_smoke_config
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.serve import FleetConfig, FleetRouter, ServeEngine
from traffic import (TrafficItem, assert_greedy_equivalent, mixed_prompts,
                     replay, replay_fleet, shared_prefix_prompts)


@pytest.fixture(scope="module")
def model_f32():
    # float32 keeps greedy argmax ties out of the parity comparisons
    cfg = get_smoke_config("granite-3-2b").replace(dtype="float32")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _fleet(model, params, n, scfg, **fcfg_kw):
    return FleetRouter(model, params, scfg,
                       FleetConfig(n_replicas=n, **fcfg_kw))


def _affinity_scfg(**over):
    base = dict(max_batch=4, max_seq=512, page_size=16, prefill_chunk=32,
                tick_token_budget=64, max_new_tokens=8, paged=True,
                chunked=True, batched=True, prefix_cache=True)
    base.update(over)
    return ServeConfig(**base)


def _replay_fleet(model, params, trace, n, **fcfg_kw):
    scfg = make_scfg(trace, False, max_new_tokens=12)
    router = _fleet(model, params, n, scfg, **fcfg_kw)
    out, _ = replay_fleet(router, trace.build(model.cfg.vocab_size),
                          check=True)
    return out, router


# ===========================================================================
# differential conformance: 1-replica fleet vs N-replica fleet
# ===========================================================================

@pytest.mark.parametrize("trace", sorted(TRACES))
def test_fleet_differential_1_vs_2_replicas(trace, model_f32):
    """The tentpole guarantee: the same trace through a 1-replica and a
    2-replica fleet yields bit-identical per-request greedy outputs (the
    replicas run the very same compiled steps), equal generated-token
    totals, and - on traces where neither prefix-cache interleaving nor
    preemption can shift executed work between topologies - byte-equal
    summed work clocks.  Page conservation on every replica is checked
    per tick and after the drain inside replay_fleet."""
    m, params = model_f32
    spec = TRACES[trace]
    out1, r1 = _replay_fleet(m, params, spec, 1)
    out2, r2 = _replay_fleet(m, params, spec, 2)
    assert out1.keys() == out2.keys()
    if out1 != out2:
        # only genuine fp argmax near-ties may differ; anything else
        # (corrupted KV, lost chunk, wrong routing bookkeeping) fails
        assert_greedy_equivalent(m, params, list(r2.requests.values()),
                                 out1)
    s1, s2 = r1.fleet_stats(), r2.fleet_stats()
    assert s1["requests"] == s2["requests"] == len(out1)
    assert s1["gen_tokens"] == s2["gen_tokens"]
    deterministic_work = not spec.scfg_kw.get("prefix_cache") \
        and not spec.scfg_kw.get("preemption")
    if deterministic_work:
        assert s1["work_tokens"] == s2["work_tokens"], \
            (s1["work_tokens"], s2["work_tokens"])
    # every request landed somewhere, and dispatch accounting closed
    assert sum(s2["dispatch"]) == len(out2)
    r1.check_invariants()
    r2.check_invariants()


def test_one_replica_fleet_matches_bare_engine(model_f32):
    """A 1-replica fleet is the engine: the router layer must add zero
    behavior - same outputs, same work clock, same prefill totals."""
    m, params = model_f32
    spec = TRACES["mixed"]
    scfg = make_scfg(spec, False, max_new_tokens=12)
    eng = ServeEngine(m, params, scfg)
    out_e, _ = replay(eng, spec.build(m.cfg.vocab_size), check=True)
    out_f, router = _replay_fleet(m, params, spec, 1)
    # engine uids and fleet uids are both monotone from 1 in submit order
    assert out_e == out_f
    se, sf = eng.stats(), router.fleet_stats()
    for k in ("work_tokens", "gen_tokens", "prefill_tokens", "requests"):
        assert se[k] == sf[k], (k, se[k], sf[k])


# ===========================================================================
# routing policy: affinity, round-robin, spill, determinism
# ===========================================================================

def test_affinity_routes_followers_to_the_warm_replica(model_f32):
    """After one request warms a replica's prefix tree, every follower
    sharing that prefix must land on the SAME replica (cache-hit-weighted
    score beats the load imbalance it creates), and actually hit: the
    home replica's prefix counters record the reuse, the router's
    affinity counters record the decisions."""
    m, params = model_f32
    prompts = shared_prefix_prompts(m.cfg.vocab_size, 128, (16, 24, 32))
    router = _fleet(m, params, 2, _affinity_scfg())
    warm_uid = router.submit(prompts[0])
    router.run_until_done()
    home = router.placement[warm_uid]
    follower_uids = [router.submit(p) for p in prompts[1:]]
    router.run_until_done()
    assert all(router.placement[u] == home for u in follower_uids), \
        "a follower was routed off its cached prefix"
    st = router.fleet_stats()
    # each follower shares exactly 128 tokens = 8 whole pages with the
    # warm prompt, and the peek-based accounting saw it at dispatch
    assert st["affinity_hits"] == len(follower_uids)
    assert st["affinity_hit_tokens"] == 128 * len(follower_uids)
    assert router.engines[home].prefix_hit_tokens >= 128 * len(follower_uids)
    cold = router.engines[1 - home]
    assert cold.prefix_hit_tokens == 0


def test_round_robin_ignores_the_cache(model_f32):
    """The control policy: round-robin alternates replicas regardless of
    where prefixes live - the bench's baseline for 'affinity actually
    buys something'."""
    m, params = model_f32
    prompts = mixed_prompts(m.cfg.vocab_size, lens=(8, 8, 8, 8))
    router = _fleet(m, params, 2, _affinity_scfg(), policy="round_robin")
    uids = [router.submit(p) for p in prompts]
    assert [router.placement[u] for u in uids] == [0, 1, 0, 1]
    router.run_until_done()
    assert router.dispatch_counts() == [2, 2]


def test_spill_to_next_best_under_admission_cap(model_f32):
    """Per-replica admission backpressure: with spill_queue_depth=1, a
    second follower bound for the warm (best-scoring) replica spills to
    the next-best one instead of queueing behind the first - counted in
    fleet_spills_total - and when EVERY replica is at the cap the best
    one still absorbs the request (the cap sheds imbalance, it never
    rejects work)."""
    m, params = model_f32
    prompts = shared_prefix_prompts(m.cfg.vocab_size, 128, (16, 24, 32))
    router = _fleet(m, params, 2, _affinity_scfg(), spill_queue_depth=1)
    warm_uid = router.submit(prompts[0])
    router.run_until_done()
    home = router.placement[warm_uid]
    u1 = router.submit(prompts[1])      # home queue: 0 -> placed home
    u2 = router.submit(prompts[2])      # home at cap -> spills
    assert router.placement[u1] == home
    assert router.placement[u2] == 1 - home
    assert router.metrics.get("fleet_spills_total").value == 1
    # both replicas now at the cap: the best-scoring one absorbs anyway
    u3 = router.submit(prompts[1][:32])
    assert router.placement[u3] == home
    router.run_until_done()
    router.check_invariants()


def test_dispatch_is_deterministic_across_replays(model_f32):
    """Bit-reproducible replays: two routers fed the identical timed
    trace make identical placements (ties break to the lowest replica
    index; every score input is deterministic host state) and produce
    identical outputs."""
    m, params = model_f32
    spec = TRACES["wave"]

    def run():
        scfg = make_scfg(spec, False, max_new_tokens=8)
        router = _fleet(m, params, 3, scfg)
        out, _ = replay_fleet(router, spec.build(m.cfg.vocab_size),
                              check=False)
        return out, dict(router.placement), router.dispatch_counts()

    out_a, place_a, counts_a = run()
    out_b, place_b, counts_b = run()
    assert place_a == place_b
    assert counts_a == counts_b
    assert out_a == out_b


# ===========================================================================
# fleet telemetry: summed registries, merged Perfetto trace
# ===========================================================================

def test_fleet_snapshot_sums_replica_registries(model_f32):
    """fleet_snapshot() is the fleet registry view: router metrics, every
    replica's full snapshot, and a summed section whose counters equal
    the per-replica totals (the fleet_stats aggregates agree with it)."""
    m, params = model_f32
    spec = TRACES["mixed"]
    out, router = _replay_fleet(m, params, spec, 2)
    snap = router.fleet_snapshot()
    assert set(snap) == {"router", "replicas", "sum"}
    assert len(snap["replicas"]) == 2
    gen_per_replica = sum(e.gen_tokens for e in router.engines)
    assert snap["sum"]["serve_gen_tokens_total"] == gen_per_replica
    assert router.fleet_stats()["gen_tokens"] == gen_per_replica
    assert snap["router"]["fleet_requests_total"]["value"] == len(out)
    assert snap["router"]["fleet_replicas"]["value"] == 2
    # labeled dispatch counters survive the summing path per label
    dispatch = snap["router"]["fleet_dispatch_total"]["value"]
    assert sum(dispatch.values()) == len(out)


def test_merged_perfetto_trace_one_track_group_per_replica(model_f32,
                                                          tmp_path):
    """export_trace merges every replica's Chrome trace into one file:
    pids offset per replica, process names `replicaN:engine` /
    `replicaN:requests`, real (non-metadata) events present for every
    replica, written on the deterministic work clock."""
    m, params = model_f32
    prompts = mixed_prompts(m.cfg.vocab_size, lens=(16, 24, 16, 24))
    router = _fleet(m, params, 2, _affinity_scfg(telemetry=True))
    for p in prompts:
        router.submit(p)
    router.run_until_done()
    path = tmp_path / "fleet_trace.json"
    trace = router.export_trace(str(path), clock="work")
    on_disk = json.loads(path.read_text())
    assert on_disk == trace
    assert trace["otherData"]["n_replicas"] == 2
    names = {ev["args"]["name"] for ev in trace["traceEvents"]
             if ev.get("ph") == "M" and ev.get("name") == "process_name"}
    assert names == {"replica0:engine", "replica0:requests",
                     "replica1:engine", "replica1:requests"}
    real_pids = {ev["pid"] for ev in trace["traceEvents"]
                 if ev.get("ph") != "M"}
    # engine tick spans exist for both replicas (pids 0 and 2)
    assert {0, 2} <= real_pids
    assert real_pids <= {0, 1, 2, 3}


def test_engine_load_stats_is_cheap_and_registry_backed(model_f32):
    """The router's per-submit load probe: correct occupancy arithmetic,
    zero device->host syncs, and the work-token total published to the
    `serve_outstanding_work_tokens` gauge."""
    m, params = model_f32
    eng = ServeEngine(m, params, _affinity_scfg())
    syncs0 = eng.host_syncs
    ls = eng.load_stats()
    assert ls == {"queue_depth": 0, "inflight": 0, "free_slots": 4,
                  "outstanding_work_tokens": 0,
                  "free_pages": ls["free_pages"], "evictable_pages": 0}
    eng.submit([1, 2, 3, 4], max_new_tokens=6)
    ls = eng.load_stats()
    assert ls["queue_depth"] == 1
    assert ls["outstanding_work_tokens"] == 4 + 6
    assert eng.tm.registry.get("serve_outstanding_work_tokens").value \
        == 10
    assert eng.host_syncs == syncs0, "load_stats touched the device"


def test_fleet_config_validation():
    with pytest.raises(ValueError, match="n_replicas"):
        FleetConfig(n_replicas=0).validate()
    with pytest.raises(ValueError, match="policy"):
        FleetConfig(policy="sticky-random").validate()
    with pytest.raises(ValueError, match="spill_queue_depth"):
        FleetConfig(spill_queue_depth=-1).validate()
    with pytest.raises(ValueError, match="weights"):
        FleetConfig(load_weight=-0.5).validate()
    with pytest.raises(ValueError, match="weights"):
        FleetConfig(slo_weight=-0.1).validate()
    with pytest.raises(ValueError, match="watchdog_ticks"):
        FleetConfig(watchdog_ticks=-1).validate()


# ===========================================================================
# replica lifecycle: drain under load, SLO shedding, exhaust reporting
# ===========================================================================

def test_drain_under_load_empties_and_matches_no_drain_run(model_f32):
    """The drain contract under real load: a replica holding BOTH
    prefilling and decoding requests is drained mid-flight; it takes no
    new work, finishes what it holds in place, every non-cached page
    returns to its pool, and the fleet's outputs are bit-identical to a
    run that never drained."""
    m, params = model_f32
    from repro.serve import ReplicaState
    spec = TRACES["mixed"]
    scfg = make_scfg(spec, False, max_new_tokens=12)
    items = spec.build(m.cfg.vocab_size)

    base_router = _fleet(m, params, 2, scfg)
    base_out, _ = replay_fleet(base_router, [TrafficItem(0, it.prompt)
                                             for it in items], check=True)

    router = _fleet(m, params, 2, scfg)
    for it in items:
        router.submit(it.prompt)
    # a couple of ticks in, replica 0 holds a mix of prefilling (long
    # prompts chunk across ticks) and decoding (short prompts) requests
    for _ in range(2):
        router.tick()
    from repro.serve.scheduler import RequestState
    eng0 = router.engines[0]
    live = [s for s in eng0.slots if s is not None]
    assert live, "trace never put in-flight work on replica 0"
    states = {r.state for r in live} | {r.state for r in eng0.queue}
    assert states & {RequestState.PREFILLING, RequestState.DECODING}, states
    router.drain(0)
    assert router.states[0] is ReplicaState.DRAINING
    pre_dispatch = router.dispatch_counts()
    # more traffic while draining: ALL of it must land on replica 1
    extra = [router.submit(list(range(50 + i, 90 + i))) for i in range(3)]
    assert all(router.placement[f] == 1 for f in extra)
    assert router.dispatch_counts()[0] == pre_dispatch[0]
    router.run_until_done()
    router.check_invariants()
    # the drained replica emptied in place and its pages came home
    assert not eng0.queue and all(s is None for s in eng0.slots)
    cached = eng0.prefix.cached_pages if eng0.prefix is not None else 0
    assert eng0.allocator.used_pages == cached
    # and the drain changed no tokens on the original trace
    got = {f: o for f, o in router.outputs().items() if f in base_out}
    if got != base_out:
        reqs = [router.requests[f] for f in base_out]
        assert_greedy_equivalent(m, params, reqs, base_out)


def test_slo_weight_sheds_load_off_a_slow_replica(model_f32):
    """The SLO dispatch term: a replica whose delivered work-clock p95
    TTFT is large loses otherwise-tied dispatches once slo_weight > 0 -
    and keeps winning ties (lowest index) when slo_weight stays 0."""
    from repro.serve.scheduler import Request

    def seed_slow_history(router, ridx, ttft):
        # fabricate a finished request whose first token cost `ttft`
        # work tokens - the shape _observed_ttft() reads
        r = Request(uid=900, prompt=[1, 2], max_new_tokens=1)
        r.w_submit = 0
        r.token_work = [ttft]
        r.done = True
        router.engines[ridx].sched.finished.append(r)

    m, params = model_f32
    scfg = _affinity_scfg(prefix_cache=False)
    prompt = list(range(1, 40))

    router = _fleet(m, params, 2, scfg)            # slo_weight=0 control
    seed_slow_history(router, 0, 500)
    uid = router.submit(prompt)
    assert router.placement[uid] == 0, "tie must break to lowest index"

    router = _fleet(m, params, 2, scfg, slo_weight=1.0)
    seed_slow_history(router, 0, 500)
    uid = router.submit(prompt)
    assert router.placement[uid] == 1, \
        "slo_weight must shed load off the slow replica"
    # symmetric histories tie again: back to the index tie-break
    router = _fleet(m, params, 2, scfg, slo_weight=1.0)
    seed_slow_history(router, 0, 500)
    seed_slow_history(router, 1, 500)
    uid = router.submit(prompt)
    assert router.placement[uid] == 0


def test_run_until_done_exhaust_reports_statuses(model_f32):
    """on_exhaust="return" must tell the caller WHAT state every request
    is in - per-status counts and the still-running fleet uids - not
    just that ticks ran out."""
    m, params = model_f32
    scfg = make_scfg(TRACES["mixed"], False, max_new_tokens=24)
    router = _fleet(m, params, 2, scfg)
    uids = [router.submit(list(range(1, 120))) for _ in range(3)]
    with pytest.warns(UserWarning) as rec:
        router.run_until_done(max_ticks=1, on_exhaust="return")
    msg = str(rec[0].message)
    assert "statuses" in msg and "still running fleet uids" in msg
    for uid in uids:
        assert router.statuses()[uid] != "done"
    with pytest.raises(RuntimeError):
        router.run_until_done(max_ticks=1)


def test_every_router_metric_is_documented(model_f32):
    """Doc-coverage for the ROUTER registry: docs/routing.md must name
    every metric the router registers (same contract the engine registry
    has with docs/observability.md)."""
    from pathlib import Path
    m, params = model_f32
    router = _fleet(m, params, 2, _affinity_scfg())
    text = (Path(__file__).resolve().parents[1]
            / "docs" / "routing.md").read_text()
    missing = [n for n in router.metrics.names() if f"`{n}`" not in text]
    assert not missing, \
        f"router metrics missing from docs/routing.md: {missing}"
