"""Distributed-equivalence tests on 4 forced host devices (subprocess).

The main test process must keep 1 device (jax locks device count at init),
so each scenario runs in a fresh subprocess with
--xla_force_host_platform_device_count=4 and asserts against single-device
references computed in the same process BEFORE the mesh is used.
"""
import subprocess
import sys
import textwrap

import pytest


def run_sub(code: str):
    prog = textwrap.dedent(code)
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        env={"PYTHONPATH": "src",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
             "JAX_PLATFORMS": "cpu", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests/", 1)[0], timeout=420)
    assert out.returncode == 0, f"STDOUT:{out.stdout}\nSTDERR:{out.stderr}"
    return out.stdout


def test_seq_parallel_decode_matches_reference():
    """shard_map sequence-parallel decode (explicit partial-softmax merge
    over the data axis) == single-device flash decode."""
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.kernels import ops, ref

B, S, Hq, Hkv, D = 2, 64, 4, 2, 16
key = jax.random.PRNGKey(0)
ks = jax.random.split(key, 3)
q = jax.random.normal(ks[0], (B, 1, Hq, D))
kc = jax.random.normal(ks[1], (B, S, Hkv, D))
vc = jax.random.normal(ks[2], (B, S, Hkv, D))
lens = jnp.array([S - 5, S // 2])

o_ref = ref.flash_decode(q, kc, vc, lens)

from repro.compat import make_mesh, shard_map
mesh = make_mesh((4, 1), ("data", "model"))
# replication checking off: the psum/pmax-combined output is replicated by
# construction; correctness is asserted numerically below.
fn = shard_map(
    lambda q, kc, vc, lens: ops.seq_parallel_decode(q, kc, vc, lens,
                                                    axis="data"),
    mesh=mesh,
    in_specs=(P(), P(None, "data", None, None),
              P(None, "data", None, None), P()),
    out_specs=P())
o_par = fn(q, kc, vc, lens)
err = float(jnp.abs(o_par - o_ref).max())
assert err < 2e-5, err
print("seq-parallel decode OK", err)
""")


def test_sharded_train_step_matches_single_device():
    """One train step on a 2x2 (data, model) mesh with the production
    sharding rules == the same step unsharded (same loss, same grad norm)."""
    run_sub("""
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig, ShapeSpec
from repro.models import build_model
from repro.train.train_step import init_train_state, make_train_step
from repro.launch.specs import train_cell

cfg = get_smoke_config("granite-3-2b")
tcfg = TrainConfig(global_batch=4, seq_len=32, remat="full")
m = build_model(cfg)
key = jax.random.PRNGKey(0)
batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size)}

# single-device reference
state0 = init_train_state(m, key, tcfg)
_, met_ref = jax.jit(make_train_step(m, tcfg))(state0, batch)
loss_ref = float(met_ref["loss"])

# sharded
from repro.compat import make_mesh, use_mesh
mesh = make_mesh((2, 2), ("data", "model"))
with use_mesh(mesh):
    shape = ShapeSpec("t", 32, 4, "train")
    step, args, shardings = train_cell(cfg, shape, mesh, tcfg)
    state1 = jax.device_put(init_train_state(m, key, tcfg), shardings[0])
    batch_sh = jax.device_put(batch, shardings[1])
    _, met = jax.jit(step, in_shardings=shardings)(state1, batch_sh)
loss_sh = float(met["loss"])
assert abs(loss_sh - loss_ref) < 2e-2, (loss_sh, loss_ref)
gn_ref, gn_sh = float(met_ref["grad_norm"]), float(met["grad_norm"])
assert abs(gn_sh - gn_ref) / max(gn_ref, 1e-6) < 0.05, (gn_sh, gn_ref)
print("sharded train step OK", loss_sh, loss_ref)
""")


def test_sharded_decode_cell_executes():
    """serve_step compiled with the production sharding rules actually RUNS
    on a small mesh (not just lowers) and matches the unsharded decode."""
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.configs.base import ShapeSpec
from repro.launch.specs import serve_cell
from repro.models import build_model

cfg = get_smoke_config("gemma3-4b")     # kv=2 heads < model axis
m = build_model(cfg)
key = jax.random.PRNGKey(0)
params = m.init(key)
B, S = 4, 32
cache = m.init_cache(B, S)
tokens = jnp.ones((B, 1), jnp.int32)
lens = jnp.full((B,), 7, jnp.int32)
logits_ref, _ = m.decode_step(params, tokens, lens, cache)

from repro.compat import make_mesh, use_mesh
mesh = make_mesh((2, 2), ("data", "model"))
with use_mesh(mesh):
    shape = ShapeSpec("d", S, B, "decode")
    step, args, shardings = serve_cell(cfg, shape, mesh)
    logits_sh, _ = jax.jit(step, in_shardings=shardings)(
        params, cache, tokens, lens)
err = float(jnp.abs(logits_sh - logits_ref).max())
assert err < 0.15, err     # bf16 + different reduction orders
print("sharded decode OK", err)
""")
