import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.data.pipeline import DataPipeline, SyntheticCorpus


def test_batches_deterministic_across_restart():
    cfg = get_smoke_config("granite-3-2b")
    tcfg = TrainConfig(global_batch=4, seq_len=32)
    p1 = DataPipeline(cfg, tcfg)
    p2 = DataPipeline(cfg, tcfg)
    for step in (0, 5, 17):
        b1, b2 = p1.host_batch(step), p2.host_batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_batches_differ_across_steps():
    cfg = get_smoke_config("granite-3-2b")
    p = DataPipeline(cfg, TrainConfig(global_batch=4, seq_len=32))
    assert not np.array_equal(p.host_batch(0)["tokens"],
                              p.host_batch(1)["tokens"])


def test_tokens_in_vocab_and_learnable():
    corpus = SyntheticCorpus(vocab_size=128, seed=0)
    b = corpus.batch(0, 8, 64)
    assert b.min() >= 0 and b.max() < 128
    # templates create repeated n-grams: some bigram appears more than chance
    from collections import Counter
    bigrams = Counter()
    for row in b:
        for i in range(len(row) - 1):
            bigrams[(row[i], row[i + 1])] += 1
    assert bigrams.most_common(1)[0][1] >= 4


def test_modality_stubs():
    cfg = get_smoke_config("llava-next-34b")
    p = DataPipeline(cfg, TrainConfig(global_batch=2, seq_len=32))
    b = p.host_batch(0)
    assert b["vision_embeds"].shape == (2, cfg.frontend_tokens, cfg.d_model)
    assert b["tokens"].shape == (2, 32 - cfg.frontend_tokens)
