"""Tensor-parallel serving: differential conformance + kernel parity.

Two layers, matching docs/tensor_parallel.md:

  validation      ServeConfig.validate() / engine construction reject
                  every indivisible or unsupported TP combination with a
                  clear error naming the knob - these run on any device
                  count.
  parity          the head-sharded engine and kernels are BIT-identical
                  to single-device: every registered conformance trace
                  replays tp=1 vs tp=2 (assert_tp_conformance), fleets
                  of TP replicas match single-replica fleets, and a
                  hypothesis sweep over random head counts / tp degrees
                  / chunk packings pins the kernel wrappers themselves
                  against the unsharded oracle.  These need >= 2 devices
                  and run in the CI multi-device job
                  (XLA_FLAGS=--xla_force_host_platform_device_count=4);
                  a subprocess smoke keeps one end-to-end TP replay in
                  the default single-device suite.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conformance import (TRACES, assert_tp_conformance,
                         assert_tp_shard_accounting, make_scfg)
from repro.configs import get_smoke_config
from repro.configs.base import ServeConfig
from repro.launch.mesh import make_serve_mesh
from repro.models import build_model
from repro.serve import FleetConfig, FleetRouter, ServeEngine
from traffic import assert_greedy_equivalent, replay_fleet

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")

TP_SCFG = dict(max_batch=4, max_seq=512, page_size=16, prefill_chunk=32,
               tick_token_budget=64, max_new_tokens=12, paged=True,
               chunked=True, batched=True)


@pytest.fixture(scope="module")
def model_f32():
    # float32 keeps greedy argmax ties out of the parity comparisons
    cfg = get_smoke_config("granite-3-2b").replace(dtype="float32")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


# ===========================================================================
# validation: every bad TP combination fails with a clear error
# ===========================================================================

def test_tp_degree_below_one_rejected():
    with pytest.raises(ValueError, match="tp_degree"):
        ServeConfig(**{**TP_SCFG, "tp_degree": 0}).validate()


# knocking out `paged` also knocks out `chunked` (chunked requires paged
# and its own validate() check fires first)
@pytest.mark.parametrize("off", [("paged", "chunked"), ("chunked",),
                                 ("batched",)])
def test_tp_requires_paged_chunked_batched(off):
    kw = {**TP_SCFG, "tp_degree": 2, **{k: False for k in off}}
    with pytest.raises(ValueError, match="tp_degree"):
        ServeConfig(**kw).validate()


def test_tp_indivisible_heads_rejected(model_f32):
    """granite smoke has n_kv_heads=2: tp_degree=3 cannot shard it, and
    the engine must say so by name instead of crashing in shard_map."""
    m, params = model_f32
    with pytest.raises(ValueError, match="n_kv_heads"):
        ServeEngine(m, params, ServeConfig(**{**TP_SCFG, "tp_degree": 3}))


def test_serve_mesh_validates_device_count():
    with pytest.raises(ValueError, match="devices"):
        make_serve_mesh(jax.device_count() + 1)
    with pytest.raises(ValueError):
        make_serve_mesh(0)


def test_serve_mesh_shape():
    mesh = make_serve_mesh(1)
    assert dict(mesh.shape) == {"data": 1, "model": 1}


# ===========================================================================
# single-device suite keeps one end-to-end TP replay (subprocess, the
# tests/test_distributed.py pattern: the main process keeps 1 device)
# ===========================================================================

def test_tp_engine_smoke_subprocess():
    prog = textwrap.dedent("""
        import jax
        from conformance import TRACES, assert_tp_conformance
        from repro.configs import get_smoke_config
        from repro.models import build_model

        assert jax.device_count() >= 2
        cfg = get_smoke_config("granite-3-2b").replace(dtype="float32")
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        assert_tp_conformance(m, params, TRACES["mixed"],
                              max_new_tokens=8)
        print("tp smoke OK")
    """)
    root = __file__.rsplit("/tests/", 1)[0]
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        env={"PYTHONPATH": f"src:{root}/tests",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=2",
             "JAX_PLATFORMS": "cpu", "PATH": "/usr/bin:/bin"},
        cwd=root, timeout=420)
    assert out.returncode == 0, f"STDOUT:{out.stdout}\nSTDERR:{out.stderr}"


# ===========================================================================
# differential conformance: tp=1 vs tp=2 on every registered trace
# ===========================================================================

@multi_device
@pytest.mark.parametrize("trace", sorted(TRACES))
def test_tp_conformance(trace, model_f32):
    """The tentpole guarantee: head-sharding changes WHERE bytes live
    and HOW MUCH each device streams, never WHAT is generated - greedy
    bit-parity, equal work clocks, page conservation, per-shard byte
    accounting, on every registered traffic shape."""
    m, params = model_f32
    assert_tp_conformance(m, params, TRACES[trace])


@multi_device
def test_tp_composes_with_speculation(model_f32):
    """TP and speculative decoding stack: the sharded verify kernel is
    bit-identical too, so spec-on tp=2 == spec-on tp=1."""
    m, params = model_f32
    _, eng_tp = assert_tp_conformance(m, params, TRACES["mixed"],
                                      speculative=True)
    assert eng_tp.stats()["spec_drafted"] > 0, "speculation never engaged"


@multi_device
def test_tp_fleet_differential(model_f32):
    """Fleets of TP replicas: the same trace through a 1-replica tp=1
    fleet and a 2-replica tp=2 fleet yields bit-identical per-request
    outputs (fleet uids key in submit order), with per-shard accounting
    holding on every replica."""
    m, params = model_f32
    spec = TRACES["mixed"]
    items = spec.build(m.cfg.vocab_size)

    def run(n_replicas, tp):
        scfg = make_scfg(spec, False, max_new_tokens=8, tp_degree=tp)
        router = FleetRouter(m, params, scfg,
                             FleetConfig(n_replicas=n_replicas))
        out, done = replay_fleet(router, spec.build(m.cfg.vocab_size),
                                 check=True)
        return out, done, router

    out1, _, r1 = run(1, 1)
    out2, done2, r2 = run(2, 2)
    assert out1.keys() == out2.keys()
    if out1 != out2:
        assert_greedy_equivalent(m, params, done2, out1)
    for eng in r2.engines:
        assert_tp_shard_accounting(eng)
        assert eng.tp_stats()["tp_degree"] == 2
    assert sum(len(v) for v in out1.values()) \
        == sum(len(v) for v in out2.values())


@multi_device
def test_tp_stats_surface(model_f32):
    """tp_stats() and the serve_tp_* metrics tell one story: the gauge
    carries the degree, per-shard bytes divide the full-page bytes
    exactly, and stats() exposes the degree for the fleet view."""
    m, params = model_f32
    eng = ServeEngine(m, params, ServeConfig(**{**TP_SCFG,
                                                "tp_degree": 2}))
    eng.submit(list(range(1, 40)))
    eng.run_until_done()
    t = eng.tp_stats()
    assert t["tp_degree"] == 2
    assert eng.stats()["tp_degree"] == 2
    assert t["shard_page_bytes"] * 2 == t["page_bytes"]
    assert t["shard_kv_bytes_read"] > 0
    assert t["table_bytes_replicated"] > 0
    snap = eng.metrics_snapshot()
    assert snap["serve_tp_degree"]["value"] == 2


# ===========================================================================
# kernel-level property sweep: random shapes, sharded == unsharded bitwise
# ===========================================================================

def _random_paged(rng, tp, hkv_mult, gqa, n_rows, d=8, page_size=4,
                  n_pages=24, n_max=6):
    """Random head-sharded-compatible paged attention inputs: Hkv a
    multiple of tp, Hq = Hkv * gqa, block tables drawing distinct pages
    (page 0 reserved null, as the engine lays it out)."""
    hkv = tp * hkv_mult
    hq = hkv * gqa
    kp = jnp.asarray(rng.standard_normal((n_pages, page_size, hkv, d)),
                     jnp.float32)
    vp = jnp.asarray(rng.standard_normal((n_pages, page_size, hkv, d)),
                     jnp.float32)
    tables = np.zeros((n_rows, n_max), np.int32)
    lens = np.zeros((n_rows,), np.int32)
    for r in range(n_rows):
        n = int(rng.integers(1, n_max * page_size + 1))
        lens[r] = n
        need = -(-n // page_size)
        tables[r, :need] = rng.choice(
            np.arange(1, n_pages), size=need, replace=False)
    return hq, kp, vp, jnp.asarray(tables), jnp.asarray(lens)


def _decode_kernel_bitwise(hkv_mult, gqa, n_rows, seed):
    """paged_flash_decode under the head-sharded wrapper == unsharded,
    BITWISE, across random head counts / GQA ratios / batch sizes /
    page layouts (float32)."""
    from repro.kernels import ops
    tp = 2
    rng = np.random.default_rng(seed)
    hq, kp, vp, tables, lens = _random_paged(rng, tp, hkv_mult, gqa,
                                             n_rows)
    q = jnp.asarray(rng.standard_normal((n_rows, 1, hq, 8)), jnp.float32)
    mesh = make_serve_mesh(tp)
    o_ref = ops.paged_flash_decode(q, kp, vp, tables, lens)
    o_tp = ops.paged_flash_decode(q, kp, vp, tables, lens, tp_mesh=mesh)
    assert o_tp.dtype == o_ref.dtype and o_tp.shape == o_ref.shape
    assert bool(jnp.all(o_tp == o_ref)), \
        float(jnp.abs(o_tp - o_ref).max())


def _chunk_kernel_bitwise(hkv_mult, gqa, n_rows, ragged, seed):
    """batched_paged_prefill_attention (the chunk AND verify kernel -
    `ragged` exercises the q_lens verify path) under the head-sharded
    wrapper == unsharded, bitwise, across random chunk packings."""
    from repro.kernels import ops
    tp = 2
    s = 8
    rng = np.random.default_rng(seed)
    hq, kp, vp, tables, lens = _random_paged(rng, tp, hkv_mult, gqa,
                                             n_rows)
    # chunk rows sit at the tail of each row's span: offset + S <= len
    # is not required (the kernel masks by true_lens), so offsets may
    # overhang short rows exactly like a padded final chunk does
    offs = jnp.asarray(np.maximum(np.asarray(lens) - s, 0), jnp.int32)
    q = jnp.asarray(rng.standard_normal((n_rows, s, hq, 8)), jnp.float32)
    q_lens = jnp.asarray(rng.integers(1, s + 1, size=n_rows), jnp.int32) \
        if ragged else None
    mesh = make_serve_mesh(tp)
    o_ref = ops.batched_paged_prefill_attention(q, kp, vp, tables, offs,
                                                lens, q_lens)
    o_tp = ops.batched_paged_prefill_attention(q, kp, vp, tables, offs,
                                               lens, q_lens, tp_mesh=mesh)
    assert bool(jnp.all(o_tp == o_ref)), \
        float(jnp.abs(o_tp - o_ref).max())


# seeded non-hypothesis sweep: the kernel parity always runs multi-device,
# even where requirements-dev.txt (hypothesis) is not installed
@multi_device
@pytest.mark.parametrize("hkv_mult,gqa,n_rows,seed",
                         [(1, 1, 1, 0), (1, 2, 2, 1), (2, 2, 3, 2)])
def test_tp_decode_kernel_bitwise_seeded(hkv_mult, gqa, n_rows, seed):
    _decode_kernel_bitwise(hkv_mult, gqa, n_rows, seed)


@multi_device
@pytest.mark.parametrize("hkv_mult,gqa,n_rows,ragged,seed",
                         [(1, 1, 1, False, 3), (1, 2, 2, True, 4),
                          (2, 1, 3, True, 5)])
def test_tp_chunk_kernel_bitwise_seeded(hkv_mult, gqa, n_rows, ragged,
                                        seed):
    _chunk_kernel_bitwise(hkv_mult, gqa, n_rows, ragged, seed)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # requirements-dev.txt extra; seeded sweep above
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @multi_device
    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 2), st.integers(1, 2), st.integers(1, 3),
           st.integers(0, 2 ** 31 - 1))
    def test_tp_decode_kernel_bitwise_property(hkv_mult, gqa, n_rows,
                                               seed):
        _decode_kernel_bitwise(hkv_mult, gqa, n_rows, seed)

    @multi_device
    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 2), st.integers(1, 2), st.integers(1, 3),
           st.booleans(), st.integers(0, 2 ** 31 - 1))
    def test_tp_chunk_kernel_bitwise_property(hkv_mult, gqa, n_rows,
                                              ragged, seed):
        _chunk_kernel_bitwise(hkv_mult, gqa, n_rows, ragged, seed)
else:
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(pip install -r requirements-dev.txt)")
    def test_tp_kernel_bitwise_property():
        pass
