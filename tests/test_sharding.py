import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_debug_mesh
from repro.launch.specs import sanitize, sds
from repro.sharding.rules import param_spec


def test_param_spec_rules():
    names = ("data", "model")
    assert param_spec("tok/embed", 2, names) == P("model", ("data",))
    assert param_spec("blocks/attn/wq", 3, names) == P(None, ("data",), "model")
    assert param_spec("blocks/attn/wo", 3, names) == P(None, "model", ("data",))
    assert param_spec("blocks/moe/experts_in", 4, names) == \
        P(None, "model", ("data",), None)
    assert param_spec("blocks/n1/scale", 2, names) == P()


def test_param_spec_multipod():
    names = ("pod", "data", "model")
    spec = param_spec("blocks/mlp/w_in", 3, names)
    # FSDP shards weights over BOTH pod and data axes (512-way)
    assert spec == P(None, ("pod", "data"), "model")


def test_sanitize_drops_nondivisible():
    mesh = make_debug_mesh(1, 1)
    sh = NamedSharding(mesh, P("data", "model"))
    spec = sds((3, 5), jnp.float32)              # neither divisible by... 1
    fixed = sanitize(sh, spec, mesh)
    assert fixed.spec == P("data", "model")      # 1 divides everything
    # now a fake 2-way mesh requirement via odd dims: use mesh of size 1 ok


def test_constrain_noop_without_mesh():
    from repro.sharding import constrain
    x = jnp.ones((2, 4, 8))
    y = constrain(x, "btd")
    assert y.shape == x.shape
