import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_MODULES, get_smoke_config
from repro.models import build_model


def make_batch(cfg, key, B=2, S=24):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.family == "audio":
        batch["audio_embeds"] = jax.random.normal(
            key, (B, S, cfg.d_model)).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", list(ARCH_MODULES))
def test_forward_shapes_and_finite(arch, rng):
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(rng)
    B, S = 2, 24
    batch = make_batch(cfg, rng, B, S)
    logits, aux = jax.jit(lambda p, b: m.forward(p, b))(params, batch)
    exp_S = S + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", list(ARCH_MODULES))
def test_train_step_smoke(arch, rng):
    """One forward/backward/update step on CPU: finite loss + grads."""
    from repro.configs.base import TrainConfig
    from repro.train.train_step import init_train_state, make_train_step
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    tcfg = TrainConfig(global_batch=2, seq_len=24, total_steps=4,
                       warmup_steps=1)
    state = init_train_state(m, rng, tcfg)
    step = jax.jit(make_train_step(m, tcfg))
    batch = make_batch(cfg, rng, 2, 24)
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    assert int(state.opt.step) == 1


@pytest.mark.parametrize("arch", ["granite-3-2b", "gemma3-4b", "zamba2-2.7b",
                                  "rwkv6-1.6b", "olmoe-1b-7b", "whisper-base"])
def test_prefill_decode_consistency(arch, rng):
    """Teacher-forced prefill logits match full forward at the last position."""
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(rng)
    B, S = 2, 16
    batch = make_batch(cfg, rng, B, S)
    logits_full, _ = m.forward(params, batch)
    cache = m.init_cache(B, 48, enc_len=S)
    last, cache, lens = m.prefill(params, batch, cache)
    ref_last = logits_full[:, -1]
    got = last[:, -1] if last.ndim == 3 else last
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref_last, np.float32),
                               atol=0.08, rtol=0.05)
    # and one decode step runs
    lg, cache = m.decode_step(params, batch["tokens"][:, :1], lens, cache)
    assert bool(jnp.isfinite(lg).all())
