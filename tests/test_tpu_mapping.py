"""Tests for the latency-balanced Pallas block chooser (the paper\'s
scheduling criterion applied to MXU/VPU stage latencies)."""
import pytest

from repro.core.tpu_mapping import (BlockConfig, choose_block_config,
                                    stage_latencies, vmem_working_set)


@pytest.mark.parametrize("hd", [64, 128, 256])
@pytest.mark.parametrize("seq", [2048, 32768])
def test_chooser_returns_valid_config(hd, seq):
    bc = choose_block_config(hd, seq)
    assert bc.block_q % 128 == 0 and bc.block_kv % 128 == 0
    assert bc.block_q <= max(seq, 128) and bc.block_kv <= max(seq, 128)
    assert bc.vmem_bytes <= 32 * 1024 * 1024
    assert bc.bubble_free            # DMA hidden under compute


def test_stage_structure_mirrors_paper_tiers():
    names = [n for n, _ in stage_latencies(256, 512, 128)]
    assert names == ["qk", "rowmax", "expsum", "pv"]   # the 4 tiers


def test_bigger_blocks_better_balance_for_small_heads():
    """For small head_dim the VPU (exp) stage dominates; the chooser should
    not pick degenerate tiny blocks."""
    bc = choose_block_config(64, 8192)
    assert bc.block_q * bc.block_kv >= 128 * 128


def test_vmem_grows_with_blocks():
    assert vmem_working_set(256, 512, 128) < vmem_working_set(512, 1024, 128)
