"""Device-side sampling stack (serve/sampling.py): unit + property tests.

Two layers: deterministic property checks that always run (the sampling
stack is load-bearing for the serve path, so it must be tested even
where hypothesis is not installed), and randomized hypothesis versions
of the same properties that run when it is (`pip install -r
requirements-dev.txt`)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.sampling import (NEG_INF, apply_top_k, apply_top_p,
                                  sample, sample_chain, speculative_accept)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
except ImportError:
    HAVE_HYP = False


def _rows(seed, shape):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


# ===========================================================================
# top-k: support-set correctness
# ===========================================================================

def _check_top_k(logits: np.ndarray, k: int):
    out = np.asarray(apply_top_k(jnp.asarray(logits), k))
    V = logits.shape[-1]
    for row_in, row_out in zip(logits.reshape(-1, V), out.reshape(-1, V)):
        kept = row_out > NEG_INF / 2
        if k <= 0 or k >= V:
            assert kept.all()                      # filter disabled
            np.testing.assert_array_equal(row_out, row_in)
            continue
        kth = np.sort(row_in)[V - k]
        # support = exactly the logits >= the k-th largest (ties kept)
        np.testing.assert_array_equal(kept, row_in >= kth)
        # surviving logits pass through unchanged
        np.testing.assert_array_equal(row_out[kept], row_in[kept])
        assert kept.sum() >= k                     # ties can only widen


def test_top_k_support():
    logits = _rows(0, (4, 16))
    for k in (0, 1, 3, 15, 16, 99):
        _check_top_k(logits, k)


def test_top_k_ties_kept():
    row = np.array([[1.0, 5.0, 5.0, 0.0]], np.float32)
    out = np.asarray(apply_top_k(jnp.asarray(row), 1))
    assert (out[0] > NEG_INF / 2).sum() == 2       # both 5.0s survive


# ===========================================================================
# top-p: nucleus correctness + renormalization
# ===========================================================================

def _check_top_p(logits: np.ndarray, p: float):
    out = np.asarray(apply_top_p(jnp.asarray(logits), p))
    V = logits.shape[-1]
    for row_in, row_out in zip(logits.reshape(-1, V), out.reshape(-1, V)):
        kept = row_out > NEG_INF / 2
        assert kept[np.argmax(row_in)]             # argmax always survives
        np.testing.assert_array_equal(row_out[kept], row_in[kept])
        order = np.argsort(-row_in, kind="stable")
        probs = jax.nn.softmax(jnp.asarray(row_in))
        cum = np.cumsum(np.asarray(probs)[order])
        # kept set is a prefix of the descending sort whose mass BEFORE
        # each kept element is < p (the standard nucleus rule)
        in_prefix = (cum - np.asarray(probs)[order]) < p
        want = np.zeros(V, bool)
        want[order[in_prefix]] = True
        # fp-tolerant comparison at the nucleus boundary: logits tied
        # with the boundary element may legitimately differ in sort order
        boundary = row_in[order[in_prefix]].min()
        disputed = np.abs(row_in - boundary) <= 1e-6
        np.testing.assert_array_equal(kept[~disputed], want[~disputed])


def test_top_p_support():
    logits = _rows(1, (4, 16))
    for p in (0.1, 0.5, 0.9, 1.0):
        _check_top_p(logits, p)


def test_top_p_renormalizes():
    """softmax after the filter = the kept probs renormalized to 1."""
    row = jnp.asarray(_rows(2, (1, 12)))
    out = apply_top_p(row, 0.7)
    kept = np.asarray(out[0]) > NEG_INF / 2
    probs = np.asarray(jax.nn.softmax(out[0]))
    assert abs(probs.sum() - 1.0) < 1e-6
    assert probs[~kept].max(initial=0.0) < 1e-12   # dropped mass is gone
    raw = np.asarray(jax.nn.softmax(row[0]))
    np.testing.assert_allclose(probs[kept], raw[kept] / raw[kept].sum(),
                               rtol=1e-5)


# ===========================================================================
# sample(): greedy limit, reproducibility, jit parity
# ===========================================================================

def test_greedy_is_temperature_zero_limit():
    """As temperature -> 0 the sampled token converges to argmax, and
    temperature <= 0 IS the argmax path (key ignored)."""
    logits = jnp.asarray(_rows(3, (5, 32)))
    key = jax.random.PRNGKey(0)
    greedy = sample(logits, temperature=0.0)
    assert greedy.shape == (5,)
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.asarray(jnp.argmax(logits, -1)))
    cold = sample(logits, key, temperature=1e-4)
    np.testing.assert_array_equal(np.asarray(cold), np.asarray(greedy))


def test_sample_fixed_seed_reproducible_and_jit_identical():
    logits = jnp.asarray(_rows(4, (6, 64)))
    key = jax.random.PRNGKey(42)
    kw = dict(temperature=0.9, top_k=10, top_p=0.9)
    eager1 = sample(logits, key, **kw)
    eager2 = sample(logits, key, **kw)
    jitted = jax.jit(lambda l, k: sample(l, k, **kw))(logits, key)
    np.testing.assert_array_equal(np.asarray(eager1), np.asarray(eager2))
    np.testing.assert_array_equal(np.asarray(eager1), np.asarray(jitted))


def test_sample_respects_filters():
    """Sampled tokens always come from the filtered support."""
    logits = jnp.asarray(_rows(5, (8, 32)))
    for seed in range(5):
        toks = np.asarray(sample(logits, jax.random.PRNGKey(seed),
                                 temperature=1.5, top_k=4))
        filt = np.asarray(apply_top_k(logits, 4))
        for b, t in enumerate(toks):
            assert filt[b, t] > NEG_INF / 2


def test_sample_chain_per_position_independent():
    """sample_chain: (K, S, V) -> (K, S), fixed-seed reproducible, each
    position in the filtered support, greedy at temperature 0."""
    logits = jnp.asarray(_rows(6, (3, 5, 16)))
    key = jax.random.PRNGKey(1)
    kw = dict(temperature=1.0, top_k=6, top_p=0.95)
    t1 = np.asarray(sample_chain(logits, key, **kw))
    t2 = np.asarray(sample_chain(logits, key, **kw))
    assert t1.shape == (3, 5)
    np.testing.assert_array_equal(t1, t2)
    g = np.asarray(sample_chain(logits, key, temperature=0.0))
    np.testing.assert_array_equal(g, np.asarray(jnp.argmax(logits, -1)))


# ===========================================================================
# speculative acceptance
# ===========================================================================

def _accept_oracle(target, draft, m):
    """Python reference: longest matching prefix, then the target's own
    token at the first mismatch (the bonus)."""
    n = 0
    while n < m and target[n] == draft[n + 1]:
        n += 1
    return n, target[n]


def test_speculative_accept_matches_oracle():
    rng = np.random.default_rng(7)
    K, S = 16, 6
    target = rng.integers(0, 4, size=(K, S)).astype(np.int32)
    draft = rng.integers(0, 4, size=(K, S)).astype(np.int32)
    lens = rng.integers(0, S, size=(K,)).astype(np.int32)
    n_acc, bonus = speculative_accept(jnp.asarray(target),
                                      jnp.asarray(draft),
                                      jnp.asarray(lens))
    for r in range(K):
        n, b = _accept_oracle(target[r], draft[r], int(lens[r]))
        assert int(n_acc[r]) == n
        assert int(bonus[r]) == b
        assert 0 <= n <= int(lens[r])


def test_speculative_accept_full_and_zero():
    # full acceptance: draft[1:] echoes target -> n_acc = m, bonus is the
    # target's token one past the chain
    target = jnp.asarray([[7, 8, 9, 1]], jnp.int32)
    draft = jnp.asarray([[5, 7, 8, 9]], jnp.int32)   # [pending, d1..d3]
    n, b = speculative_accept(target, draft, jnp.asarray([3]))
    assert int(n[0]) == 3 and int(b[0]) == 1
    # zero acceptance: first draft token wrong -> bonus = target[0]
    draft0 = jnp.asarray([[5, 0, 8, 9]], jnp.int32)
    n, b = speculative_accept(target, draft0, jnp.asarray([3]))
    assert int(n[0]) == 0 and int(b[0]) == 7
    # m = 0 (no draft): plain decode - bonus is target[0]
    n, b = speculative_accept(target, draft, jnp.asarray([0]))
    assert int(n[0]) == 0 and int(b[0]) == 7


# ===========================================================================
# hypothesis layer (randomized versions of the same properties)
# ===========================================================================

if HAVE_HYP:
    finite_rows = st.integers(0, 2**31 - 1).map(
        lambda s: _rows(s, (3, 24)))

    @given(finite_rows, st.integers(-2, 30))
    def test_hyp_top_k_support(rows, k):
        _check_top_k(rows, k)

    @given(finite_rows, st.floats(0.05, 1.0))
    def test_hyp_top_p_support(rows, p):
        _check_top_p(rows, p)

    @given(finite_rows, st.integers(0, 2**31 - 1))
    def test_hyp_greedy_limit(rows, seed):
        logits = jnp.asarray(rows)
        cold = sample(logits, jax.random.PRNGKey(seed), temperature=1e-4)
        np.testing.assert_array_equal(np.asarray(cold),
                                      np.asarray(jnp.argmax(logits, -1)))

    @given(st.integers(0, 2**31 - 1), st.integers(1, 8))
    def test_hyp_accept_oracle(seed, S):
        rng = np.random.default_rng(seed)
        target = rng.integers(0, 3, size=(4, S)).astype(np.int32)
        draft = rng.integers(0, 3, size=(4, S)).astype(np.int32)
        lens = rng.integers(0, S, size=(4,)).astype(np.int32)
        n_acc, bonus = speculative_accept(jnp.asarray(target),
                                          jnp.asarray(draft),
                                          jnp.asarray(lens))
        for r in range(4):
            n, b = _accept_oracle(target[r], draft[r], int(lens[r]))
            assert (int(n_acc[r]), int(bonus[r])) == (n, b)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hyp_sampling_properties():
        pass
