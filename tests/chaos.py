"""Deterministic fault-injection (chaos) harness for the fleet router.

Extends the traffic-replay harness (tests/traffic.py) with a seeded,
replayable fault schedule: a FaultPlan names exactly WHICH fault hits
WHICH replica at WHICH fleet tick, so every chaos scenario is a plain
deterministic test - no wall-clock, no racing threads, no flaky sleeps.
The fault vocabulary covers the failure modes the router's lifecycle
machinery exists for:

  kill           replica declared DEAD (FleetRouter.fail): queued and
                 in-flight requests redispatch to survivors through the
                 resume path
  drain          replica stops taking new work and empties in place
  undrain        drained replica rejoins dispatch rotation
  stuck          the replica's tick() is stubbed to a no-op, freezing its
                 work clock while it still holds work - the shape the
                 tick watchdog exists to catch (requires
                 FleetConfig.watchdog_ticks > 0 to self-heal)
  unstick        restore the stubbed tick()
  pool_squeeze   quarantine N free pages in the replica's allocator
                 (sanctioned exhaustion: invariants stay assertable)
  pool_restore   release every quarantined page back to the pool

replay_fleet_chaos() drives a fleet through a timed-arrival trace while
applying the plan, asserting the full invariant suite EVERY tick:
router/engine invariants on survivors, per-replica work-clock
monotonicity, and no duplicated terminal requests.  After the drain it
asserts the request ledger is complete (every submitted fleet uid went
terminal - done, timeout, or failed; nothing lost) and page conservation
on survivors.  Conformance on top of that is the caller's one-liner:
assert_chaos_conformance() checks every request that finished DONE
produced output identical to a fault-free run of the same trace.

The harness is tp-degree agnostic: a fleet of head-sharded replicas
(ServeConfig.tp_degree > 1, docs/tensor_parallel.md) runs the same fault
vocabulary unchanged, and the per-tick engine invariant sweep then also
cross-checks every survivor's per-shard KV byte accounting against its
allocator's page counter (ServeEngine.check_invariants).
"""
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.router import FleetRouter, ReplicaState
from repro.serve.scheduler import Request, TERMINAL_STATES
from traffic import (TrafficItem, assert_fleet_pages_drained,
                     assert_greedy_equivalent)

FAULT_KINDS = ("kill", "drain", "undrain", "stuck", "unstick",
               "pool_squeeze", "pool_restore")


@dataclass(frozen=True)
class Fault:
    """One scheduled fault: `kind` hits `replica` at fleet tick `tick`
    (applied just before that tick runs).  `pages` only matters for
    pool_squeeze (how many free pages to quarantine)."""
    tick: int
    kind: str
    replica: int
    pages: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")


@dataclass
class FaultPlan:
    """A deterministic fault schedule.  Faults apply in (tick, list
    order); the same plan over the same trace replays bit-identically."""
    faults: List[Fault] = field(default_factory=list)
    seed: Optional[int] = None     # provenance stamp for random plans

    def at_tick(self, tick: int) -> List[Fault]:
        return [f for f in self.faults if f.tick == tick]

    def max_tick(self) -> int:
        return max((f.tick for f in self.faults), default=0)


def apply_fault(router: FleetRouter, fault: Fault,
                saved_ticks: Dict[int, Callable]):
    """Apply one fault to the fleet.  `saved_ticks` carries the original
    tick() bound methods of stuck replicas so unstick can restore them."""
    eng = router.engines[fault.replica]
    if fault.kind == "kill":
        router.fail(fault.replica)
    elif fault.kind == "drain":
        router.drain(fault.replica)
    elif fault.kind == "undrain":
        router.undrain(fault.replica)
    elif fault.kind == "stuck":
        if fault.replica not in saved_ticks:
            saved_ticks[fault.replica] = eng.tick
            eng.tick = lambda: []          # work clock freezes, work stays
    elif fault.kind == "unstick":
        orig = saved_ticks.pop(fault.replica, None)
        if orig is not None:
            eng.tick = orig
    elif fault.kind == "pool_squeeze":
        if eng.paged:
            eng.allocator.quarantine(fault.pages)
    elif fault.kind == "pool_restore":
        if eng.paged:
            eng.allocator.release_quarantine()


def replay_fleet_chaos(router: FleetRouter, items: Sequence[TrafficItem],
                       plan: FaultPlan, max_ticks: int = 50_000,
                       check: bool = True
                       ) -> Tuple[Dict[int, List[int]], List[Request]]:
    """Drive a FleetRouter through a timed-arrival trace while applying
    `plan`, asserting the invariant suite after every tick:

      - FleetRouter.check_invariants(): survivor engine invariants
        (refcount conservation, table mirroring, prefix trees) plus the
        router's placement/dispatch/redispatch ledger
      - work-clock monotonicity per live replica (never goes backward)
      - no duplicated terminal requests (each fleet uid finishes once)

    After the drain: every submitted fleet uid is terminal (done /
    timeout / failed - no request lost), and survivors' pools hold only
    their prefix trees' pages.  Returns ({fleet uid: out_tokens},
    terminal Requests in completion order)."""
    pending_q = sorted(items, key=lambda it: it.tick)
    saved_ticks: Dict[int, Callable] = {}
    done: List[Request] = []
    seen_terminal: set = set()
    last_work = [0] * len(router.engines)
    tick = 0
    while pending_q or not router.idle or tick <= plan.max_tick():
        for fault in plan.at_tick(tick):
            apply_fault(router, fault, saved_ticks)
        while pending_q and pending_q[0].tick <= tick:
            item = pending_q.pop(0)
            item.uid = router.submit(item.prompt,
                                     max_new_tokens=item.max_new,
                                     stop_tokens=item.stop_tokens,
                                     priority=item.priority,
                                     deadline=item.deadline,
                                     max_retries=item.max_retries)
        finished = router.tick()
        done.extend(finished)
        if check:
            router.check_invariants()
            for fuid in (r.fleet_uid for r in finished):
                assert fuid not in seen_terminal, \
                    f"fleet uid {fuid} went terminal twice"
                seen_terminal.add(fuid)
            for i, eng in enumerate(router.engines):
                if router.states[i] is ReplicaState.DEAD:
                    continue
                wc = eng.sched.work_clock
                assert wc >= last_work[i], \
                    f"replica {i} work clock went backward: " \
                    f"{last_work[i]} -> {wc}"
                last_work[i] = wc
        tick += 1
        if tick >= max_ticks:
            raise RuntimeError(
                f"replay_fleet_chaos: {max_ticks} ticks exhausted; "
                f"statuses: {router.statuses()}")
    if check:
        statuses = router.statuses()
        stuck = {f: s for f, s in statuses.items()
                 if router.requests[f].state not in TERMINAL_STATES}
        assert not stuck, f"requests lost (never terminal): {stuck}"
        assert_fleet_pages_drained(router)
    return {r.fleet_uid: list(r.out_tokens) for r in done}, done


def assert_chaos_conformance(model, params, router: FleetRouter,
                             done: List[Request],
                             baseline: Dict[int, List[int]]):
    """The chaos differential: every request the faulted fleet finished
    DONE must have produced output identical to the fault-free baseline
    run of the same trace (bit-equality fast path, teacher-forced
    near-tie fallback).  TIMEOUT / FAILED requests are excluded - their
    contract is clean terminal accounting, not completion."""
    statuses = router.statuses()
    done_uids = {f for f, s in statuses.items() if s == "done"}
    assert done_uids <= baseline.keys(), \
        f"faulted run finished unknown uids: {done_uids - baseline.keys()}"
    got = {f: o for f, o in router.outputs().items() if f in done_uids}
    want = {f: baseline[f] for f in done_uids}
    if got != want:
        survivors = [r for r in done if r.fleet_uid in done_uids]
        assert_greedy_equivalent(model, params, survivors, want)
    return done_uids


def random_fault_plan(seed: int, n_replicas: int, max_tick: int = 20,
                      n_faults: int = 3,
                      kinds: Sequence[str] = ("kill", "drain",
                                              "pool_squeeze"),
                      squeeze_pages: int = 8) -> FaultPlan:
    """A seeded random FaultPlan that always leaves at least one replica
    HEALTHY and never drains/kills the designated survivor - so every
    soak iteration can complete (the dispatch path always has a target).
    Kills are permanent; drains get a paired undrain a few ticks later
    half the time; squeezes always get a paired restore."""
    rng = np.random.default_rng(seed)
    survivor = int(rng.integers(0, n_replicas))
    victims = [i for i in range(n_replicas) if i != survivor]
    faults: List[Fault] = []
    dead: set = set()
    for _ in range(n_faults):
        kind = str(rng.choice(list(kinds)))
        pool = [v for v in victims if v not in dead]
        if not pool:
            break
        victim = int(rng.choice(pool))
        tick = int(rng.integers(1, max_tick + 1))
        if kind == "kill":
            faults.append(Fault(tick, "kill", victim))
            dead.add(victim)
        elif kind == "drain":
            faults.append(Fault(tick, "drain", victim))
            if rng.random() < 0.5:
                faults.append(Fault(tick + int(rng.integers(2, 8)),
                                    "undrain", victim))
        elif kind == "pool_squeeze":
            faults.append(Fault(tick, "pool_squeeze", victim,
                                pages=squeeze_pages))
            faults.append(Fault(tick + int(rng.integers(2, 8)),
                                "pool_restore", victim))
    faults.sort(key=lambda f: f.tick)
    return FaultPlan(faults=faults, seed=seed)
