"""Engine-wide telemetry: metrics registry, span tracer, per-launch
data-movement attribution, and the Chrome-trace exporter.

Covers: registry semantics (exactly-once registration with a mandatory
help string, counter monotonicity, cumulative histogram buckets, labeled
children, JSON snapshot and Prometheus text exposition), the doc-coverage
check (every metric an engine registers must be documented in
docs/observability.md), tracer determinism (two replays of the same
seeded trace produce bit-identical work-clock span sequences), the
zero-overhead guarantee (telemetry on vs off: bit-identical greedy
outputs and identical per-tick jit-call / host-sync dispatch accounting),
Chrome trace-event schema validation for both the wall and the work
clock, launch-record KV-page accounting against the PageAllocator (the
block-table-derived per-launch counts must sum exactly to the engine's
analytic kv_pages_read counter), the movement-breakdown byte model,
preempt/resume lifecycle instants, the speculative counters, and the
legacy launch_log / stats() compatibility views.
"""
import json
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.serve import ServeEngine
from repro.serve.telemetry import (TRACK_ENGINE, TRACK_QUEUE, Counter,
                                   Gauge, Histogram, LaunchRecord,
                                   MetricError, MetricsRegistry, Span,
                                   SpanTracer, Telemetry, TickRecord,
                                   TraceEvent, export_chrome_trace,
                                   movement_breakdown)

from traffic import mixed_prompts, priority_burst, replay, serve_all

PAGE = 8
DOCS = Path(__file__).resolve().parent.parent / "docs"


@pytest.fixture(scope="module")
def model_f32():
    # float32 keeps greedy argmax ties out of the parity comparisons
    cfg = get_smoke_config("granite-3-2b").replace(dtype="float32")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _base(**over):
    base = dict(max_batch=3, max_seq=256, max_new_tokens=6, paged=True,
                page_size=PAGE, num_pages=3 * 29 + 1, chunked=True,
                prefill_chunk=16, tick_token_budget=32,
                prefix_cache=True)
    base.update(over)
    return ServeConfig(**base)


@pytest.fixture(scope="module")
def traced_run(model_f32):
    """One mixed trace served with span tracing ON - shared by the
    schema / accounting / catalog tests (read-only for all of them)."""
    model, params = model_f32
    prompts = mixed_prompts(model.cfg.vocab_size)
    outs, eng = serve_all(model, params, _base(telemetry=True), prompts,
                          check=True)
    return eng, outs, prompts


# ===========================================================================
# metrics registry semantics
# ===========================================================================

def test_registry_exactly_once_and_help_required():
    reg = MetricsRegistry()
    reg.counter("a_total", "help text")
    with pytest.raises(MetricError):
        reg.counter("a_total", "again")            # duplicate name
    with pytest.raises(MetricError):
        reg.gauge("a_total", "kind change is still a duplicate")
    with pytest.raises(MetricError):
        reg.counter("b_total", "")                 # help is mandatory
    with pytest.raises(MetricError):
        reg.counter("b_total", "   ")
    with pytest.raises(MetricError):
        reg.counter("bad-name!", "punctuation is not a metric name")
    assert "a_total" in reg and "b_total" not in reg


def test_counter_is_monotone():
    c = Counter("c_total", "h")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(MetricError):
        c.inc(-1)
    c.set_total(9)                                 # legacy write-through
    with pytest.raises(MetricError):
        c.set_total(3)                             # never backwards
    assert c.value == 9


def test_gauge_set_and_watermark():
    g = Gauge("g", "h")
    g.set(7)
    g.set(3)
    assert g.value == 3
    g.max_update(10)
    g.max_update(4)
    assert g.value == 10


def test_histogram_cumulative_buckets_and_mean():
    h = Histogram("h", "h", buckets=(1, 4, 16))
    for v in (0.5, 2, 3, 20, 100):
        h.observe(v)
    assert h.bucket_counts == [1, 2, 0, 2]         # per-bucket (+Inf last)
    assert h.count == 5
    assert h.sum == pytest.approx(125.5)
    assert h.mean == pytest.approx(125.5 / 5)
    with pytest.raises(MetricError):
        Histogram("e", "h", buckets=())


def test_labeled_children():
    reg = MetricsRegistry()
    g = reg.gauge("depth", "queue depth by priority",
                  labelnames=("priority",))
    g.labels(0).set(3)
    g.labels(5).set(1)
    g.labels(0).set(4)                             # same child, updated
    assert {k: c.value for k, c in g.label_items()} == \
        {("0",): 4, ("5",): 1}
    with pytest.raises(MetricError):
        g.labels(0, "extra")                       # label-arity mismatch


def test_snapshot_and_prometheus_text():
    reg = MetricsRegistry()
    reg.counter("reqs_total", "Requests served").inc(3)
    reg.gauge("depth", "Queue depth", labelnames=("prio",)).labels(2).set(7)
    h = reg.histogram("lat", "Latency", buckets=(1, 2))
    h.observe(0.5)
    h.observe(5)
    snap = reg.snapshot()
    assert snap["reqs_total"] == {"kind": "counter",
                                  "help": "Requests served", "value": 3}
    assert snap["depth"]["value"] == {"prio=2": 7} or \
        snap["depth"]["value"] == {"2": 7}
    assert snap["lat"]["value"]["count"] == 2
    text = reg.prometheus_text()
    assert "# HELP reqs_total Requests served" in text
    assert "# TYPE reqs_total counter" in text
    assert "reqs_total 3" in text
    assert 'depth{prio="2"} 7' in text
    # histogram buckets are CUMULATIVE and close with +Inf == count
    assert 'lat_bucket{le="1"} 1' in text
    assert 'lat_bucket{le="2"} 1' in text
    assert 'lat_bucket{le="+Inf"} 2' in text
    assert "lat_count 2" in text
    assert text.endswith("\n")
    assert reg.catalog() == {"depth": "Queue depth", "lat": "Latency",
                             "reqs_total": "Requests served"}


def test_tracer_is_a_bounded_ring():
    tr = SpanTracer(capacity=4)
    for i in range(10):
        tr.add_event(f"e{i}", "request", 0, i, i, float(i))
    assert len(tr) == 4
    assert tr.dropped == 6
    assert [e.name for e in tr.events()] == ["e6", "e7", "e8", "e9"]
    with pytest.raises(ValueError):
        SpanTracer(capacity=0)


def test_telemetry_facade_noop_without_tracer():
    tm = Telemetry()                               # registry only
    assert not tm.enabled
    tm.request_phase(1, "QUEUED", TRACK_QUEUE, 0, 0)
    tm.request_event(1, "PREEMPT", 0, 0, 0)
    assert tm.open_phases() == {}


# ===========================================================================
# engine registry: exactly-once registration + doc coverage
# ===========================================================================

def test_engine_registers_every_metric_once_with_help(traced_run):
    eng, _, _ = traced_run
    cat = eng.tm.registry.catalog()
    assert len(cat) >= 30
    for name, help_ in cat.items():
        assert help_.strip(), f"metric {name} has an empty help string"
    # one shared registry per engine: every component's prefix shows up
    prefixes = {n.split("_")[0] for n in cat}
    assert {"serve", "sched", "pool", "prefix"} <= prefixes
    # registration is exactly-once by construction - a second engine must
    # be able to build its own registry without tripping the guard
    names = eng.tm.registry.names()
    assert names == sorted(set(names))


def test_every_metric_is_documented(traced_run):
    """Doc-coverage check: docs/observability.md must name every metric
    the engine registers (the catalog is the source of truth, so adding
    a metric without documenting it fails here)."""
    eng, _, _ = traced_run
    text = (DOCS / "observability.md").read_text()
    missing = [n for n in eng.tm.registry.catalog() if f"`{n}`" not in text]
    assert not missing, \
        f"metrics missing from docs/observability.md: {missing}"


def test_standalone_components_get_private_registries():
    """A scheduler / allocator / prefix cache built without an engine must
    each self-register into a private registry (unit tests construct them
    directly) - twice, without duplicate-registration errors."""
    from repro.serve import (PageAllocator, RadixPrefixCache,
                             TokenBudgetScheduler)
    for _ in range(2):
        sched = TokenBudgetScheduler(_base())
        alloc = PageAllocator(16, PAGE, 2, 64)
        cache = RadixPrefixCache(alloc, PAGE)
        assert "sched_ticks_total" in sched.metrics
        assert "pool_free_pages" in alloc.metrics
        assert "prefix_lookups_total" in cache.metrics
        assert cache.metrics is not alloc.metrics is not sched.metrics


# ===========================================================================
# determinism and zero overhead
# ===========================================================================

def test_work_clock_trace_is_deterministic(model_f32):
    """Two replays of the same seeded trace must record bit-identical
    work-clock span sequences (wall stamps excluded by construction)."""
    model, params = model_f32
    prompts = mixed_prompts(model.cfg.vocab_size)
    traces = []
    for _ in range(2):
        _, eng = serve_all(model, params, _base(telemetry=True), prompts)
        traces.append(eng.tm.tracer.deterministic_trace())
    assert traces[0], "tracer recorded nothing"
    assert traces[0] == traces[1]


def test_telemetry_off_is_bit_identical_and_free(model_f32):
    """Span tracing must be observer-only: greedy outputs bit-identical
    and the dispatch accounting (jitted calls and device->host syncs,
    per tick) EXACTLY unchanged with telemetry on vs off."""
    model, params = model_f32
    prompts = mixed_prompts(model.cfg.vocab_size)
    outs_off, eng_off = serve_all(model, params, _base(), prompts)
    outs_on, eng_on = serve_all(model, params, _base(telemetry=True),
                                prompts)
    assert outs_on == outs_off
    # launch_log rows are (jit_calls, host_syncs, host_wall_s, n_chunks,
    # n_decode); compare everything but the wall-time field
    def dispatch(eng):
        return [(t[0], t[1], t[3], t[4]) for t in eng.launch_log]
    assert dispatch(eng_on) == dispatch(eng_off)
    assert eng_on.jit_calls == eng_off.jit_calls
    assert eng_on.host_syncs == eng_off.host_syncs
    # the off engine records no spans and refuses to export a trace
    assert eng_off.tm.tracer is None
    assert not eng_off.scfg.telemetry
    with pytest.raises(ValueError):
        eng_off.export_trace("/dev/null")


# ===========================================================================
# request lifecycle spans
# ===========================================================================

def test_request_lifecycle_spans(traced_run):
    eng, outs, prompts = traced_run
    tr = eng.tm.tracer
    assert eng.tm.open_phases() == {}, "drained trace left open spans"
    spans = tr.spans()
    phases = {}
    for s in spans:
        if s.cat == "request":
            args = dict(s.args)
            phases.setdefault(args["uid"], []).append(args["phase"])
    assert set(phases) == set(outs)
    for uid, seq in phases.items():
        assert seq[0] == "QUEUED", f"uid {uid} did not start QUEUED"
        assert "PREFILLING" in seq and "DECODING" in seq
        # work-clock stamps are monotone within a request's lifecycle
    done_events = [e for e in tr.events() if e.name.endswith(":DONE")]
    assert len(done_events) == len(outs)
    # every span is work-clock-consistent and stamped with its tick
    for s in spans:
        assert s.work1 >= s.work0 >= 0
        assert s.wall1 >= s.wall0 >= 0.0
        assert s.tick >= 0


def test_preempt_resume_events(model_f32):
    """A capacity-capped priority burst must land PREEMPT and RESUME
    instants (and a RESUMING phase span) on the trace."""
    model, params = model_f32
    items = priority_burst(model.cfg.vocab_size, background_lens=(96, 96),
                           burst_lens=(64,), burst_tick=2)
    scfg = ServeConfig(max_batch=3, max_seq=256, max_new_tokens=8,
                       paged=True, page_size=PAGE, num_pages=200,
                       chunked=True, prefill_chunk=16,
                       tick_token_budget=24, preemption=True,
                       max_chunks_per_tick=1, usable_pages=28,
                       telemetry=True)
    eng = ServeEngine(model, params, scfg)
    replay(eng, items)
    assert eng.sched.preemptions >= 1 and eng.sched.resumes >= 1
    names = {e.name.split(":", 1)[1] for e in eng.tm.tracer.events()
             if ":" in e.name}
    assert "PREEMPT" in names and "RESUME" in names
    resuming = [s for s in eng.tm.tracer.spans()
                if s.cat == "request" and dict(s.args).get("phase") ==
                "RESUMING"]
    assert resuming and all(s.track == TRACK_QUEUE for s in resuming)
    assert eng.tm.open_phases() == {}


# ===========================================================================
# Chrome trace-event export (Perfetto)
# ===========================================================================

def _validate_chrome_trace(trace, n_slots):
    assert set(trace) == {"traceEvents", "displayTimeUnit", "otherData"}
    events = trace["traceEvents"]
    assert isinstance(events, list) and events
    for ev in events:
        assert ev["ph"] in ("X", "i", "M"), ev
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        assert isinstance(ev["name"], str) and ev["name"]
        if ev["ph"] == "M":
            assert ev["name"] in ("process_name", "thread_name")
            assert isinstance(ev["args"]["name"], str)
            continue
        assert isinstance(ev["ts"], (int, float)) and ev["ts"] >= 0
        assert isinstance(ev["args"], dict)
        if ev["ph"] == "X":
            assert isinstance(ev["dur"], (int, float)) and ev["dur"] >= 0
        else:
            assert ev["s"] in ("t", "p", "g")      # instant scope
    # metadata must name the engine + requests processes and every track
    meta = {(e["pid"], e["tid"], e["args"]["name"])
            for e in events if e["ph"] == "M"}
    assert (0, 0, "engine") in meta and (1, 0, "requests") in meta
    for slot in range(n_slots):
        assert (1, slot, f"slot{slot}") in meta
    assert (1, n_slots, "queue") in meta


def test_export_trace_is_valid_chrome_json(traced_run, tmp_path):
    eng, _, _ = traced_run
    path = tmp_path / "trace.json"
    returned = eng.export_trace(path)
    on_disk = json.loads(path.read_text())         # must round-trip as JSON
    assert on_disk == json.loads(json.dumps(returned))
    _validate_chrome_trace(on_disk, eng.scfg.max_batch)
    assert on_disk["otherData"]["clock"] == "wall"
    assert on_disk["otherData"]["dropped_records"] == 0


def test_export_trace_work_clock(traced_run, tmp_path):
    """The work-clock export is the deterministic view: every timestamp
    is an integer number of work tokens (1 token == 1 us)."""
    eng, _, _ = traced_run
    path = tmp_path / "trace_work.json"
    trace = eng.export_trace(path, clock="work")
    _validate_chrome_trace(trace, eng.scfg.max_batch)
    assert trace["otherData"]["clock"] == "work"
    for ev in trace["traceEvents"]:
        if ev["ph"] in ("X", "i"):
            assert float(ev["ts"]).is_integer()
    with pytest.raises(ValueError):
        eng.export_trace(path, clock="sundial")


# ===========================================================================
# per-launch movement attribution
# ===========================================================================

KNOWN_KINDS = {"prefill", "prefill_paged", "chunk", "chunk_batch",
               "decode", "spec_verify", "stepwise"}


def test_launch_records_match_page_allocator_accounting(traced_run):
    """The acceptance cross-check: per-launch KV-page counts are derived
    from PageAllocator block-table rows, the engine's kv_pages_read
    counter from the analytic ceil(len / page_size) - the two views of
    the same accounting must agree EXACTLY over the whole trace."""
    eng, _, _ = traced_run
    recs = eng.launch_records()
    assert recs, "no launch records"
    for r in recs:
        assert r.kind in KNOWN_KINDS
        assert 0 <= r.live_rows <= r.rows
        assert 0 <= r.true_tokens <= r.padded_tokens
        assert r.kv_pages_read >= 0 and r.kv_pages_written >= 0
        assert r.tick >= 0 and r.work_clock >= 0
    from_records = sum(r.kv_pages_read for r in recs
                       if r.kind in ("decode", "spec_verify"))
    assert from_records == eng.kv_pages_read, \
        (from_records, eng.kv_pages_read)


def test_movement_breakdown_byte_model(model_f32):
    """Synthetic launch records through the exact byte model: KV pages
    stream page_size tokens of K+V, weights stream once per launch,
    activations move per padded token, SRAM is 2x HBM (single-pass flash
    staging), and energy folds through core/energy.py."""
    import jax.numpy as jnp
    model, _ = model_f32
    cfg, scfg = model.cfg, _base()
    it = jnp.dtype(cfg.dtype).itemsize
    kv_tok = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim * it
    rec = LaunchRecord(tick=0, kind="decode", rows=4, live_rows=2,
                       true_tokens=2, padded_tokens=4, kv_pages_read=5,
                       kv_pages_written=2, new_kv_tokens=2, work_clock=9)
    out = movement_breakdown([rec], cfg, scfg)
    d = out["decode"]
    assert d["kv_read_bytes"] == 5 * PAGE * kv_tok
    assert d["kv_write_bytes"] == 2 * kv_tok
    assert d["weight_bytes"] == cfg.active_param_count() * it
    assert d["act_bytes"] == 4 * 2 * cfg.n_layers * cfg.d_model * it
    assert d["hbm_bytes"] == (d["kv_read_bytes"] + d["kv_write_bytes"]
                              + d["weight_bytes"] + d["act_bytes"])
    assert d["sram_bytes"] == 2 * d["hbm_bytes"]
    assert d["energy_j"] > 0
    assert d["padding_overhead"] == pytest.approx(0.5)
    assert d["hbm_share"] == pytest.approx(1.0)
    assert out["total"]["hbm_bytes"] == d["hbm_bytes"]
    assert movement_breakdown([], cfg, scfg)["total"]["launches"] == 0


def test_movement_stats_over_trace(traced_run):
    eng, _, _ = traced_run
    mv = eng.movement_stats()
    total = mv.pop("total")
    assert total["hbm_bytes"] > 0
    assert total["sram_bytes"] == pytest.approx(2 * total["hbm_bytes"])
    assert 0 <= total["padding_overhead"] < 1
    assert sum(row["hbm_share"] for row in mv.values()) == \
        pytest.approx(1.0)
    assert sum(row["launches"] for row in mv.values()) == \
        total["launches"] == len(eng.launch_records())


# ===========================================================================
# speculative counters
# ===========================================================================

def test_spec_counters_reach_registry(model_f32):
    """drafted == accepted + rejected, the acceptance-ratio histogram
    sees one observation per verified chain, and the registry values
    back the stats() keys the bench prints."""
    model, params = model_f32
    rng = np.random.default_rng(11)
    base = rng.integers(1, model.cfg.vocab_size, size=4).tolist()
    prompts = [base * 6, base * 5]                 # repetitive by design
    scfg = ServeConfig(max_batch=2, max_seq=256, max_new_tokens=48,
                       paged=True, page_size=16, chunked=True,
                       prefill_chunk=16, tick_token_budget=32,
                       speculative=True, spec_k=4, telemetry=True)
    outs, eng = serve_all(model, params, scfg, prompts)
    st = eng.stats()
    reg = eng.tm.registry
    assert st["spec_drafted"] > 0, "drafter never engaged"
    assert st["spec_drafted"] == st["spec_accepted"] + st["spec_rejected"]
    assert st["spec_drafted"] == reg.get("sched_spec_drafted_total").value
    assert st["spec_rejected"] == reg.get("sched_spec_rejected_total").value
    hist = reg.get("sched_spec_chain_accept_ratio")
    assert hist.count > 0
    assert 0.0 <= st["spec_chain_accept_mean"] <= 1.0
    assert st["spec_chain_accept_mean"] == pytest.approx(hist.mean)
    # verify instants carry the per-chain outcome onto the trace
    spec_events = [e for e in eng.tm.tracer.events()
                   if e.name.endswith(":SPEC_VERIFY")]
    assert len(spec_events) == hist.count
    drafted = sum(dict(e.args)["drafted"] for e in spec_events)
    assert drafted == st["spec_drafted"]


# ===========================================================================
# legacy compatibility views
# ===========================================================================

def test_launch_log_and_stats_compat(traced_run):
    """launch_log stays the 5-tuple view PR-4-era consumers read, and
    stats() keeps its flat keys - both now computed from the registry
    and the typed TickRecords."""
    eng, outs, _ = traced_run
    log = eng.launch_log
    assert log and all(len(t) == 5 for t in log)
    assert all(isinstance(t, tuple) for t in log)
    assert sum(t[0] for t in log) == eng.jit_calls
    assert sum(t[1] for t in log) == eng.host_syncs
    assert [t.as_tuple() for t in eng.tm.ticks] == log
    st = eng.stats()
    for key in ("jit_calls", "host_syncs", "prefill_tokens", "gen_tokens",
                "ticks", "chunks_run", "preemptions", "resumes",
                "spec_drafted", "spec_accepted", "spec_rejected",
                "queue_depth", "max_tick_tokens", "compile_count",
                "tbt_work_p95", "telemetry"):
        assert key in st, f"stats() lost key {key}"
    assert st["telemetry"] is True
    assert st["jit_calls"] == eng.jit_calls
    assert st["gen_tokens"] == sum(len(t) for t in outs.values())
    # legacy attribute writes still route through the registry
    reg = eng.tm.registry
    assert eng.jit_calls == reg.get("serve_jit_calls_total").value
    assert eng.peak_pages == reg.get("serve_peak_pages").value
    snap = eng.metrics_snapshot()
    assert snap["serve_jit_calls_total"]["value"] == eng.jit_calls
    prom = eng.prometheus_metrics()
    assert f"serve_jit_calls_total {eng.jit_calls}" in prom
