import os
import sys

# smoke tests and benches must see 1 device (the dry-run sets its own flags
# in-process before importing jax; see src/repro/launch/dryrun.py)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _isolate_mixed_dots_env():
    """repro.launch.dryrun sets REPRO_MIXED_DOTS=1 at import (compile-only
    native mixed-precision dots).  The CPU *runtime* cannot execute those, so
    tests that actually run computations must not inherit the flag."""
    os.environ.pop("REPRO_MIXED_DOTS", None)
    yield
    os.environ.pop("REPRO_MIXED_DOTS", None)
