"""Token-budget scheduler: chunked-vs-monolithic parity on mixed traffic,
budget accounting invariants, decode starvation, lifecycle, admission
policy, temperature plumbing, stop tokens, mid-prompt chunk kernel parity,
and a hypothesis property test over random budgets / chunk sizes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ServeConfig
from repro.kernels import ops
from repro.models import build_model
from repro.serve import ServeEngine, RequestState, TokenBudgetScheduler
from repro.serve.scheduler import Request

# shared traffic-replay harness (tests/traffic.py): seeded generators +
# the serve loop; MIXED_LENS is the acceptance-shape mixed traffic
from traffic import MIXED_LENS, mixed_prompts as _mixed_prompts, \
    serve_all as _serve


@pytest.fixture(scope="module")
def model_f32():
    # float32 keeps greedy argmax ties out of the parity comparisons
    cfg = get_smoke_config("granite-3-2b").replace(dtype="float32")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _base(**over):
    base = dict(max_batch=3, max_seq=256, max_new_tokens=6, paged=True,
                page_size=8, num_pages=3 * 29 + 1)
    base.update(over)
    return ServeConfig(**base)


# ===========================================================================
# parity: chunked scheduling must produce byte-identical greedy outputs
# ===========================================================================

@pytest.mark.parametrize("prefix_cache", [False, True])
def test_chunked_matches_monolithic_mixed_traffic(prefix_cache, model_f32):
    m, params = model_f32
    prompts = _mixed_prompts(m.cfg.vocab_size)
    mono, _ = _serve(m, params, _base(prefix_cache=prefix_cache), prompts)
    chunked, eng = _serve(
        m, params, _base(prefix_cache=prefix_cache, chunked=True,
                         prefill_chunk=16, tick_token_budget=32), prompts)
    assert mono == chunked
    assert eng.allocator.used_pages == 0 if not prefix_cache \
        else eng.allocator.live_pages() == 0
    st = eng.stats()
    assert st["chunks_run"] > len(prompts)        # long prompts chunked
    assert st["max_tick_tokens"] <= 32            # budget is a hard ceiling


def test_chunked_matches_monolithic_windowed_model(rng):
    """Local/global sliding-window layers (gemma3 pattern) through the
    chunked path: the offset-causal kernel's window mask must compose
    across chunk boundaries."""
    cfg = get_smoke_config("gemma3-4b").replace(dtype="float32")
    m = build_model(cfg)
    params = m.init(rng)
    prompts = _mixed_prompts(cfg.vocab_size, lens=(40, 9, 100))
    mono, _ = _serve(m, params, _base(max_batch=2), prompts)
    chunked, _ = _serve(m, params,
                        _base(max_batch=2, chunked=True, prefill_chunk=16,
                              tick_token_budget=32), prompts)
    assert mono == chunked


def test_prefix_cache_composes_with_chunking(model_f32):
    """Warm request publishes its prompt pages; followers skip the cached
    prefix and chunk-prefill only the remainder."""
    m, params = model_f32
    rng = np.random.default_rng(3)
    shared = rng.integers(1, m.cfg.vocab_size, size=64).tolist()
    tails = [rng.integers(1, m.cfg.vocab_size, size=24).tolist()
             for _ in range(3)]
    prompts = [shared + t for t in tails] + [shared]   # last: full cover
    scfg_off = _base(chunked=True, prefill_chunk=16, tick_token_budget=32)
    scfg_on = _base(prefix_cache=True, chunked=True, prefill_chunk=16,
                    tick_token_budget=32)

    def run(scfg):
        eng = ServeEngine(m, params, scfg)
        out = {}
        for wave in ([prompts[0]], prompts[1:]):   # warmup, then followers
            for p in wave:
                eng.submit(p)
            for r in eng.run_until_done(max_ticks=50_000):
                out[r.uid] = r.out_tokens
        return out, eng

    out_off, _ = run(scfg_off)
    out_on, eng = run(scfg_on)
    assert out_on == out_off
    assert eng.prefix_hit_tokens > 0
    assert eng.prefill_tokens < sum(len(p) for p in prompts)
    eng.prefix.check_invariants()


# ===========================================================================
# budget accounting + starvation
# ===========================================================================

def test_budget_accounting_invariants(model_f32):
    """No tick may exceed tick_token_budget, decode slots always consume
    their token, and prefill chunks are governed by prefill_chunk."""
    m, params = model_f32
    budget, chunk = 24, 8
    eng = ServeEngine(m, params, _base(chunked=True, prefill_chunk=chunk,
                                       tick_token_budget=budget))
    for p in _mixed_prompts(m.cfg.vocab_size):
        eng.submit(p)
    eng.run_until_done(max_ticks=50_000)
    assert eng.tick_log, "no ticks recorded"
    for decode_toks, prefill_toks in eng.tick_log:
        assert decode_toks + prefill_toks <= budget
        assert 0 <= decode_toks <= eng.scfg.max_batch
    # every prompt longer than one chunk was split into multiple chunks
    n_long = sum(1 for n in MIXED_LENS if n > chunk)
    assert eng.sched.chunks_run >= n_long + sum(
        1 for n in MIXED_LENS if n <= chunk)
    # total work conserved: every prompt token computed exactly once
    assert eng.prefill_tokens == sum(MIXED_LENS)


def test_decode_never_starves_behind_long_prefill(model_f32):
    """The acceptance property: while a long prompt streams in chunk by
    chunk, every already-decoding slot still produces exactly one token
    per tick (no request-level pipeline bubble)."""
    m, params = model_f32
    eng = ServeEngine(m, params,
                      _base(max_batch=2, chunked=True, prefill_chunk=8,
                            tick_token_budget=16, max_new_tokens=40))
    short = eng.submit([5, 7, 11, 13])
    # let the short request reach DECODING
    while not any(r is not None and r.state is RequestState.DECODING
                  for r in eng.slots):
        eng.tick()
    long_uid = eng.submit(list(range(1, 161)))     # 20 chunks of 8
    saw_prefilling = 0
    while True:
        long_req = next((r for r in list(eng.slots) + eng.queue
                         if r is not None and r.uid == long_uid), None)
        short_req = next((r for r in eng.slots
                          if r is not None and r.uid == short), None)
        if long_req is None or long_req.state is not RequestState.PREFILLING:
            if saw_prefilling:
                break
        if short_req is None:
            break
        before = len(short_req.out_tokens)
        eng.tick()
        if long_req is not None \
                and long_req.state is RequestState.PREFILLING:
            saw_prefilling += 1
            assert len(short_req.out_tokens) == before + 1, \
                "decode slot stalled behind a streaming prefill"
    assert saw_prefilling >= 5    # the long prompt really did stream in


def test_long_prefill_never_starved_by_short_stream(model_f32):
    """The other side of shortest-remaining-first: a sustained stream of
    short newcomers must not stop a long prompt from advancing - the
    oldest prefilling request is guaranteed one chunk every tick."""
    m, params = model_f32
    eng = ServeEngine(m, params,
                      _base(max_batch=4, chunked=True, prefill_chunk=8,
                            tick_token_budget=20, max_new_tokens=2))
    long_uid = eng.submit(list(range(1, 129)))     # 16 chunks of 8
    eng.tick()
    long_req = next(r for r in eng.slots if r is not None)
    while long_req.state is RequestState.PREFILLING:
        eng.submit([1, 2, 3, 4, 5])                # newcomer every tick
        before = long_req.prefill_pos
        eng.tick()
        assert long_req.prefill_pos > before, \
            "oldest prefilling request starved by newcomers"
    assert long_req.uid == long_uid
    eng.run_until_done(max_ticks=10_000)


def test_lifecycle_states(model_f32):
    m, params = model_f32
    eng = ServeEngine(m, params,
                      _base(max_batch=1, chunked=True, prefill_chunk=8,
                            tick_token_budget=9, max_new_tokens=2))
    uid = eng.submit(list(range(1, 33)))           # 4 chunks
    req = eng.queue[0]
    assert req.state is RequestState.QUEUED and req.uid == uid
    eng.tick()
    assert req.state is RequestState.PREFILLING
    assert 0 < req.prefill_pos < len(req.prompt)
    while req.state is RequestState.PREFILLING:
        eng.tick()
    assert req.state is RequestState.DECODING
    assert req.out_tokens and req.prefill_pos == len(req.prompt)
    done = eng.run_until_done()
    assert req.state is RequestState.DONE and req.done
    assert req in done and req.finish_reason == "length"
    # latency accounting recorded for every emitted token
    assert len(req.token_work) == len(req.out_tokens)
    assert req.ttft_work() > 0 and len(req.tbt_work()) == 1


def test_chunked_lowers_stalls_and_short_ttft(model_f32):
    """The acceptance criterion at test scale: on a wave trace (a long
    prompt arriving at the head of each wave with shorts behind it while
    earlier requests decode), chunked scheduling lowers the p95 per-token
    tick-work stall (the deterministic TBT bubble) and the p95 TTFT of
    short prompts - with byte-identical greedy outputs."""
    m, params = model_f32
    rng = np.random.default_rng(1)
    lens = (224, 32, 16)                 # each wave: long first, shorts behind
    arrivals = []
    for w in range(2):
        for n in lens:
            arrivals.append((w * 3, rng.integers(
                1, m.cfg.vocab_size, size=n).tolist()))

    def run(scfg):
        eng = ServeEngine(m, params, scfg)
        pending = list(arrivals)
        tick, done = 0, []
        while pending or eng.queue or any(s is not None for s in eng.slots):
            while pending and pending[0][0] <= tick:
                eng.submit(pending.pop(0)[1])
            done.extend(eng.tick())
            tick += 1
            assert tick < 10_000
        outs = {r.uid: r.out_tokens for r in done}
        shorts = [r.ttft_work() for r in done if len(r.prompt) < max(lens)]
        st = eng.stats()
        return outs, st["stall_work_p95"], float(np.percentile(shorts, 95))

    base = dict(max_batch=6, max_seq=256, max_new_tokens=8, paged=True,
                page_size=8, num_pages=6 * 29 + 1)
    # budget fits the oldest request's guaranteed chunk plus a
    # shortest-remaining-first chunk, so shorts drain past the long
    mono_out, mono_stall, mono_ttft = run(ServeConfig(**base))
    chunk_out, chunk_stall, chunk_ttft = run(
        ServeConfig(**base, chunked=True, prefill_chunk=16,
                    tick_token_budget=40))
    assert chunk_out == mono_out
    assert chunk_stall <= 40 < mono_stall
    assert chunk_stall < mono_stall
    assert chunk_ttft < mono_ttft


# ===========================================================================
# admission policy
# ===========================================================================

@pytest.mark.parametrize("policy,first", [("fifo", "long"),
                                          ("sjf", "short")])
def test_admission_policy_order(policy, first, model_f32):
    m, params = model_f32
    eng = ServeEngine(m, params,
                      _base(max_batch=1, admission_policy=policy))
    uid_long = eng.submit(list(range(1, 100)))
    uid_short = eng.submit([3, 1, 4])
    done = eng.run_until_done()
    order = [r.uid for r in done]
    expect = [uid_long, uid_short] if first == "long" \
        else [uid_short, uid_long]
    assert order == expect


def test_scheduler_plan_chunks_unit():
    """Pure planning: shortest-remaining-first chunk fill under the
    budget, round-robin passes until the budget is spent."""
    scfg = ServeConfig(max_batch=2, prefill_chunk=8, tick_token_budget=64,
                       paged=True, chunked=True, page_size=8)
    sched = TokenBudgetScheduler(scfg)
    a = Request(1, list(range(20)), 4)   # 20 tokens: chunks 8, 8, 4
    b = Request(2, list(range(9)), 4)    # 9 tokens: chunks 8, 1
    tasks = sched.plan_chunks([(0, a), (1, b)], budget=25)
    # a is OLDEST (guaranteed floor chunk), then shortest-remaining-first:
    # pass 1: a[0:8], b[0:8]; pass 2: a[8:16], b's 1-token tail fits last
    got = [(t.req.uid, t.start, t.length) for t in tasks]
    assert got == [(1, 0, 8), (2, 0, 8), (1, 8, 8), (2, 8, 1)]
    assert sum(t.length for t in tasks) == 25
    # a budget too small for any whole chunk schedules nothing
    assert sched.plan_chunks([(0, Request(3, list(range(20)), 4))], 7) == []


def test_config_validation():
    bad = [dict(chunked=True),                                 # not paged
           dict(chunked=True, paged=True, tick_token_budget=512,
                prefill_chunk=13, page_size=8),                # misaligned
           dict(chunked=True, paged=True, prefill_chunk=8, page_size=8,
                max_batch=8, tick_token_budget=8),             # starves
           dict(admission_policy="lifo"),
           dict(temperature=-1.0)]
    for kw in bad:
        with pytest.raises(ValueError):
            ServeConfig(**kw).validate()
    ServeConfig(chunked=True, paged=True, page_size=8, prefill_chunk=16,
                max_batch=4, tick_token_budget=20).validate()


# ===========================================================================
# SLO-driven priority aging
# ===========================================================================

def test_priority_aging_reorders_admission_unit():
    """Pure queue ordering: with priority_aging on, a queued request gains
    +1 effective priority per priority_age_tokens of work-clock age, so an
    old low-priority request outranks a freshly submitted higher class -
    and the boost is counted exactly when the aged admission happens."""
    def fresh(aging):
        return TokenBudgetScheduler(ServeConfig(
            max_batch=1, paged=True, page_size=8,
            priority_aging=aging, priority_age_tokens=10))

    s = fresh(True)
    lo = Request(1, list(range(8)), 2, priority=0)
    s.submit(lo)
    s.note_work(60)                     # lo ages: effective 0 + 60//10 = 6
    hi = Request(2, list(range(8)), 2, priority=5)
    s.submit(hi)                        # fresh: age 0, effective 5
    assert s.effective_priority(lo) == 6
    assert s.effective_priority(hi) == 5
    assert s.peek() is lo
    s.pop(lo)
    assert s.priority_boosts == 1       # admitted above its base class
    s.pop(hi)
    assert s.priority_boosts == 1       # hi admitted at base priority
    # same shape with aging off: the higher class wins, nothing boosts
    s = fresh(False)
    lo = Request(1, list(range(8)), 2, priority=0)
    s.submit(lo)
    s.note_work(60)
    hi = Request(2, list(range(8)), 2, priority=5)
    s.submit(hi)
    assert s.effective_priority(lo) == 0
    assert s.peek() is hi
    s.pop(hi)
    assert s.priority_boosts == 0


def test_priority_aging_bounds_starvation(model_f32):
    """The SLO property end to end: under a sustained stream of fresh
    high-priority arrivals (each starting at age 0 - simultaneous
    submissions age in lockstep, so only a STAGGERED stream exposes
    starvation), a low-priority request's work-clock TTFT is bounded by
    gap * priority_age_tokens plus a couple of service times.  With aging
    off the same trace serves the low request dead last."""
    m, params = model_f32
    rng = np.random.default_rng(9)
    V = m.cfg.vocab_size
    # each request is 16 prompt + 2 generated = 18 work tokens and takes
    # 2 ticks at max_batch=1; one high arrival per 2 ticks saturates the
    # engine, so the low request waits on priority alone
    arrivals = [0, 0] + [2 * i for i in range(1, 23)]      # 24 highs

    def run(priority_aging):
        eng = ServeEngine(m, params, _base(
            max_batch=1, chunked=True, prefill_chunk=16,
            tick_token_budget=17, max_new_tokens=2,
            priority_aging=priority_aging, priority_age_tokens=32))
        low = eng.submit(rng.integers(1, V, size=16).tolist(), priority=0)
        pending = list(arrivals)
        tick, done = 0, []
        while pending or eng.queue or any(s is not None for s in eng.slots):
            while pending and pending[0] <= tick:
                pending.pop(0)
                eng.submit(rng.integers(1, V, size=16).tolist(), priority=5)
            done.extend(eng.tick())
            tick += 1
            assert tick < 10_000
        order = [r.uid for r in done]
        low_req = next(r for r in done if r.uid == low)
        return order, low_req.ttft_work(), eng

    gap, age_tokens, per_req_work = 5, 32, 18
    bound = gap * age_tokens + 3 * per_req_work    # admission + drain slack
    order_on, ttft_on, eng_on = run(True)
    order_off, ttft_off, eng_off = run(False)
    # aging off: every high class request is served first - unbounded wait
    assert order_off[-1] == min(order_off)         # low (uid 1) dead last
    assert ttft_off > bound
    assert eng_off.sched.priority_boosts == 0
    # aging on: the low request jumps the stream inside the bound
    assert order_on.index(min(order_on)) < len(order_on) - 8
    assert ttft_on <= bound, (ttft_on, bound)
    assert eng_on.sched.priority_boosts >= 1
    assert eng_on.stats()["priority_boosts"] >= 1


# ===========================================================================
# temperature plumbing (bugfix: ServeConfig.temperature was ignored)
# ===========================================================================

def test_temperature_zero_stays_greedy(model_f32):
    m, params = model_f32
    prompts = _mixed_prompts(m.cfg.vocab_size, lens=(12, 30))
    greedy, _ = _serve(m, params, _base(max_batch=2), prompts)
    explicit, _ = _serve(m, params, _base(max_batch=2, temperature=0.0),
                         prompts)
    assert greedy == explicit


def test_temperature_seeded_is_reproducible(model_f32):
    m, params = model_f32
    prompts = _mixed_prompts(m.cfg.vocab_size, lens=(12, 30))
    kw = dict(max_batch=2, max_new_tokens=16, temperature=0.9)
    out1, _ = _serve(m, params, _base(seed=7, **kw), prompts)
    out2, _ = _serve(m, params, _base(seed=7, **kw), prompts)
    assert out1 == out2                       # same seed, same trace
    out3, _ = _serve(m, params, _base(seed=8, **kw), prompts)
    assert out1 != out3                       # sampling actually happens
    greedy, _ = _serve(m, params, _base(max_batch=2, max_new_tokens=16),
                       prompts)
    assert out1 != greedy


def test_temperature_chunked_reproducible(model_f32):
    m, params = model_f32
    prompts = _mixed_prompts(m.cfg.vocab_size, lens=(40, 9))
    kw = dict(max_batch=2, temperature=0.7, seed=11, chunked=True,
              prefill_chunk=8, tick_token_budget=16)
    out1, _ = _serve(m, params, _base(**kw), prompts)
    out2, _ = _serve(m, params, _base(**kw), prompts)
    assert out1 == out2


# ===========================================================================
# stop tokens
# ===========================================================================

def test_stop_tokens_finish_early(model_f32):
    m, params = model_f32
    prompts = _mixed_prompts(m.cfg.vocab_size, lens=(20, 33))
    kw = dict(max_batch=2, max_new_tokens=12)
    ref, _ = _serve(m, params, _base(**kw), prompts)
    # pick a token the first request actually generates mid-stream
    uid0 = min(ref)
    stop = ref[uid0][4]
    out, eng = _serve(m, params, _base(**kw), prompts, stop_tokens=[stop])
    for uid, toks in out.items():
        full = ref[uid]
        if stop in full:
            cut = full.index(stop) + 1
            assert toks == full[:cut]          # truncated AT the stop token
        else:
            assert toks == full
    assert any(r.finish_reason == "stop" for r in eng.sched.finished)
    assert eng.allocator.used_pages == 0       # pages freed on early finish


def test_eos_id_config_equivalent_to_stop_tokens(model_f32):
    m, params = model_f32
    prompts = _mixed_prompts(m.cfg.vocab_size, lens=(20,))
    ref, _ = _serve(m, params, _base(max_new_tokens=12), prompts)
    stop = ref[min(ref)][2]
    via_cfg, _ = _serve(m, params, _base(max_new_tokens=12, eos_id=stop),
                        prompts)
    via_submit, _ = _serve(m, params, _base(max_new_tokens=12), prompts,
                           stop_tokens=[stop])
    assert via_cfg == via_submit


def test_stop_tokens_publish_prefix_pages(model_f32):
    """A stop-token finish must still publish prompt pages into the
    prefix cache that tick (not leak or skip them)."""
    m, params = model_f32
    eng = ServeEngine(m, params, _base(prefix_cache=True, max_new_tokens=12))
    prompt = list(range(1, 25))
    eng.submit(prompt)
    ref = eng.run_until_done()
    stop = ref[0].out_tokens[1]
    eng2 = ServeEngine(m, params, _base(prefix_cache=True, eos_id=stop,
                                        max_new_tokens=12))
    eng2.submit(prompt)
    done = eng2.run_until_done()
    assert done[0].finish_reason == "stop"
    assert eng2.prefix.cached_pages == len(prompt) // 8
    assert eng2.prefix.match(prompt)           # prefix reusable immediately
    eng2.prefix.check_invariants()


def test_finish_at_admission_does_not_corrupt_published_pages(model_f32):
    """Regression: a request that finishes AT admission (its first sampled
    token is a stop token / max_new_tokens == 1) publishes its prompt
    pages the same tick; the batched decode that follows must not write
    its lane's garbage K/V into the just-published page through a stale
    device block table.  A follower matching the prefix must match the
    cache-off reference exactly."""
    m, params = model_f32
    prompt = list(range(1, 33))                    # 4 full pages of 8
    follower = prompt + [7, 3]

    def run(prefix_cache, first_max_new):
        eng = ServeEngine(m, params, _base(prefix_cache=prefix_cache,
                                           max_batch=2, max_new_tokens=8))
        eng.submit([9, 8, 7])                      # keeps a decode in flight
        eng.tick()
        eng.submit(prompt, max_new_tokens=first_max_new)
        eng.tick()      # publisher admits (and may finish) as the LAST
        eng.tick()      # admission of its tick, then the batched decode runs
        uid = eng.submit(follower)
        done = {r.uid: r.out_tokens for r in eng.run_until_done()}
        return done[uid]

    want = run(False, 1)
    assert run(True, 1) == want                    # finish-at-admission
    assert run(True, 8) == want                    # finish during decode

def test_run_until_done_raises_on_exhaustion(model_f32):
    m, params = model_f32
    eng = ServeEngine(m, params, _base(max_new_tokens=30))
    eng.submit(list(range(1, 40)))
    with pytest.raises(RuntimeError, match="in-flight"):
        eng.run_until_done(max_ticks=3)
    # the lenient mode warns and returns the partial trace instead
    eng2 = ServeEngine(m, params, _base(max_new_tokens=30))
    eng2.submit(list(range(1, 40)))
    with pytest.warns(UserWarning, match="exhausted"):
        done = eng2.run_until_done(max_ticks=3, on_exhaust="return")
    assert done == []


# ===========================================================================
# mid-prompt chunk kernel: pallas (interpret) vs ref oracle vs monolithic
# ===========================================================================

@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("off,s_chunk", [(0, 16), (8, 16), (20, 8),
                                         (28, 4)])
def test_chunk_attention_matches_monolithic(impl, off, s_chunk, rng):
    """A chunk's attention through the block table must equal the same
    rows of one monolithic causal attention - for page-aligned AND
    mid-page chunk starts."""
    S, Hq, Hkv, D, ps = 48, 4, 2, 16, 8
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (1, S, Hq, D))
    k = jax.random.normal(ks[1], (1, S, Hkv, D))
    v = jax.random.normal(ks[2], (1, S, Hkv, D))
    want = ops.flash_attention(q, k, v, causal=True,
                               impl="ref")[:, off:off + s_chunk]
    # scatter ALL K/V (prefix + chunk) into a shuffled page pool
    n_pages = S // ps
    perm = np.random.default_rng(0).permutation(np.arange(1, n_pages + 1))
    k_pages = jnp.zeros((n_pages + 1, ps, Hkv, D))
    v_pages = jnp.zeros((n_pages + 1, ps, Hkv, D))
    for j in range(n_pages):
        k_pages = k_pages.at[perm[j]].set(k[0, j * ps:(j + 1) * ps])
        v_pages = v_pages.at[perm[j]].set(v[0, j * ps:(j + 1) * ps])
    got = ops.paged_prefill_attention(
        q[:, off:off + s_chunk], k_pages, v_pages,
        jnp.asarray(perm, jnp.int32), off, impl=impl)
    assert float(jnp.abs(got - want).max()) <= 1e-5


def test_model_chunked_prefill_composes_exactly(model_f32):
    """Composing Model.prefill_chunk left to right must reproduce the
    monolithic paged prefill: identical final logits, identical decode
    continuation."""
    m, params = model_f32
    toks = np.random.default_rng(5).integers(
        1, m.cfg.vocab_size, size=40).tolist()
    ps, n_pages = 8, 8
    page_ids = jnp.arange(1, 6, dtype=jnp.int32)     # 40 tokens = 5 pages
    row = np.zeros(8, np.int32)
    row[:5] = np.arange(1, 6)

    def fresh_cache():
        c = m.init_cache(1, 64, page_size=ps, num_pages=n_pages)
        return dict(c, block_table=jnp.asarray([row]))

    batch = {"tokens": jnp.asarray([toks], jnp.int32),
             "true_lens": jnp.asarray([40])}
    logits_mono, cache_mono, _ = m.prefill_paged(params, batch,
                                                 fresh_cache(), page_ids)
    cache = fresh_cache()
    page_row = jnp.asarray(row)
    for start, n in ((0, 16), (16, 16), (32, 8)):
        chunk = {"tokens": jnp.asarray([toks[start:start + n]], jnp.int32),
                 "offset": jnp.asarray([start], jnp.int32),
                 "true_lens": jnp.asarray([start + n], jnp.int32)}
        logits, cache, cursor = m.prefill_chunk(params, chunk, cache,
                                                page_row)
        assert int(cursor[0]) == start + n
    assert float(jnp.abs(logits - logits_mono).max()) <= 1e-4
    for key in ("k_pages", "v_pages"):
        assert float(jnp.abs(cache[key] - cache_mono[key]).max()) <= 1e-4
    d1, _ = m.decode_step(params, jnp.asarray([[7]]), jnp.asarray([40]),
                          cache_mono)
    d2, _ = m.decode_step(params, jnp.asarray([[7]]), jnp.asarray([40]),
                          cache)
    assert float(jnp.abs(d1 - d2).max()) <= 1e-4


# ===========================================================================
# hypothesis: parity + budget invariants over random budgets / chunk sizes
# ===========================================================================

def _hypothesis_or_skip():
    return pytest.importorskip("hypothesis")


def test_property_random_budget_and_chunk(model_f32):
    _hypothesis_or_skip()
    from hypothesis import given, settings, strategies as st

    m, params = model_f32
    prompts = _mixed_prompts(m.cfg.vocab_size, lens=(28, 9, 60))
    mono, _ = _serve(m, params, _base(max_batch=2), prompts)

    @settings(max_examples=8, deadline=None)
    @given(chunk_mult=st.integers(1, 4), extra=st.integers(0, 40),
           policy=st.sampled_from(["fifo", "sjf"]))
    def check(chunk_mult, extra, policy):
        chunk = 8 * chunk_mult
        budget = 2 + chunk + extra
        out, eng = _serve(
            m, params,
            _base(max_batch=2, chunked=True, prefill_chunk=chunk,
                  tick_token_budget=budget, admission_policy=policy),
            prompts)
        assert out == mono
        assert eng.stats()["max_tick_tokens"] <= budget
        assert eng.prefill_tokens == sum(len(p) for p in prompts)
        assert eng.allocator.used_pages == 0

    check()


# ===========================================================================
# deadlines: submit-time validation and work-clock expiry
# ===========================================================================

def test_submit_deadline_and_retry_validation(model_f32):
    """Every never-servable deadline/retry combination fails AT SUBMIT
    with a clear error - not deep inside prefill or the allocator."""
    m, params = model_f32
    eng = ServeEngine(m, params, _base())
    with pytest.raises(ValueError, match="deadline"):
        eng.submit([1, 2, 3], deadline=0)
    with pytest.raises(ValueError, match="deadline"):
        eng.submit([1, 2, 3], deadline=-5)
    with pytest.raises(ValueError, match="minimum prefill work"):
        # the prompt alone costs 3 work tokens of prefill: a deadline at
        # or below that is a guaranteed timeout
        eng.submit([1, 2, 3], deadline=3)
    with pytest.raises(ValueError, match="max_retries"):
        eng.submit([1, 2, 3], max_retries=-1)
    # the boundary case is accepted: one token CAN land in time
    uid = eng.submit([1, 2, 3], deadline=4, max_retries=0)
    assert eng.sched.queue[-1].uid == uid
    assert eng.sched.queue[-1].deadline_tokens == 4


def test_default_deadline_tokens_config(model_f32):
    """ServeConfig.default_deadline_tokens stamps every submit that does
    not bring its own deadline; 0 means none; negatives are rejected at
    config validation."""
    m, params = model_f32
    with pytest.raises(ValueError, match="default_deadline_tokens"):
        _base(default_deadline_tokens=-1).validate()
    eng = ServeEngine(m, params, _base(default_deadline_tokens=64))
    eng.submit([1, 2, 3])
    assert eng.sched.queue[-1].deadline_tokens == 64
    eng.submit([1, 2, 3], deadline=32)
    assert eng.sched.queue[-1].deadline_tokens == 32
    eng = ServeEngine(m, params, _base())        # default 0 = no deadline
    eng.submit([1, 2, 3])
    assert eng.sched.queue[-1].deadline_tokens is None


def test_deadline_expiry_frees_pages_same_tick(model_f32):
    """A request whose work-clock deadline lands mid-flight goes
    terminal TIMEOUT the very tick it expires - slot and pages freed
    immediately (conservation checked per tick), unrelated traffic
    unharmed, and the engine never hangs."""
    m, params = model_f32
    scfg = _base(chunked=True, prefill_chunk=16, tick_token_budget=32,
                 max_new_tokens=8)
    eng = ServeEngine(m, params, scfg)
    # 100-token prompt, deadline 101: barely above the submit-time floor,
    # but chunked prefill at 32 tokens/tick crosses 101 work tokens long
    # before the first token - a mid-prefill expiry
    doomed = eng.submit(list(range(1, 101)), deadline=101)
    fine = eng.submit(list(range(200, 210)))
    done = eng.run_until_done(max_ticks=1000)
    by_uid = {r.uid: r for r in done}
    assert by_uid[doomed].state is RequestState.TIMEOUT
    assert by_uid[doomed].finish_reason == "timeout"
    assert by_uid[doomed].slot is None
    assert by_uid[fine].state is RequestState.DONE
    assert len(by_uid[fine].out_tokens) == 8
    assert eng.stats()["timeouts"] == 1
    assert eng.allocator.used_pages == 0         # every page came home
    eng.check_invariants()


def test_deadline_expiry_in_queue_never_admits(model_f32):
    """A request that expires while still QUEUED times out from the
    queue - it must never be admitted, never touch a slot or a page."""
    m, params = model_f32
    scfg = _base(max_batch=1, chunked=True, prefill_chunk=16,
                 tick_token_budget=32, max_new_tokens=4)
    eng = ServeEngine(m, params, scfg)
    hog = eng.submit(list(range(1, 80)))          # owns the only slot
    starved = eng.submit(list(range(100, 140)), deadline=41)
    done = eng.run_until_done(max_ticks=1000)
    by_uid = {r.uid: r for r in done}
    assert by_uid[hog].state is RequestState.DONE
    assert by_uid[starved].state is RequestState.TIMEOUT
    assert by_uid[starved].slot is None
    assert by_uid[starved].out_tokens == []
    eng.check_invariants()
    assert eng.allocator.used_pages == 0


def test_deadline_met_is_untouched(model_f32):
    """A generous deadline changes nothing: same outputs as the
    deadline-free run (the sweep is pure bookkeeping until an expiry)."""
    m, params = model_f32
    prompts = _mixed_prompts(m.cfg.vocab_size)
    base_out, _ = _serve(m, params, _base(), prompts)
    eng = ServeEngine(m, params, _base())
    for p in prompts:
        eng.submit(p, deadline=100_000)
    done = eng.run_until_done()
    assert {r.uid: r.out_tokens for r in done} == base_out
    assert eng.stats()["timeouts"] == 0
