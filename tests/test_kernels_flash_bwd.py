"""Pallas backward kernels (dq / dkv) vs autodiff of the naive oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.flash_backward import flash_attention_bwd

CASES = [
    (1, 256, 2, 2, 32, True, 0, 0.0),
    (2, 256, 4, 2, 32, True, 0, 0.0),      # GQA
    (1, 256, 2, 2, 32, False, 0, 0.0),     # non-causal
    (1, 256, 2, 2, 32, True, 128, 0.0),    # sliding window
    (1, 256, 2, 2, 32, True, 0, 25.0),     # softcap
    (1, 200, 2, 1, 32, True, 0, 0.0),      # padding + group 2
]


@pytest.mark.parametrize("case", CASES)
def test_pallas_backward_matches_autodiff(case, rng):
    B, S, Hq, Hkv, D, causal, window, cap = case
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))

    def f(q, k, v):
        return jnp.sum(ref.naive_attention(
            q, k, v, causal=causal, window=window,
            logit_softcap=cap).astype(jnp.float32) ** 2)

    g_ref = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    o_p, lse = flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   logit_softcap=cap, block_q=128,
                                   block_kv=128)
    do = 2.0 * o_p.astype(jnp.float32)
    grads = flash_attention_bwd(q, k, v, o_p, lse, do.astype(q.dtype),
                                causal=causal, window=window,
                                logit_softcap=cap, block_q=128, block_kv=128)
    for a, b in zip(grads, g_ref):
        a32 = np.asarray(a, np.float32)
        b32 = np.asarray(b, np.float32)
        rel = np.abs(a32 - b32).max() / (np.abs(b32).max() + 1e-6)
        assert rel < 3e-4, rel


def test_ops_dispatch_pallas_backward(rng, monkeypatch):
    """ops.flash_attention with impl='pallas' runs the Pallas fwd AND bwd
    (interpret mode on CPU) and matches the ref path's grads."""
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))

    def loss(impl):
        def f(q):
            return jnp.sum(ops.flash_attention(
                q, k, v, causal=True, impl=impl).astype(jnp.float32) ** 2)
        return jax.grad(f)(q)

    g_pallas = loss("pallas")
    g_ref = loss("ref")
    np.testing.assert_allclose(np.asarray(g_pallas), np.asarray(g_ref),
                               atol=5e-4, rtol=1e-3)
