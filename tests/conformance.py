"""Differential conformance layer for speculative decoding.

Extends the traffic-replay harness (tests/traffic.py) with the spec-on /
spec-off differential: every registered trace replays through TWO engines
that differ ONLY in ServeConfig.speculative, and the checks assert the
speculative engine is observationally identical to the baseline -

  greedy outputs       bit-identical (fast path), tolerating only genuine
                       fp argmax near-ties via the teacher-forced fallback
                       (traffic.assert_greedy_equivalent)
  sampled outputs      every emitted token lies in the support of the
                       target's OWN filtered distribution at that position
                       (teacher-forced through model.forward with the same
                       temperature / top-k / top-p stack), and a fixed
                       seed reproduces the trace exactly
  work clock           equal work_tokens totals: the work clock advances
                       only for ACCEPTED tokens, so drafting never skews
                       work-clock TTFT/TBT between the two runs
  page accounting      refcount conservation across rejection rollbacks -
                       replay() runs ServeEngine.check_invariants() after
                       EVERY tick, and after the trace drains every page
                       is back in the pool (or parked, refcounted, in the
                       prefix tree)

The registry deliberately covers every traffic shape the serve suites
use: mixed lengths, shared prefixes (the prefix-cache + high-acceptance
shape), waves (pipeline-bubble shape), and priority bursts (preemption
interleaved with speculation).
"""
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Tuple

import numpy as np

from repro.configs.base import ServeConfig
from repro.serve import ServeEngine
from repro.serve.scheduler import Request
from traffic import (TrafficItem, assert_greedy_equivalent, mixed_prompts,
                     priority_burst, replay, shared_prefix_prompts,
                     wave_arrivals)

# smoke-scale engine shape every conformance trace shares (speculation
# needs paged + chunked + batched; overrides per trace below)
BASE_SCFG = dict(max_batch=4, max_seq=512, page_size=16, prefill_chunk=32,
                 tick_token_budget=64, max_new_tokens=24, paged=True,
                 chunked=True, batched=True, spec_k=6, spec_ngram=3)


@dataclass(frozen=True)
class TraceSpec:
    """One registered conformance trace: a seeded item builder plus the
    ServeConfig overrides the shape needs (pool pressure, prefix cache,
    preemption)."""
    name: str
    build: Callable[[int], List[TrafficItem]]
    scfg_kw: Dict[str, Any] = field(default_factory=dict)


def _mixed_items(vocab: int) -> List[TrafficItem]:
    return [TrafficItem(0, p) for p in
            mixed_prompts(vocab, lens=(16, 64, 224, 9, 130, 40))]


def _shared_prefix_items(vocab: int) -> List[TrafficItem]:
    return [TrafficItem(0, p) for p in
            shared_prefix_prompts(vocab, 48, (8, 16, 24, 4))]


def _wave_items(vocab: int) -> List[TrafficItem]:
    return wave_arrivals(vocab, (120, 24, 16), waves=3, period=4)


def _priority_burst_items(vocab: int) -> List[TrafficItem]:
    return priority_burst(vocab, (96, 96), (64,), burst_tick=3,
                          burst_priority=5, seed=1)


TRACES: Dict[str, TraceSpec] = {t.name: t for t in [
    TraceSpec("mixed", _mixed_items),
    TraceSpec("shared_prefix", _shared_prefix_items,
              {"prefix_cache": True}),
    TraceSpec("wave", _wave_items),
    # usable_pages squeezed so the burst actually preempts: preemption's
    # lens-rollback bookkeeping must stay consistent with speculation's
    TraceSpec("priority_burst", _priority_burst_items,
              {"preemption": True, "usable_pages": 28,
               "max_chunks_per_tick": 1, "max_batch": 3}),
]}


def make_scfg(trace: TraceSpec, speculative: bool,
              **extra) -> ServeConfig:
    kw = dict(BASE_SCFG)
    kw.update(trace.scfg_kw)
    kw.update(extra)
    return ServeConfig(speculative=speculative, **kw)


def replay_trace(model, params, trace: TraceSpec, speculative: bool,
                 **scfg_extra) -> Tuple[Dict[int, List[int]], ServeEngine]:
    """Replay one registered trace (fresh items - replay() stamps uids)
    with per-tick engine invariant checks.  Returns ({uid: out}, engine)."""
    eng = ServeEngine(model, params,
                      make_scfg(trace, speculative, **scfg_extra))
    items = trace.build(model.cfg.vocab_size)
    out, _ = replay(eng, items, check=True)
    return out, eng


def assert_pages_conserved(eng: ServeEngine):
    """After a drained trace every page is accounted for: back in the
    free pool, or parked in the prefix tree with a live refcount.  A
    speculative rollback that leaked (or double-freed) a page fails
    here - and per-tick, in replay()'s check_invariants sweeps."""
    if not eng.paged:
        return
    assert all(s is None for s in eng.slots)
    if eng.prefix is not None:
        eng.prefix.check_invariants()
        assert eng.allocator.used_pages == eng.prefix.cached_pages, \
            (eng.allocator.used_pages, eng.prefix.cached_pages)
    else:
        assert eng.allocator.used_pages == 0, eng.allocator.used_pages
        assert (eng.allocator.table == 0).all()


def assert_spec_conformance(model, params, trace: TraceSpec,
                            **scfg_extra):
    """The greedy differential: replay `trace` spec-off and spec-on and
    assert bit-identical outputs (teacher-forced near-tie fallback),
    equal work-clock totals, page conservation on both engines, and -
    on traces long enough to draft - that speculation actually engaged.
    Returns (baseline engine, speculative engine) for extra checks."""
    base_out, eng_off = replay_trace(model, params, trace, False,
                                     **scfg_extra)
    spec_out, eng_on = replay_trace(model, params, trace, True,
                                    **scfg_extra)
    assert base_out.keys() == spec_out.keys()
    if spec_out != base_out:
        assert_greedy_equivalent(model, params, eng_on.sched.finished,
                                 base_out)
    s_off, s_on = eng_off.stats(), eng_on.stats()
    assert s_off["work_tokens"] == s_on["work_tokens"], \
        (s_off["work_tokens"], s_on["work_tokens"])
    assert s_off["gen_tokens"] == s_on["gen_tokens"]
    assert_pages_conserved(eng_off)
    assert_pages_conserved(eng_on)
    assert s_on["spec_drafted"] > 0, "speculation never engaged"
    return eng_off, eng_on


def assert_tp_shard_accounting(eng: ServeEngine):
    """Per-shard KV-byte accounting cross-checked against the allocator's
    page counter: every page the decode path read was read once per
    device, each device streaming exactly its head shard of the page -
    so shard bytes x tp_degree must equal pages x full page bytes, with
    no rounding (head counts divide tp_degree by construction).  With
    tp_degree > 1 the block table is replicated onto every shard, so
    replication bytes must have accrued."""
    t = eng.tp_stats()
    tp = t["tp_degree"]
    assert t["shard_page_bytes"] * tp == t["page_bytes"], t
    assert t["shard_kv_bytes_read"] * tp \
        == t["kv_pages_read"] * t["page_bytes"], t
    if tp > 1 and t["kv_pages_read"] > 0:
        assert t["table_bytes_replicated"] > 0, t


def assert_tp_conformance(model, params, trace: TraceSpec,
                          tp_degree: int = 2, speculative: bool = False,
                          **scfg_extra):
    """The tensor-parallel differential: replay `trace` through a
    single-device engine and a head-sharded tp=`tp_degree` engine that
    differ ONLY in ServeConfig.tp_degree, and assert the sharded engine
    is observationally identical - bit-identical greedy outputs (the
    all-gather inside the sharded kernels restores the tp=1 float
    summation order, so this is exact equality, with the teacher-forced
    near-tie fallback kept only for belt and braces), equal work-clock
    and generated-token totals, page conservation on both engines, and
    the per-shard byte cross-check above.  Returns (tp=1 engine,
    tp=`tp_degree` engine) for extra checks."""
    base_out, eng_1 = replay_trace(model, params, trace, speculative,
                                   **scfg_extra)
    tp_out, eng_tp = replay_trace(model, params, trace, speculative,
                                  tp_degree=tp_degree, **scfg_extra)
    assert base_out.keys() == tp_out.keys()
    if tp_out != base_out:
        assert_greedy_equivalent(model, params, eng_tp.sched.finished,
                                 base_out)
    s_1, s_tp = eng_1.stats(), eng_tp.stats()
    assert s_1["work_tokens"] == s_tp["work_tokens"], \
        (s_1["work_tokens"], s_tp["work_tokens"])
    assert s_1["gen_tokens"] == s_tp["gen_tokens"]
    assert s_1["kv_pages_read"] == s_tp["kv_pages_read"], \
        "sharding must not change WHICH pages decode reads, only how " \
        "much of each page every device streams"
    assert_pages_conserved(eng_1)
    assert_pages_conserved(eng_tp)
    assert_tp_shard_accounting(eng_1)
    assert_tp_shard_accounting(eng_tp)
    return eng_1, eng_tp


def assert_sampled_support(model, params, scfg: ServeConfig,
                           done: List[Request], slack: float = 1e-3):
    """Teacher-force every finished request's emitted trace through
    model.forward and assert each generated token survives the SAME
    temperature -> top-k -> top-p filter stack the engine sampled it
    with: its logit sits at or above the filter thresholds (within
    `slack`, for kernel-vs-forward rounding wobble).  A speculative
    acceptance path that emitted a token the target could never have
    sampled fails loudly here."""
    import jax.numpy as jnp

    for req in done:
        seq = req.prompt + req.out_tokens
        out = model.forward(params, {"tokens": jnp.asarray([seq],
                                                           jnp.int32)})
        logits = np.asarray(out[0] if isinstance(out, tuple) else out,
                            np.float64)[0]
        V = logits.shape[-1]
        for i, tok in enumerate(req.out_tokens):
            row = logits[len(req.prompt) - 1 + i]
            if scfg.temperature <= 0.0:
                assert row[tok] >= row.max() - slack
                continue
            scaled = row / scfg.temperature
            if 0 < scfg.top_k < V:
                kth = np.sort(scaled)[V - scfg.top_k]
                assert scaled[tok] >= kth - slack, \
                    f"uid {req.uid} token {i}: outside top-k"
            if scfg.top_p < 1.0:
                order = np.argsort(-scaled)
                probs = np.exp(scaled - scaled.max())
                probs /= probs.sum()
                cum = np.cumsum(probs[order])
                keep = (cum - probs[order]) < scfg.top_p
                kept = set(order[keep].tolist())
                # slack: admit tokens tied (within fp wobble) with the
                # last kept logit
                floor = scaled[order[keep]].min()
                assert tok in kept or scaled[tok] >= floor - slack, \
                    f"uid {req.uid} token {i}: outside top-p nucleus"
