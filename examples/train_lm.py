"""Training driver: fault-tolerant LM training on synthetic data.

Smoke scale by default; pass --full-ish for a ~100M-parameter variant (slow
on CPU; sized for a real accelerator).  Demonstrates checkpoint/restart: run
it, Ctrl-C it, run it again - it resumes.

    PYTHONPATH=src python examples/train_lm.py --arch olmo-1b --steps 50
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import get_config, get_smoke_config
from repro.configs.base import TrainConfig
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-ish", action="store_true",
                    help="~100M-param config (d_model=768, 12 layers)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if args.full_ish:
        cfg = cfg.replace(n_layers=12, d_model=768, n_heads=12,
                          n_kv_heads=12, head_dim=64, d_ff=3072,
                          vocab_size=32768)
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    tcfg = TrainConfig(global_batch=args.batch, seq_len=args.seq,
                       total_steps=args.steps, warmup_steps=5,
                       learning_rate=6e-3, checkpoint_every=20,
                       checkpoint_dir=args.ckpt_dir, log_every=10,
                       grad_compression="int8" if args.compress_grads else "")
    tr = Trainer(cfg, tcfg)
    if tr.start_step:
        print(f"resumed from checkpoint at step {tr.start_step}")
    out = tr.run()
    for m in out["metrics"]:
        print(f"step {m['step']:4d}  loss {m['loss']:.4f}  "
              f"grad_norm {m['grad_norm']:.2f}  {m['step_time_s']*1e3:.0f} ms")
    print(f"done at step {out['final_step']}; "
          f"straggler events: {out['straggler_events']}")


if __name__ == "__main__":
    main()
