"""Quickstart: build a model, train a few steps, generate tokens.

    PYTHONPATH=src python examples/quickstart.py [--arch granite-3-2b]

Trains a smoke-scale model for a few steps (loss should fall), then serves
two requests through the paged-KV ServeEngine (continuous batching; see
docs/serving.md and examples/serve_lm.py for the full serving driver).

Expected output shape:

    == granite-3-2b-smoke: 0.07M params (dense) ==
      step    4  loss 5.54  lr ...  ... ms
      ...
      request 1: generated [..., ..., ...]
      request 2: generated [..., ..., ...]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.configs import get_smoke_config
from repro.configs.base import ServeConfig, TrainConfig
from repro.models import build_model
from repro.serve.engine import ServeEngine
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    print(f"== {cfg.name}: {cfg.param_count()/1e6:.2f}M params "
          f"({cfg.family}) ==")

    tcfg = TrainConfig(global_batch=8, seq_len=64, total_steps=args.steps,
                       warmup_steps=2, learning_rate=1e-2,
                       checkpoint_every=10,
                       checkpoint_dir="/tmp/repro_quickstart", log_every=5)
    out = Trainer(cfg, tcfg).run()
    for m in out["metrics"]:
        print(f"  step {m['step']:4d}  loss {m['loss']:.3f}  "
              f"lr {m['lr']:.2e}  {m['step_time_s']*1e3:.0f} ms")

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=2, max_seq=96, max_new_tokens=8,
                                  paged=True, page_size=16))
    eng.submit([1, 2, 3, 4])
    eng.submit([5, 6, 7])
    for r in eng.run_until_done():
        print(f"  request {r.uid}: generated {r.out_tokens}")


if __name__ == "__main__":
    main()
