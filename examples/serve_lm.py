"""End-to-end serving driver (the paper is an inference accelerator, so the
end-to-end example serves a small LM with continuously-batched requests).

This is the paged-serving entry point: by default requests are served
through the paged KV cache (a global page pool walked via a block table -
see docs/serving.md); --dense switches back to the one-strip-per-slot
layout for comparison.  Both modes print tokens/s and allocated KV bytes.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-4b --requests 12
    PYTHONPATH=src python examples/serve_lm.py --dense
    PYTHONPATH=src python examples/serve_lm.py --chunked  # token-budget
        # scheduler: prefill chunks interleave with decode ticks
        # (docs/scheduling.md); greedy outputs match the monolithic
        # schedule exactly in float32 (bf16 can flip an argmax tie - the
        # chunk kernel and the monolithic prefill reduce in different
        # orders)

Expected output (CPU, smoke-scale model; numbers vary by machine):

    served 12 requests, 192 tokens in 8.3s (23.1 tok/s,
    continuous batching over 4 slots, paged KV: 0.03 MB, peak 18 pages)
      req 1: [132, 38, ...]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--dense", action="store_true",
                    help="dense KV cache instead of the paged pool")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=0,
                    help="page pool size (0 = dense-equivalent capacity)")
    ap.add_argument("--chunked", action="store_true",
                    help="token-budget scheduler: chunked prefill mixed "
                         "into decode ticks (paged only)")
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--tick-budget", type=int, default=0,
                    help="tokens of work per tick "
                         "(0 = max_batch + prefill_chunk)")
    args = ap.parse_args()
    if args.chunked and args.dense:
        ap.error("--chunked needs the paged cache (drop --dense)")

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=args.max_batch, max_seq=128,
                                  max_new_tokens=args.max_new,
                                  paged=not args.dense,
                                  page_size=args.page_size,
                                  num_pages=args.num_pages,
                                  chunked=args.chunked,
                                  prefill_chunk=args.prefill_chunk,
                                  tick_token_budget=args.tick_budget or
                                  args.max_batch + args.prefill_chunk))

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size,
                              size=rng.integers(3, 12)).tolist()
        eng.submit(prompt)
    done = eng.run_until_done()
    dt = time.time() - t0
    tokens = sum(len(r.out_tokens) for r in done)
    kv = f"paged KV: {eng.kv_cache_bytes() / 1e6:.2f} MB, " \
         f"peak {eng.peak_pages} pages" if not args.dense \
        else f"dense KV: {eng.kv_cache_bytes() / 1e6:.2f} MB"
    sched = "chunked prefill" if args.chunked else "monolithic prefill"
    print(f"served {len(done)} requests, {tokens} tokens "
          f"in {dt:.1f}s ({tokens/dt:.1f} tok/s, "
          f"continuous batching over {args.max_batch} slots, {sched}, "
          f"{kv})")
    if args.chunked:
        st = eng.stats()
        print(f"  budget {st['tick_token_budget']} tok/tick, max tick "
              f"{st['max_tick_tokens']}, {st['chunks_run']} chunks, p95 "
              f"TTFT {st['ttft_work_p95']:.0f} work-tok / "
              f"{st['ttft_wall_p95'] * 1e3:.0f} ms")
    for r in done[:4]:
        print(f"  req {r.uid}: {r.out_tokens}")


if __name__ == "__main__":
    main()
