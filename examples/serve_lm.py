"""End-to-end serving driver (the paper is an inference accelerator, so the
end-to-end example serves a small LM with continuously-batched requests).

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-4b --requests 12
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import ServeConfig
from repro.models import build_model
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=args.max_batch, max_seq=128,
                                  max_new_tokens=args.max_new))

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size,
                              size=rng.integers(3, 12)).tolist()
        eng.submit(prompt)
    done = eng.run_until_done()
    dt = time.time() - t0
    tokens = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {tokens} tokens "
          f"in {dt:.1f}s ({tokens/dt:.1f} tok/s, "
          f"continuous batching over {args.max_batch} slots)")
    for r in done[:4]:
        print(f"  req {r.uid}: {r.out_tokens}")


if __name__ == "__main__":
    main()
