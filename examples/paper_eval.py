"""Reproduce the paper's evaluation (Figs 1, 5-8, Table II) from the
3D-Flow co-design simulator.

    PYTHONPATH=src python examples/paper_eval.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def main():
    from benchmarks import (fig1_motivation, fig5_energy, fig6_data_movement,
                            fig7_speedup, fig8_utilization, table2_breakdown)
    print("name,us_per_call,derived")
    fig1_motivation.run()
    fig5_energy.run()
    fig6_data_movement.run()
    fig7_speedup.run()
    fig8_utilization.run()
    table2_breakdown.run()


if __name__ == "__main__":
    main()
