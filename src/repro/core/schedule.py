"""3D-FlashAttention scheduling: latency-balanced tier mapping.

This module reproduces the paper's Section IV: the assignment of the
FlashAttention-2 inner-loop operators (Algorithm 1) onto the four stacked PE
tiers, the cycle-level pipeline this forms, and the generalized
latency-balancer ("the co-designed hybrid-bonded NPU architecture can also be
generalized to other fused operators beyond attention").

Timeline reproduced from the paper (Fig. 4), for a d x d tile:

  Tier 0 (QK^T, output-stationary):  first S element at cycle d, all at 3d;
                                     next iteration may start at 2d.
  Tier 1 (rowmax + subtract):        starts at d (first S via TSV), `a` done
                                     at 3d, matrix N done at 4d.
  Tier 2 (exp2 / rowsum / l-update): starts at 2d, done before 5d.
  Tier 3 (PV, weight-stationary, + O rescale): V injected at 2d, first
                                     local_O at 3d, all done at 5d.

Steady state: initiation interval = 2d cycles per inner-loop iteration;
pipeline depth = 5d cycles (first iteration's completion).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Sequence, Tuple


@dataclass(frozen=True)
class TierStage:
    """One pipeline stage (= one PE tier) of the 3D-FlashAttention schedule."""

    name: str
    tier: int
    # per d x d tile op counts, as functions of d
    macs: Callable[[int], float]
    exp_ops: Callable[[int], float]
    alu_ops: Callable[[int], float]
    # bytes forwarded upward through the hybrid-bonded TSV links per tile
    tsv_out_bytes: Callable[[int], float]
    # cycles this stage occupies per tile (its stage latency)
    latency: Callable[[int], float]
    # initiation interval: min cycles between successive tiles on this tier
    ii: Callable[[int], float]


def threed_flash_schedule(dtype_bytes: int = 2) -> List[TierStage]:
    """The paper's 4-tier operator mapping (Fig. 2/3/4, Alg. 1 colors)."""
    B = dtype_bytes
    return [
        TierStage(
            name="QK^T", tier=0,
            macs=lambda d: float(d) ** 3,
            exp_ops=lambda d: 0.0,
            alu_ops=lambda d: 0.0,
            # S tile forwarded element-by-element upward
            tsv_out_bytes=lambda d: float(d * d) * B,
            latency=lambda d: 3.0 * d,   # all S elements ready at 3d
            ii=lambda d: 2.0 * d,        # top-left PE frees at 2d
        ),
        TierStage(
            name="rowmax+sub", tier=1,
            macs=lambda d: 0.0,
            exp_ops=lambda d: 0.0,
            # rightward max propagation (d^2 cmp) + leftward compare with
            # old_m (d) + subtraction producing N (d^2) and a (d)
            alu_ops=lambda d: 2.0 * d * d + 2.0 * d,
            tsv_out_bytes=lambda d: (float(d * d) + d) * B,   # N and a
            latency=lambda d: 3.0 * d,   # active d..4d
            ii=lambda d: 2.0 * d,
        ),
        TierStage(
            name="exp+rowsum", tier=2,
            # new_l = old_l * b + local_l -> d MACs; const mult folded below
            macs=lambda d: float(d),
            # P (d^2) plus b (d) exponentials, exp2-based
            exp_ops=lambda d: float(d * d) + d,
            # const multiply (d^2) + rowsum accumulation (d^2)
            alu_ops=lambda d: 2.0 * d * d,
            tsv_out_bytes=lambda d: (float(d * d) + 2.0 * d) * B,  # P, b, l
            latency=lambda d: 3.0 * d,   # active 2d..5d
            ii=lambda d: 2.0 * d,
        ),
        TierStage(
            name="PV+rescale", tier=3,
            # PV: d^3 MACs; new_O = diag(b) old_O + local_O: d^2 MACs
            macs=lambda d: float(d) ** 3 + float(d * d),
            exp_ops=lambda d: 0.0,
            alu_ops=lambda d: 0.0,
            tsv_out_bytes=lambda d: 0.0,  # O leaves through the top to SRAM
            latency=lambda d: 3.0 * d,   # active 2d..5d
            ii=lambda d: 2.0 * d,
        ),
    ]


def pipeline_period(stages: Sequence[TierStage], d: int) -> float:
    """Steady-state initiation interval = max over stages (bubble-free when
    all tiers share the same II - the paper's latency-balanced property)."""
    return max(s.ii(d) for s in stages)


def pipeline_depth(stages: Sequence[TierStage], d: int) -> float:
    """Cycles until the first tile fully drains (paper: 5d)."""
    # Tier start offsets (paper Fig. 4): 0, d, 2d, 2d; depth = last finish.
    offsets = [0.0, 1.0 * d, 2.0 * d, 2.0 * d]
    return max(off + s.latency(d) for off, s in zip(offsets, stages))


def pipeline_cycles(n_tiles: int, stages: Sequence[TierStage], d: int) -> float:
    """Total cycles to stream `n_tiles` inner-loop tiles through the stack."""
    if n_tiles <= 0:
        return 0.0
    period = pipeline_period(stages, d)
    depth = pipeline_depth(stages, d)
    return depth + (n_tiles - 1) * period


def is_bubble_free(stages: Sequence[TierStage], d: int, tol: float = 1e-9) -> bool:
    """Bubble-free <=> every tier's initiation interval equals the pipeline
    period, i.e. no tier is left waiting on a slower neighbor."""
    period = pipeline_period(stages, d)
    return all(abs(s.ii(d) - period) <= tol * max(period, 1.0) for s in stages)


# ---------------------------------------------------------------------------
# Generalized latency balancer (beyond attention)
# ---------------------------------------------------------------------------

def balance_chain(costs: Sequence[float], n_tiers: int) -> Tuple[List[List[int]], float]:
    """Partition a chain of fused micro-operators (given per-op latencies)
    into `n_tiers` contiguous groups minimizing the maximum group latency.

    This is the paper's "latency-balanced mapping" generalized: the returned
    max group latency is the pipeline initiation interval when each group is
    assigned to one tier.  Exact O(n^2 * k) dynamic program.
    """
    n = len(costs)
    if n == 0:
        return [[] for _ in range(n_tiers)], 0.0
    k = min(n_tiers, n)
    prefix = [0.0]
    for c in costs:
        prefix.append(prefix[-1] + float(c))

    INF = float("inf")
    # dp[j][i] = minimal max-group-cost partitioning costs[:i] into j groups
    dp = [[INF] * (n + 1) for _ in range(k + 1)]
    cut = [[0] * (n + 1) for _ in range(k + 1)]
    dp[0][0] = 0.0
    for j in range(1, k + 1):
        for i in range(1, n + 1):
            for m in range(j - 1, i):
                cand = max(dp[j - 1][m], prefix[i] - prefix[m])
                if cand < dp[j][i]:
                    dp[j][i] = cand
                    cut[j][i] = m
    # reconstruct
    groups: List[List[int]] = []
    i = n
    for j in range(k, 0, -1):
        m = cut[j][i]
        groups.append(list(range(m, i)))
        i = m
    groups.reverse()
    while len(groups) < n_tiers:
        groups.append([])
    return groups, dp[k][n]


def balanced_ii(costs: Sequence[float], n_tiers: int) -> float:
    """Pipeline initiation interval after latency balancing."""
    _, mx = balance_chain(costs, n_tiers)
    return mx
