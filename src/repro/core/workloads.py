"""Workload descriptions consumed by the dataflow models.

The paper evaluates *attention computation* (Figs 5-7) on OPT (MHA) and Qwen
(GQA) at sequence lengths 1K-64K, and end-to-end inference energy (Table II,
"overall energy") which additionally includes the projection / FFN GEMMs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable


@dataclass(frozen=True)
class AttentionWorkload:
    """One attention *core* (S = QK^T, softmax, PV) for a full model forward.

    Sizes are per-forward over `seq` tokens (prefill-style, as in the paper's
    inference evaluation).
    """

    name: str
    seq: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    n_layers: int
    batch: int = 1

    @property
    def group_size(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def total_head_instances(self) -> float:
        return float(self.n_heads * self.n_layers * self.batch)

    # exact op counts (per full forward, all layers/heads)
    @property
    def qk_macs(self) -> float:
        return self.total_head_instances * self.seq * self.seq * self.head_dim

    @property
    def pv_macs(self) -> float:
        return self.total_head_instances * self.seq * self.seq * self.head_dim

    @property
    def softmax_elems(self) -> float:
        return self.total_head_instances * self.seq * self.seq


@dataclass(frozen=True)
class ModelWorkload:
    """Full transformer forward: attention cores + projection/FFN GEMMs."""

    name: str
    attn: AttentionWorkload
    d_model: int
    d_ff: int
    vocab: int = 0
    # MoE: number of active experts' worth of FFN compute (top_k), 0 = dense
    moe_top_k: int = 0
    moe_experts: int = 0

    @property
    def proj_macs(self) -> float:
        """QKV + output projection MACs for the whole forward."""
        a = self.attn
        d_head_total_q = a.n_heads * a.head_dim
        d_head_total_kv = a.n_kv_heads * a.head_dim
        per_tok = (self.d_model * d_head_total_q            # Q
                   + 2 * self.d_model * d_head_total_kv     # K, V
                   + d_head_total_q * self.d_model)         # O
        return per_tok * a.seq * a.batch * a.n_layers

    @property
    def ffn_macs(self) -> float:
        a = self.attn
        mult = self.moe_top_k if self.moe_top_k else 1
        # gated-MLP (3 matmuls) for modern archs; OPT-style 2-matmul handled
        # as d_ff already folded.  Use 3 matmuls uniformly: up, gate, down.
        per_tok = 3 * self.d_model * self.d_ff * mult
        return per_tok * a.seq * a.batch * a.n_layers

    @property
    def weight_bytes(self) -> float:
        a = self.attn
        d_q = a.n_heads * a.head_dim
        d_kv = a.n_kv_heads * a.head_dim
        attn_w = self.d_model * (2 * d_q + 2 * d_kv)
        n_ffn = self.moe_experts if self.moe_experts else 1
        ffn_w = 3 * self.d_model * self.d_ff * n_ffn
        return (attn_w + ffn_w) * a.n_layers * 2.0  # bf16


# ---------------------------------------------------------------------------
# Paper workloads
# ---------------------------------------------------------------------------

def opt_6_7b(seq: int) -> ModelWorkload:
    """OPT-6.7B: MHA, 32 layers, 32 heads, d_head 128, d_ff 4*d_model."""
    attn = AttentionWorkload("opt-6.7b", seq=seq, n_heads=32, n_kv_heads=32,
                             head_dim=128, n_layers=32)
    return ModelWorkload("opt-6.7b", attn, d_model=4096, d_ff=16384,
                         vocab=50272)


def qwen_7b(seq: int) -> ModelWorkload:
    """Qwen2-7B-class GQA: 28 layers, 28 heads / 4 KV heads, d_head 128."""
    attn = AttentionWorkload("qwen-7b", seq=seq, n_heads=28, n_kv_heads=4,
                             head_dim=128, n_layers=28)
    return ModelWorkload("qwen-7b", attn, d_model=3584, d_ff=18944,
                         vocab=152064)


PAPER_MODELS = {"opt-6.7b": opt_6_7b, "qwen-7b": qwen_7b}
PAPER_SEQS = (1024, 4096, 16384, 65536)


def paper_grid() -> Iterable[ModelWorkload]:
    for mk in PAPER_MODELS.values():
        for s in PAPER_SEQS:
            yield mk(s)


def from_model_config(cfg, seq: int, batch: int = 1) -> AttentionWorkload:
    """Build an attention workload from a repro.configs ModelConfig."""
    n_kv = getattr(cfg, "n_kv_heads", cfg.n_heads) or cfg.n_heads
    return AttentionWorkload(
        name=cfg.name, seq=seq, n_heads=cfg.n_heads, n_kv_heads=n_kv,
        head_dim=cfg.head_dim, n_layers=cfg.n_layers, batch=batch)
