"""3D-Flow / 3D-FlashAttention core: the paper's contribution.

- arch:       Table I accelerator design points
- energy:     Accelergy-style activity -> energy model
- schedule:   latency-balanced tier scheduling (the 2d-cycle pipeline)
- dataflows:  analytical models of 3D-Flow and the four baselines
- simulator:  design x workload sweeps behind every paper figure
- workloads:  OPT (MHA) / Qwen (GQA) and assigned-arch attention workloads
- tpu_mapping: the paper's balance principle re-targeted at Pallas blocks
"""
from .arch import DESIGNS, AcceleratorSpec, get_spec
from .energy import Activity, EnergyBreakdown, EnergyTable, energy_of
from .schedule import (balance_chain, balanced_ii, is_bubble_free,
                       pipeline_cycles, threed_flash_schedule)
from .simulator import (SimResult, data_movement, mean_utilization,
                        normalized_energy, simulate_attention, simulate_model,
                        speedups, sweep)
from .thermal import ThermalSpec, junction_temp_c
from .thermal import report as thermal_report
from .tpu_mapping import BlockConfig, choose_block_config
from .workloads import (PAPER_MODELS, PAPER_SEQS, AttentionWorkload,
                        ModelWorkload, from_model_config, opt_6_7b, paper_grid,
                        qwen_7b)

__all__ = [
    "DESIGNS", "AcceleratorSpec", "get_spec",
    "Activity", "EnergyBreakdown", "EnergyTable", "energy_of",
    "balance_chain", "balanced_ii", "is_bubble_free", "pipeline_cycles",
    "threed_flash_schedule",
    "SimResult", "data_movement", "mean_utilization", "normalized_energy",
    "simulate_attention", "simulate_model", "speedups", "sweep",
    "BlockConfig", "choose_block_config",
    "ThermalSpec", "junction_temp_c", "thermal_report",
    "PAPER_MODELS", "PAPER_SEQS", "AttentionWorkload", "ModelWorkload",
    "from_model_config", "opt_6_7b", "paper_grid", "qwen_7b",
]
