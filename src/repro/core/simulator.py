"""Top-level simulator: design x workload -> cycles / energy / traffic.

Drives the analytical dataflow models (dataflows.py) and the Accelergy-style
energy model (energy.py); produces the records behind every paper figure.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional

from .arch import AcceleratorSpec, get_spec
from .dataflows import ATTENTION_MODELS, gemm_activity
from .energy import Activity, EnergyBreakdown, EnergyTable, energy_of
from .workloads import AttentionWorkload, ModelWorkload


@dataclass
class SimResult:
    design: str
    workload: str
    seq: int
    cycles: float
    time_s: float
    activity: Activity
    energy: EnergyBreakdown
    utilization: float

    @property
    def total_energy(self) -> float:
        return self.energy.total

    def row(self) -> Dict:
        return {
            "design": self.design, "workload": self.workload, "seq": self.seq,
            "cycles": self.cycles, "time_s": self.time_s,
            "energy_J": self.energy.total,
            "util": self.utilization,
            "dram_B": self.activity.dram_bytes,
            "sram_B": self.activity.sram_bytes,
            "tsv_B": self.activity.tsv_bytes,
            "noc_B": self.activity.noc_bytes,
            "reg_B": self.activity.reg_bytes,
        }


def _is_3d(name: str) -> bool:
    return name.startswith("3D")


def simulate_attention(design: str, wl: AttentionWorkload,
                       spec: Optional[AcceleratorSpec] = None,
                       table: Optional[EnergyTable] = None) -> SimResult:
    """Attention-core simulation (paper Figs 5-8)."""
    spec = spec or get_spec(design)
    table = table or EnergyTable.default16nm()
    act = ATTENTION_MODELS[design](spec, wl)
    eb = energy_of(act, table, is_3d=_is_3d(design),
                   time_s=act.cycles / spec.freq_hz)
    return SimResult(design=design, workload=wl.name, seq=wl.seq,
                     cycles=act.cycles, time_s=act.cycles / spec.freq_hz,
                     activity=act, energy=eb, utilization=act.utilization)


def simulate_model(design: str, mwl: ModelWorkload,
                   spec: Optional[AcceleratorSpec] = None,
                   table: Optional[EnergyTable] = None) -> SimResult:
    """End-to-end forward (attention core + projection/FFN GEMMs).

    The GEMM part is identical across designs (the technique targets the
    attention core); weights stream from DRAM once per forward.
    """
    spec = spec or get_spec(design)
    table = table or EnergyTable.default16nm()
    wl = mwl.attn
    act = ATTENTION_MODELS[design](spec, wl)

    # projections: per layer, (seq x d_model) x (d_model x out)
    d_q = wl.n_heads * wl.head_dim
    d_kv = wl.n_kv_heads * wl.head_dim
    tok = wl.seq * wl.batch
    for out in (d_q, d_kv, d_kv, mwl.d_model):
        g = gemm_activity(spec, tok, mwl.d_model, out)
        act = act + g.scaled(wl.n_layers)
    # FFN (gated 3-matmul); MoE runs top_k experts' worth of compute
    mult = mwl.moe_top_k if mwl.moe_top_k else 1
    for (m, k, n) in ((tok, mwl.d_model, mwl.d_ff), (tok, mwl.d_model, mwl.d_ff),
                      (tok, mwl.d_ff, mwl.d_model)):
        g = gemm_activity(spec, m * mult, k, n)
        act = act + g.scaled(wl.n_layers)
    # weight DRAM traffic: whole parameter set streamed once per forward
    act.dram_bytes += mwl.weight_bytes

    eb = energy_of(act, table, is_3d=_is_3d(design),
                   time_s=act.cycles / spec.freq_hz)
    return SimResult(design=design, workload=mwl.name, seq=wl.seq,
                     cycles=act.cycles, time_s=act.cycles / spec.freq_hz,
                     activity=act, energy=eb, utilization=act.utilization)


def sweep(designs: Iterable[str], workloads: Iterable[AttentionWorkload],
          table: Optional[EnergyTable] = None) -> list:
    return [simulate_attention(dsn, wl, table=table)
            for dsn in designs for wl in workloads]


# ---------------------------------------------------------------------------
# Figure-level aggregates
# ---------------------------------------------------------------------------

def normalized_energy(results: list, baseline: str = "2D-Unfused") -> Dict:
    """Fig 5: energy normalized to the 2D-Unfused baseline per (wl, seq)."""
    base = {(r.workload, r.seq): r.total_energy
            for r in results if r.design == baseline}
    out: Dict = {}
    for r in results:
        out.setdefault(r.design, {})[(r.workload, r.seq)] = \
            r.total_energy / base[(r.workload, r.seq)]
    return out


def speedups(results: list, ours: str = "3D-Flow") -> Dict:
    """Fig 7: mean speedup of `ours` over every other design."""
    ours_t = {(r.workload, r.seq): r.time_s for r in results if r.design == ours}
    agg: Dict = {}
    for r in results:
        if r.design == ours:
            continue
        agg.setdefault(r.design, []).append(
            r.time_s / ours_t[(r.workload, r.seq)])
    return {k: sum(v) / len(v) for k, v in agg.items()}


def mean_utilization(results: list) -> Dict:
    agg: Dict = {}
    for r in results:
        agg.setdefault(r.design, []).append(r.utilization)
    return {k: sum(v) / len(v) for k, v in agg.items()}


def data_movement(results: list) -> Dict:
    """Fig 6: mean DRAM / SRAM / vertical traffic per design."""
    agg: Dict = {}
    for r in results:
        e = agg.setdefault(r.design, {"dram": [], "sram": [], "tsv": []})
        e["dram"].append(r.activity.dram_bytes)
        e["sram"].append(r.activity.sram_bytes)
        e["tsv"].append(r.activity.tsv_bytes)
    return {k: {m: sum(v) / len(v) for m, v in d.items()}
            for k, d in agg.items()}
