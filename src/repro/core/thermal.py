"""Thermal feasibility model of the 4-tier stack (paper Section III-C).

First-order thermal-resistance model of the hybrid-bonded stack:

  * per-PE peak power P_PE = 200 uW; one 128x128 tier ~ 3.3 W
  * four-tier stack P_total ~ 13.1 W over A ~ 80 mm^2
  * layer power density rho ~ 41 W/cm^2
  * internal (tier-to-tier) rise ~ 2.8 C (good vertical conduction)
  * junction temperature at 25 C ambient with R_thJA ~ 2.5 K/W: ~ 83 C

ERRATA found while reproducing (documented, not silently "fixed"):
  1. rho: 3.3 W over the stated A = 80 mm^2 gives 4.1 W/cm^2, not 41 -
     the paper's 41 W/cm^2 requires A = 8 mm^2.
  2. Tj: 25 C + 13.1 W x 2.5 K/W + 2.8 C = 60.6 C, not 83 C - the paper's
     83 C requires ~23 W.  Our faithful evaluation of their own formula
     gives a LOWER Tj, so the feasibility conclusion holds a fortiori.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ThermalSpec:
    p_pe_w: float = 200e-6            # peak per-PE power
    array_dim: int = 128
    n_tiers: int = 4
    area_mm2: float = 80.0            # synthesized tier area
    # vertical stack conduction: silicon ~ 1.2 cm^2K/W per 100um die +
    # hybrid-bond interface; effective per-tier interface resistance:
    r_tier_cm2_k_per_w: float = 0.15   # calibrated to the paper's 2.8 C rise
    r_theta_ja_k_per_w: float = 2.5   # conservative package (TI SPRA953)
    ambient_c: float = 25.0
    util: float = 0.87                # average activity (Fig 8)


def tier_power_w(spec: ThermalSpec = ThermalSpec()) -> float:
    return spec.p_pe_w * spec.array_dim ** 2


def total_power_w(spec: ThermalSpec = ThermalSpec()) -> float:
    return tier_power_w(spec) * spec.n_tiers


def power_density_w_cm2(spec: ThermalSpec = ThermalSpec()) -> float:
    return tier_power_w(spec) / (spec.area_mm2 / 100.0)


def internal_rise_c(spec: ThermalSpec = ThermalSpec()) -> float:
    """Temperature rise from the top tier to the heat-sink-side tier:
    heat from tier i crosses (n_tiers - 1 - i) interfaces."""
    area_cm2 = spec.area_mm2 / 100.0
    r_if = spec.r_tier_cm2_k_per_w / area_cm2        # K/W per interface
    p = tier_power_w(spec)
    rise = 0.0
    for i in range(spec.n_tiers):
        rise += p * r_if * i                          # tier i crosses i ifaces
    return rise / spec.n_tiers * (spec.n_tiers - 1)   # mean-to-worst spread


def junction_temp_c(spec: ThermalSpec = ThermalSpec()) -> float:
    return (spec.ambient_c
            + total_power_w(spec) * spec.r_theta_ja_k_per_w
            + internal_rise_c(spec))


def feasible(spec: ThermalSpec = ThermalSpec(), t_max_c: float = 105.0) -> bool:
    """TSMC 16nm commercial junction limit 105 C."""
    return junction_temp_c(spec) <= t_max_c


def report(spec: ThermalSpec = ThermalSpec()) -> dict:
    return {
        "tier_power_w": tier_power_w(spec),
        "total_power_w": total_power_w(spec),
        "power_density_w_cm2": power_density_w_cm2(spec),
        "internal_rise_c": internal_rise_c(spec),
        "junction_temp_c": junction_temp_c(spec),
        "feasible_105c": feasible(spec),
    }
