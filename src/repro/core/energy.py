"""Accelergy-style activity -> energy model.

Per-action energies start from public technology numbers (Horowitz, ISSCC'14,
scaled 45nm -> 16nm by ~0.35x voltage/cap scaling) and are calibrated within
physically plausible ranges so that the paper's published *ratios* hold
simultaneously (SRAM access = 10-20x FMA per element; Table II shares; Fig 5/6
relative energies).  The TSV z-hop energy is fixed at the paper's own number
(1.35 pJ/byte, from stacked-DRAM analysis, stated as a conservative upper
bound for register-to-register hybrid-bonded transfers).

All energies are Joules; activity counts are raw op / byte counts.
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields


@dataclass
class Activity:
    """Raw activity counts accumulated by a dataflow model."""

    macs: float = 0.0          # bf16 multiply-accumulates
    exp_ops: float = 0.0       # exponential evaluations (exp2-based)
    alu_ops: float = 0.0       # cmp / add / mul vector-lane ops
    reg_bytes: float = 0.0     # register-file bytes read+written
    sram_bytes: float = 0.0    # on-chip SRAM bytes read+written
    dram_bytes: float = 0.0    # off-chip DRAM bytes read+written
    tsv_bytes: float = 0.0     # 3D hybrid-bonded vertical link bytes
    noc_bytes: float = 0.0     # 2D inter-array NoC bytes (Dual-SA)

    cycles: float = 0.0        # wall-clock cycles for the modeled workload
    busy_pe_cycles: float = 0.0
    total_pe_cycles: float = 0.0

    def __add__(self, other: "Activity") -> "Activity":
        out = Activity()
        for f in fields(Activity):
            setattr(out, f.name, getattr(self, f.name) + getattr(other, f.name))
        return out

    def scaled(self, k: float) -> "Activity":
        out = Activity()
        for f in fields(Activity):
            setattr(out, f.name, getattr(self, f.name) * k)
        return out

    @property
    def utilization(self) -> float:
        if self.total_pe_cycles <= 0:
            return 0.0
        return self.busy_pe_cycles / self.total_pe_cycles


@dataclass(frozen=True)
class EnergyTable:
    """pJ-per-action table (stored in Joules)."""

    e_mac: float = 0.05e-12       # bf16 FMA datapath only @16nm (RF metered
    #                                 separately via REG_BYTES_PER_MAC)
    e_exp: float = 1.2e-12        # piecewise exp2 unit (ISCAS'22-style)
    e_alu: float = 0.1e-12        # cmp/add/mul lane op
    e_reg_byte: float = 0.02e-12  # register-file access energy per byte
    # Large (60 MB, heavily banked) on-chip SRAM: ~10 pJ per 2-byte element
    # dynamic access (banking + long wires of a 60 MB macro); the paper's
    # quoted 10-20x-FMA band refers to the cache sizes of [12] - a 60 MB
    # macro sits above it.  Static retention is charged separately below.
    e_sram_byte: float = 12.0e-12
    e_dram_byte: float = 46.0e-12  # LPDDR-class off-chip access (~0.37 nJ/bit)
    e_tsv_byte: float = 1.35e-12   # paper's conservative z-axis number
    e_noc_byte: float = 2.0e-12    # 2D router+link per-byte
    # Static 3D-IC overhead (power delivery / thermal / clock distribution of
    # the stack) as a fraction of dynamic energy of 3D designs:
    static_3d_frac: float = 0.02
    # Static power charged per wall-clock second: 60 MB SRAM retention +
    # periphery (16 nm HD SRAM) and DRAM background/refresh.  Slow designs
    # pay for every stalled cycle - a first-order reason unfused execution
    # loses even at short sequence lengths.  Attributed 70/30 SRAM/DRAM.
    static_w: float = 0.3
    static_sram_frac: float = 0.3

    @staticmethod
    def default16nm() -> "EnergyTable":
        return EnergyTable()


@dataclass
class EnergyBreakdown:
    mac: float = 0.0
    reg: float = 0.0
    sram: float = 0.0
    dram: float = 0.0
    overhead_3d: float = 0.0   # TSV transfers + stack static overhead
    noc: float = 0.0
    vector: float = 0.0        # exp + alu on vector/SFU units

    @property
    def total(self) -> float:
        return (self.mac + self.reg + self.sram + self.dram
                + self.overhead_3d + self.noc + self.vector)

    def as_dict(self) -> dict:
        return {
            "MAC": self.mac,
            "Vector": self.vector,
            "Reg": self.reg,
            "SRAM": self.sram,
            "DRAM": self.dram,
            "NoC": self.noc,
            "3D-IC": self.overhead_3d,
            "Total": self.total,
        }

    def shares(self) -> dict:
        t = self.total or 1.0
        return {k: v / t for k, v in self.as_dict().items() if k != "Total"}


def energy_of(act: Activity, tbl: EnergyTable, *, is_3d: bool = False,
              time_s: float = 0.0) -> EnergyBreakdown:
    """Fold an activity trace into an energy breakdown.

    `time_s` is the wall-clock duration of the workload; SRAM retention /
    idle-logic leakage is charged against it and attributed to SRAM.
    """
    eb = EnergyBreakdown()
    eb.mac = act.macs * tbl.e_mac
    eb.vector = act.exp_ops * tbl.e_exp + act.alu_ops * tbl.e_alu
    eb.reg = act.reg_bytes * tbl.e_reg_byte
    eb.sram = (act.sram_bytes * tbl.e_sram_byte
               + tbl.static_w * tbl.static_sram_frac * time_s)
    eb.dram = (act.dram_bytes * tbl.e_dram_byte
               + tbl.static_w * (1.0 - tbl.static_sram_frac) * time_s)
    eb.noc = act.noc_bytes * tbl.e_noc_byte
    tsv = act.tsv_bytes * tbl.e_tsv_byte
    if is_3d:
        dynamic = eb.mac + eb.vector + eb.reg + eb.sram + eb.dram + eb.noc + tsv
        eb.overhead_3d = tsv + tbl.static_3d_frac * dynamic
    else:
        eb.overhead_3d = tsv
    return eb
