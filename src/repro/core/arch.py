"""Hardware architecture specifications for 3D-Flow and its baselines.

Reproduces Table I of the paper:

    |                   | Ours / 3D-Base | 2D-Unfused / 2D-Fused | Dual-SA     |
    | Array Size        | 128x128x4      | 128x128               | 128x128x2   |
    | Clusters          | 1              | 4                     | 2           |
    | On-Chip Mem. Size | 60MB           | 60MB                  | 60MB        |
    | On-Chip BW        | 8 TB/s         | 8 TB/s                | 8 TB/s      |
    | Off-Chip BW       | 400 GB/s       | 400 GB/s              | 400 GB/s    |

All designs have identical total compute (128*128*4 PEs) and identical memory
resources; they differ only in how the PEs are organized (stacked tiers vs
planar clusters) and how intermediates move between operators.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

KB = 1024
MB = 1024 * KB
GB = 1024 * MB


@dataclass(frozen=True)
class AcceleratorSpec:
    """Static description of one accelerator design point."""

    name: str
    # -- compute fabric -------------------------------------------------
    array_dim: int = 128          # PE rows == cols of one tier / cluster array
    n_tiers: int = 1              # vertically stacked tiers (3D designs)
    n_clusters: int = 4           # independent planar arrays (2D designs)
    freq_hz: float = 1e9          # 1 GHz clock, paper-typical for 16 nm NPUs
    dtype_bytes: int = 2          # bf16 datapath

    # -- memory hierarchy (Table I) --------------------------------------
    sram_bytes: int = 60 * MB
    onchip_bw_Bps: float = 8e12   # 8 TB/s aggregate SRAM bandwidth
    offchip_bw_Bps: float = 400e9  # 400 GB/s DRAM bandwidth

    # -- microarchitectural knobs (calibrated; see DESIGN.md §7) ---------
    # Vector/scalar unit throughput for softmax on 2D designs.  The paper's
    # motivation: "softmax runs on slower scalar or vector units, causing
    # stalls".  elem ops (add/cmp/mul) per cycle per cluster:
    vec_elem_per_cycle: float = 26.4
    # exponential throughput (exp is multi-cycle on scalar/vector units):
    vec_exp_per_cycle: float = 3.3
    # Dedicated softmax SFU throughput for Dual-SA (exp/cycle):
    sfu_exp_per_cycle: float = 64.0
    # SRAM port width seen by one array/tier when exchanging intermediates
    # (bytes/cycle).  This is the serialization the paper identifies: "data
    # transfer between large caches and systolic arrays is serialized over
    # multiple cycles".
    sram_port_bytes_per_cycle: float = 1792.0
    # 2D inter-array NoC: router-to-router transfer (Dual-SA drain/inject).
    noc_bytes_per_cycle: float = 80.0
    noc_hop_latency: float = 24.0  # cycles per tile handoff through the NoC
    # fraction of per-cluster SRAM usable for score-matrix residency before
    # the unfused design must spill S/P to DRAM
    sram_resident_frac: float = 0.8
    # 3D hybrid-bonded TSV link: one element per PE per cycle, single-cycle
    # latency (sub-10um pitch hybrid bonding).
    tsv_latency_cycles: float = 1.0

    @property
    def pes_per_array(self) -> int:
        return self.array_dim * self.array_dim

    @property
    def total_pes(self) -> int:
        return self.pes_per_array * self.n_tiers * self.n_clusters

    @property
    def onchip_bytes_per_cycle(self) -> float:
        return self.onchip_bw_Bps / self.freq_hz

    @property
    def offchip_bytes_per_cycle(self) -> float:
        return self.offchip_bw_Bps / self.freq_hz

    def replace(self, **kw) -> "AcceleratorSpec":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Table I design points.  Total PEs identical (= 128*128*4) across designs.
# ---------------------------------------------------------------------------

def ours_3dflow() -> AcceleratorSpec:
    """3D-Flow: one 128x128x4 hybrid-bonded stack, register-to-register TSVs."""
    return AcceleratorSpec(name="3D-Flow", n_tiers=4, n_clusters=1)


def base_3d() -> AcceleratorSpec:
    """3D-Base: architecturally identical stack; operators per tier but
    intermediates exchanged via on-chip SRAM (mapping of ISQED'21 / SiPS'18)."""
    return AcceleratorSpec(name="3D-Base", n_tiers=4, n_clusters=1)


def unfused_2d() -> AcceleratorSpec:
    """2D-Unfused: 4 planar clusters; attention phases run sequentially with
    full S / P materialization through SRAM (and DRAM once SRAM overflows)."""
    return AcceleratorSpec(name="2D-Unfused", n_tiers=1, n_clusters=4)


def fused_2d() -> AcceleratorSpec:
    """2D-Fused: FuseMax / FLAT / TileFlow-class deep fusion on planar arrays."""
    return AcceleratorSpec(name="2D-Fused", n_tiers=1, n_clusters=4)


def dual_sa() -> AcceleratorSpec:
    """Dual-SA: COSA-class dual systolic arrays + dedicated softmax SFU."""
    return AcceleratorSpec(name="Dual-SA", n_tiers=2, n_clusters=2)


DESIGNS = {
    "3D-Flow": ours_3dflow,
    "3D-Base": base_3d,
    "2D-Unfused": unfused_2d,
    "2D-Fused": fused_2d,
    "Dual-SA": dual_sa,
}


def get_spec(name: str) -> AcceleratorSpec:
    try:
        return DESIGNS[name]()
    except KeyError:
        raise KeyError(f"unknown design {name!r}; one of {sorted(DESIGNS)}")
