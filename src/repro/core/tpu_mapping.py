"""Bridge from the paper's latency-balanced tier scheduling to TPU kernels.

The paper's scheduling principle: assign the fused attention chain to compute
stages such that every stage has the same initiation interval -> bubble-free
pipeline.  On TPU the "tiers" are the MXU (128x128 systolic matmul) and the
VPU (8x128 vector unit), and the "TSV register links" are VREGs/VMEM inside a
single Pallas kernel.  The degree of freedom is the block shape
(block_q, block_kv): it sets the per-block latency of each stage and the VMEM
working set.

This module picks block shapes by the same balance criterion the paper uses
across tiers, and checks the Pallas grid pipeline is "bubble-free" in the
paper's sense: HBM->VMEM DMA time for the next block <= compute time of the
current block.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Tuple

from .schedule import balance_chain

# TPU v5e-class hardware constants (per core)
MXU_DIM = 128
MXU_FLOPS_PER_CYCLE = 2 * MXU_DIM * MXU_DIM      # one 128x128 MAC wave / cycle
VPU_LANES = 8 * 128                              # 8 sublanes x 128 lanes
VPU_EXP_CYCLES = 4.0                             # transcendental cost factor
VMEM_BYTES = 64 * 1024 * 1024                    # ~64 MiB usable VMEM budget
HBM_BYTES_PER_CYCLE = 819e9 / 0.94e9             # ~871 B/cycle at 940 MHz


@dataclass(frozen=True)
class BlockConfig:
    """Chosen Pallas block shapes for the fused attention kernel."""
    block_q: int
    block_kv: int
    stages: Tuple[Tuple[str, float], ...]   # (stage name, cycles per block)
    vmem_bytes: int
    mxu_cycles: float
    vpu_cycles: float
    dma_cycles: float

    @property
    def balanced(self) -> float:
        """Stage imbalance: max/mean stage latency (1.0 = perfectly balanced,
        the paper's bubble-free criterion)."""
        lat = [c for _, c in self.stages]
        return max(lat) / (sum(lat) / len(lat))

    @property
    def bubble_free(self) -> bool:
        """Grid pipeline analogue of the paper's 2d-cycle property: next
        block's DMA hides under current block's compute."""
        return self.dma_cycles <= (self.mxu_cycles + self.vpu_cycles)


def stage_latencies(block_q: int, block_kv: int, head_dim: int,
                    dtype_bytes: int = 2) -> List[Tuple[str, float]]:
    """Per-block latency of each fused-chain stage on its TPU unit.

    Mirrors the paper's four tiers:
      QK^T  -> MXU
      rowmax/subtract -> VPU
      exp/rowsum/rescale -> VPU (transcendental-weighted)
      PV + O update -> MXU
    """
    mm1 = block_q * block_kv * head_dim          # MACs
    qk = 2.0 * mm1 / MXU_FLOPS_PER_CYCLE
    elems = block_q * block_kv
    rowmax = 2.0 * elems / VPU_LANES
    expsum = elems * (VPU_EXP_CYCLES + 2.0) / VPU_LANES
    mm2 = block_q * block_kv * head_dim
    pv = 2.0 * mm2 / MXU_FLOPS_PER_CYCLE + 2.0 * block_q * head_dim / VPU_LANES
    return [("qk", qk), ("rowmax", rowmax), ("expsum", expsum), ("pv", pv)]


def vmem_working_set(block_q: int, block_kv: int, head_dim: int,
                     dtype_bytes: int = 2, acc_bytes: int = 4) -> int:
    """Double-buffered VMEM bytes for one grid step of the fused kernel."""
    q = block_q * head_dim * dtype_bytes
    kv = 2 * block_kv * head_dim * dtype_bytes
    s = block_q * block_kv * acc_bytes              # scores in fp32
    o = block_q * head_dim * acc_bytes
    stats = 2 * block_q * acc_bytes
    return 2 * (q + kv) + s + o + stats             # in/out double buffering


def choose_block_config(head_dim: int, seq_len: int, dtype_bytes: int = 2,
                        vmem_budget: int = VMEM_BYTES // 2) -> BlockConfig:
    """Latency-balanced block-shape selection (the paper's scheduling method
    re-targeted at MXU/VPU stage balance).

    Candidates are MXU-aligned (multiples of 128).  Of the candidates that
    (a) fit the VMEM budget and (b) are bubble-free (DMA hidden), pick the one
    minimizing stage imbalance, tie-breaking on larger blocks (fewer grid
    steps, better MXU occupancy).
    """
    cands = []
    for bq in (128, 256, 512, 1024):
        if bq > max(seq_len, 128):
            continue
        for bkv in (128, 256, 512, 1024, 2048):
            if bkv > max(seq_len, 128):
                continue
            stages = stage_latencies(bq, bkv, head_dim, dtype_bytes)
            vmem = vmem_working_set(bq, bkv, head_dim, dtype_bytes)
            if vmem > vmem_budget:
                continue
            mxu = sum(c for n, c in stages if n in ("qk", "pv"))
            vpu = sum(c for n, c in stages if n in ("rowmax", "expsum"))
            dma = (bkv * head_dim * 2 * dtype_bytes) / HBM_BYTES_PER_CYCLE
            cfg = BlockConfig(block_q=bq, block_kv=bkv,
                              stages=tuple(stages), vmem_bytes=vmem,
                              mxu_cycles=mxu, vpu_cycles=vpu, dma_cycles=dma)
            cands.append(cfg)
    if not cands:
        stages = stage_latencies(128, 128, head_dim, dtype_bytes)
        return BlockConfig(128, 128, tuple(stages),
                           vmem_working_set(128, 128, head_dim),
                           sum(c for n, c in stages if n in ("qk", "pv")),
                           sum(c for n, c in stages if n in ("rowmax", "expsum")),
                           0.0)
    bubble_free = [c for c in cands if c.bubble_free] or cands
    return min(bubble_free, key=lambda c: (round(c.balanced, 3),
                                           -c.block_q * c.block_kv))


def tier_assignment_for_chain(costs: List[float], n_units: int = 4):
    """Expose the generalized latency balancer for arbitrary fused chains
    (paper: "generalizable to other fused operators beyond attention")."""
    return balance_chain(costs, n_units)
