"""Analytical dataflow models for the five evaluated accelerator designs.

Each model walks the attention computation at tile granularity (the same
granularity as the paper's in-house simulator) and accumulates an Activity
trace: cycles, MAC/exp/alu ops, and byte traffic at every level of the
hierarchy (register / SRAM / DRAM / TSV / NoC).

Conventions
-----------
* `d` = attention head dimension = PE array dimension (128 in the paper).
* One "tile" = one FlashAttention-2 inner-loop iteration over a d x d block
  (Algorithm 1, lines 6-19).
* The paper evaluates non-causal prefill attention; op counts use full N^2.
* GQA: K/V off-chip traffic is paid once per KV head and amortized across the
  `group_size` query heads that share it (K/V stay resident in SRAM).
* Head instances are scheduled onto `n_clusters` parallel units; 3D designs
  have a single (stacked) cluster and process heads sequentially, exactly as
  in the paper ("multiple heads can be processed in parallel by integrating
  multiple 3D-stacked PE arrays" - Table I gives ours 1 cluster).

Utilization is *array-level* (paper Fig. 8: "Average utilization of PE
arrays"): the fraction of array-cycles in which an array/tier is actively
streaming computation rather than stalled on memory, a slower producer, or a
phase boundary.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from .arch import AcceleratorSpec
from .energy import Activity
from .schedule import (pipeline_cycles, pipeline_depth, pipeline_period,
                       threed_flash_schedule)
from .workloads import AttentionWorkload

# ---------------------------------------------------------------------------
# Calibrated micro-constants (see DESIGN.md §7).  Register traffic per op
# counts only *architectural* register-file accesses that Accelergy would
# meter (psum read-modify-write, operand staging); the operand-forwarding
# flip-flops inside a systolic PE are part of the MAC energy.
# ---------------------------------------------------------------------------
# Each systolic MAC performs 4 architectural register accesses (two operand
# registers read-forward, psum read + write) of 2 bytes each - the classic
# Eyeriss/Accelergy RF accounting.  This is why the paper's Table II shows
# register energy 2-3x MAC energy.
REG_BYTES_PER_MAC = 8.0
REG_BYTES_PER_VECOP = 4.0        # vector/scalar op operand staging
# 3D-Flow keeps the running state (old_m, old_l, old_O) plus forwarded
# operands in PE-local registers - the paper's "increased register access".
REG_BYTES_PER_TSV_BYTE = 2.0     # write on producer tier + read on consumer
FUSEMAX_CTX_REGS = 10            # FuseMax stores 10 intermediates per PE


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclass(frozen=True)
class TileGeom:
    """Tile geometry for one head."""
    N: int
    d: int

    @property
    def Tr(self) -> int:
        return _ceil_div(self.N, self.d)

    @property
    def Tc(self) -> int:
        return _ceil_div(self.N, self.d)

    @property
    def tiles(self) -> int:
        return self.Tr * self.Tc


def _qkv_dram_bytes(wl: AttentionWorkload, B: int) -> float:
    """Compulsory off-chip traffic for the whole workload (all heads/layers):
    Q and O per query head; K and V once per KV head (GQA reuse in SRAM)."""
    per_q_head = 2.0 * wl.seq * wl.head_dim * B          # Q read + O write
    per_kv_head = 2.0 * wl.seq * wl.head_dim * B         # K read + V read
    return (wl.n_heads * per_q_head + wl.n_kv_heads * per_kv_head) \
        * wl.n_layers * wl.batch


def _heads_wall_factor(wl: AttentionWorkload, parallel_units: int) -> float:
    """Wall-clock multiplier: head instances executed per parallel unit."""
    return _ceil_div(int(wl.total_head_instances), parallel_units)


# ===========================================================================
# 3D-Flow (ours)
# ===========================================================================

def flow3d_attention(spec: AcceleratorSpec, wl: AttentionWorkload) -> Activity:
    """The paper's co-designed dataflow: 4-tier register-to-register pipeline,
    one inner-loop tile per 2d cycles in steady state, no SRAM round-trips for
    intermediates."""
    d = spec.array_dim
    B = spec.dtype_bytes
    g = TileGeom(wl.seq, d)
    stages = threed_flash_schedule(B)
    act = Activity()

    H = wl.total_head_instances

    # ---- per-head cycles: bubble-free vertical pipeline -------------------
    per_head_cycles = pipeline_cycles(g.tiles, stages, d)
    # outer-loop boundary: final O scaling by diag(l)^-1 (Alg.1 line 21),
    # overlapped except for a d-cycle drain per outer row
    per_head_cycles += g.Tr * d
    wall = per_head_cycles * _heads_wall_factor(wl, spec.n_clusters)

    # ---- op counts --------------------------------------------------------
    per_tile_macs = sum(s.macs(d) for s in stages)
    per_tile_exp = sum(s.exp_ops(d) for s in stages)
    per_tile_alu = sum(s.alu_ops(d) for s in stages)
    per_tile_tsv = sum(s.tsv_out_bytes(d) for s in stages)

    act.macs = H * g.tiles * per_tile_macs
    act.exp_ops = H * g.tiles * per_tile_exp
    act.alu_ops = H * g.tiles * per_tile_alu + H * g.Tr * d * d  # line 21
    act.tsv_bytes = H * g.tiles * per_tile_tsv

    # ---- register traffic -------------------------------------------------
    # psum + running-state (old_m, old_l: 2d elems; old_O: d^2 elems) kept in
    # registers and updated once per tile
    state_reg = (2.0 * d + d * d) * B * 2.0   # read+write per tile
    act.reg_bytes = (act.macs * REG_BYTES_PER_MAC
                     + (act.exp_ops + act.alu_ops) * REG_BYTES_PER_VECOP
                     + act.tsv_bytes * REG_BYTES_PER_TSV_BYTE
                     + H * g.tiles * state_reg)

    # ---- SRAM traffic: tile injection only (Q_i, K_j, V_j per tile) plus
    # final O write-back.  NO intermediate round-trips - the paper's point.
    act.sram_bytes = H * (g.tiles * 3.0 * d * d * B      # Q,K,V injection
                          + g.Tr * d * d * B)            # O write
    # staging DRAM->SRAM (double-buffered): counted once as SRAM writes
    act.sram_bytes += _qkv_dram_bytes(wl, B)

    act.dram_bytes = _qkv_dram_bytes(wl, B)

    # ---- cycles & utilization --------------------------------------------
    act.cycles = wall
    n_arrays = spec.n_tiers * spec.n_clusters
    act.total_pe_cycles = wall * n_arrays
    # each tier streams continuously while the pipeline is full; fill/drain
    # and the per-outer-row scaling drain are the only idle windows
    steady = g.tiles * pipeline_period(stages, d)
    per_head_busy = 4.0 * steady * 0.89   # intra-window occupancy of wavefront
    act.busy_pe_cycles = per_head_busy * _heads_wall_factor(wl, spec.n_clusters)
    return act


# ===========================================================================
# 3D-Base: same stack, operators per tier, but intermediates exchanged via
# SRAM (ISQED'21 / SiPS'18-style mapping).  Broadcast input reuse via TSV.
# ===========================================================================

def base3d_attention(spec: AcceleratorSpec, wl: AttentionWorkload) -> Activity:
    d = spec.array_dim
    B = spec.dtype_bytes
    g = TileGeom(wl.seq, d)
    stages = threed_flash_schedule(B)
    act = Activity()
    H = wl.total_head_instances

    # Every inter-tier transfer becomes an SRAM write + read, serialized over
    # the tier's SRAM port.  Three tier boundaries; traffic per tile ~= the
    # TSV bytes of 3D-Flow.
    per_tile_boundary_bytes = sum(s.tsv_out_bytes(d) for s in stages)
    roundtrip_bytes = 2.0 * per_tile_boundary_bytes
    stall = roundtrip_bytes / spec.sram_port_bytes_per_cycle

    period = pipeline_period(stages, d) + stall
    per_head_cycles = (pipeline_depth(stages, d)
                       + (g.tiles - 1) * period + g.Tr * d)
    wall = per_head_cycles * _heads_wall_factor(wl, spec.n_clusters)

    per_tile_macs = sum(s.macs(d) for s in stages)
    per_tile_exp = sum(s.exp_ops(d) for s in stages)
    per_tile_alu = sum(s.alu_ops(d) for s in stages)
    act.macs = H * g.tiles * per_tile_macs
    act.exp_ops = H * g.tiles * per_tile_exp
    act.alu_ops = H * g.tiles * per_tile_alu + H * g.Tr * d * d

    # input reuse via TSV broadcast (Q tile broadcast to tiers): counted as
    # TSV traffic, saving one of the three SRAM injections
    act.tsv_bytes = H * g.tiles * d * d * B
    act.sram_bytes = (H * (g.tiles * 2.0 * d * d * B     # K,V injection
                           + g.Tr * d * d * B)           # O write
                      + H * g.tiles * roundtrip_bytes    # intermediates!
                      + _qkv_dram_bytes(wl, B))
    act.dram_bytes = _qkv_dram_bytes(wl, B)

    state_reg = (2.0 * d + d * d) * B * 2.0
    act.reg_bytes = (act.macs * REG_BYTES_PER_MAC
                     + (act.exp_ops + act.alu_ops) * REG_BYTES_PER_VECOP
                     + act.tsv_bytes * REG_BYTES_PER_TSV_BYTE
                     + H * g.tiles * state_reg)

    act.cycles = wall
    n_arrays = spec.n_tiers * spec.n_clusters
    act.total_pe_cycles = wall * n_arrays
    steady = g.tiles * pipeline_period(stages, d)   # useful fraction
    act.busy_pe_cycles = (4.0 * steady * 0.92
                          * _heads_wall_factor(wl, spec.n_clusters))
    return act


# ===========================================================================
# 2D-Unfused: true kernel-per-operator execution - the semantics
# FlashAttention was invented to eliminate.
# ===========================================================================

def unfused2d_attention(spec: AcceleratorSpec, wl: AttentionWorkload) -> Activity:
    """Every operator materializes its output off-chip: S and P round-trip
    DRAM between kernels, and the softmax chain runs as five separate vector
    kernels (rowmax, subtract, exp, rowsum, scale), each streaming operands
    from/to DRAM.  On-chip SRAM only stages GEMM operand tiles (DMA-in +
    array injection) - unfused scheduling has no cross-kernel residency, and
    GQA K/V sharing is not exploited."""
    d = spec.array_dim
    B = spec.dtype_bytes
    g = TileGeom(wl.seq, d)
    act = Activity()
    H = wl.total_head_instances
    par = spec.n_clusters

    s_bytes = float(wl.seq) * wl.seq * B          # one S (or P) matrix
    dram_bpc = spec.offchip_bytes_per_cycle / spec.n_clusters

    # ---- off-chip intermediate transfers (per head) ------------------------
    # GEMM boundaries: S write (QK^T out), P read (PV in)         -> 2
    # softmax chain:   S r | S r + N w | N r + P w | P r | P r+w  -> 8
    dram_interm = 10.0 * s_bytes

    # ---- phase cycles (per head, one cluster); phases are barriers ---------
    qk_cycles = g.tiles * 2.0 * d + d + s_bytes / dram_bpc        # S to DRAM
    n_elems = float(wl.seq) * wl.seq
    sm_compute = (n_elems * 3.0 / spec.vec_elem_per_cycle         # max+sub+scale
                  + n_elems / spec.vec_exp_per_cycle)             # exp
    sm_cycles = max(sm_compute, 8.0 * s_bytes / dram_bpc)
    pv_cycles = g.tiles * 2.0 * d + d + s_bytes / dram_bpc        # P from DRAM

    per_head_cycles = qk_cycles + sm_cycles + pv_cycles
    wall = per_head_cycles * _heads_wall_factor(wl, par)

    # ---- ops ---------------------------------------------------------------
    act.macs = wl.qk_macs + wl.pv_macs
    act.exp_ops = wl.softmax_elems
    act.alu_ops = wl.softmax_elems * 3.0

    # ---- traffic ------------------------------------------------------------
    inject = g.tiles * 2.0 * d * d * B * 2.0      # (Q,K) + (P,V) injections
    staging = g.tiles * 2.0 * d * d * B * 2.0     # DMA-in staging of the same
    per_head_io = 2.0 * wl.seq * wl.head_dim * B  # Q read + O write
    per_head_kv = 2.0 * wl.seq * wl.head_dim * B  # K + V, per q head (no GQA)
    compulsory = H * (per_head_io + per_head_kv)
    act.sram_bytes = H * (inject + staging + g.Tr * d * d * B)
    act.dram_bytes = compulsory + H * dram_interm
    act.reg_bytes = (act.macs * REG_BYTES_PER_MAC
                     + (act.exp_ops + act.alu_ops) * REG_BYTES_PER_VECOP)

    act.cycles = wall
    act.total_pe_cycles = wall * spec.n_tiers * spec.n_clusters
    # arrays idle during the whole softmax phase and all DRAM stalls
    busy = (g.tiles * 2.0 * d) * 2.0 * 0.92       # QK^T + PV streaming
    act.busy_pe_cycles = busy * _heads_wall_factor(wl, par)
    return act


# ===========================================================================
# 2D-Fused: FuseMax / FLAT / TileFlow-class deep fusion on a single planar
# array per cluster.  No S/P DRAM materialization, but every operator hand-
# off round-trips SRAM, and softmax reductions time-multiplex the array.
# ===========================================================================

def fused2d_attention(spec: AcceleratorSpec, wl: AttentionWorkload) -> Activity:
    d = spec.array_dim
    B = spec.dtype_bytes
    g = TileGeom(wl.seq, d)
    act = Activity()
    H = wl.total_head_instances
    par = spec.n_clusters

    # per-tile array occupancy: QK^T (2d) + PV (2d) + spatial rowmax/rowsum
    # ripple passes (2d) time-multiplexed on ONE array
    compute_ii = 6.0 * d
    # operator hand-offs through SRAM: S out/in, P out/in, O partial r/w
    handoff_bytes = 6.0 * d * d * B
    stall = handoff_bytes / spec.sram_port_bytes_per_cycle
    # FuseMax-style iteration context switching: 10 live registers per PE
    # spilled/restored through the array edge (d elems/cycle)
    ctx = FUSEMAX_CTX_REGS * d * B / 4.0
    period = compute_ii + stall + ctx

    per_head_cycles = period * g.tiles + 5.0 * d
    wall = per_head_cycles * _heads_wall_factor(wl, par)

    act.macs = wl.qk_macs + wl.pv_macs + H * g.tiles * (d * d + d)
    act.exp_ops = wl.softmax_elems + H * g.tiles * d
    act.alu_ops = wl.softmax_elems * 3.0

    inject = g.tiles * 3.0 * d * d * B            # Q,K,V per tile
    interm = g.tiles * (4.0 * d * d * B           # S round-trip, P round-trip
                        + 2.0 * d * d * B         # exp stage reload
                        + 4.0 * d * d * B         # O partial read+write
                        + 8.0 * d * B)            # m,l stats round-trips
    ctx_bytes = g.tiles * FUSEMAX_CTX_REGS * d * B
    act.sram_bytes = H * (inject + interm + ctx_bytes + g.Tr * d * d * B) \
        + _qkv_dram_bytes(wl, B)
    act.dram_bytes = _qkv_dram_bytes(wl, B)
    act.reg_bytes = (act.macs * REG_BYTES_PER_MAC
                     + (act.exp_ops + act.alu_ops) * REG_BYTES_PER_VECOP
                     + H * g.tiles * FUSEMAX_CTX_REGS * d * d * B * 0.25)

    act.cycles = wall
    act.total_pe_cycles = wall * spec.n_tiers * spec.n_clusters
    act.busy_pe_cycles = (compute_ii * g.tiles * 0.92
                          * _heads_wall_factor(wl, par))
    return act


# ===========================================================================
# Dual-SA (COSA-class): QK^T on array A, PV on array B, dedicated softmax SFU
# between them; inter-array transfers over the 2D NoC ("drain-and-inject").
# ===========================================================================

def dualsa_attention(spec: AcceleratorSpec, wl: AttentionWorkload) -> Activity:
    d = spec.array_dim
    B = spec.dtype_bytes
    g = TileGeom(wl.seq, d)
    act = Activity()
    H = wl.total_head_instances
    par = spec.n_clusters          # each cluster = 2 arrays + SFU

    # stage latencies per tile
    qk = 2.0 * d
    # SFU throughput on d^2 exponentials + stats
    sfu = (d * d) / spec.sfu_exp_per_cycle
    pv = 2.0 * d
    # drain S from array A through the NoC into the SFU, then inject P into
    # array B: two transfers of d^2 elements over the 2D NoC
    drain_inject = 2.0 * (d * d * B / spec.noc_bytes_per_cycle
                          + spec.noc_hop_latency)
    # SFU exchanges its operands through SRAM (paper: "its dedicated Softmax
    # unit still relies on SRAM for data exchange")
    sfu_sram_stall = 4.0 * d * d * B / spec.sram_port_bytes_per_cycle
    period = max(qk, pv, sfu + drain_inject + sfu_sram_stall)

    per_head_cycles = period * g.tiles + (qk + sfu + pv + drain_inject)
    wall = per_head_cycles * _heads_wall_factor(wl, par)

    act.macs = wl.qk_macs + wl.pv_macs + H * g.tiles * (d * d + d)
    act.exp_ops = wl.softmax_elems + H * g.tiles * d
    act.alu_ops = wl.softmax_elems * 3.0

    act.noc_bytes = H * g.tiles * 2.0 * d * d * B
    inject = g.tiles * 3.0 * d * d * B
    interm = g.tiles * 8.0 * d * d * B            # SFU in/out via SRAM, both
    #                                               S and P staged + stats
    act.sram_bytes = H * (inject + interm + g.Tr * d * d * B) \
        + _qkv_dram_bytes(wl, B)
    act.dram_bytes = _qkv_dram_bytes(wl, B)
    act.reg_bytes = (act.macs * REG_BYTES_PER_MAC
                     + (act.exp_ops + act.alu_ops) * REG_BYTES_PER_VECOP)

    act.cycles = wall
    act.total_pe_cycles = wall * spec.n_tiers * spec.n_clusters
    act.busy_pe_cycles = ((qk + pv) * g.tiles * 0.92
                          * _heads_wall_factor(wl, par))
    return act


ATTENTION_MODELS = {
    "3D-Flow": flow3d_attention,
    "3D-Base": base3d_attention,
    "2D-Unfused": unfused2d_attention,
    "2D-Fused": fused2d_attention,
    "Dual-SA": dualsa_attention,
}


# ===========================================================================
# Conventional GEMM on the fabric (projections / FFN) - identical across
# designs (the paper's contribution targets the attention core; Table II /
# end-to-end numbers include these).
# ===========================================================================

def gemm_activity(spec: AcceleratorSpec, M: float, K: float, N: float,
                  weight_resident: bool = False) -> Activity:
    """Weight-stationary GEMM (M,K)x(K,N) on all arrays of the device."""
    d = spec.array_dim
    B = spec.dtype_bytes
    act = Activity()
    tiles = (math.ceil(M / d) * math.ceil(K / d) * math.ceil(N / d))
    n_arrays = spec.n_tiers * spec.n_clusters
    act.macs = M * K * N
    act.cycles = tiles * d / n_arrays + 2 * d
    act.sram_bytes = tiles * 2.0 * d * d * B + M * N * B
    w_bytes = K * N * B
    act.dram_bytes = (0.0 if weight_resident else w_bytes) + (M * K + M * N) * B * 0.0
    act.reg_bytes = act.macs * REG_BYTES_PER_MAC
    act.total_pe_cycles = act.cycles * n_arrays
    act.busy_pe_cycles = tiles * d * 0.92
    return act
