from .rules import (cache_spec, constrain, dp_axes, param_sharding_tree,
                    param_spec, tp_axis, tree_paths)

__all__ = ["cache_spec", "constrain", "dp_axes", "param_sharding_tree",
           "param_spec", "tp_axis", "tree_paths"]
