from .rules import (active_mesh, cache_spec, constrain, dp_axes,
                    mesh_axis_size, param_sharding_tree, param_spec, tp_axis,
                    tree_paths)

__all__ = ["active_mesh", "cache_spec", "constrain", "dp_axes",
           "mesh_axis_size", "param_sharding_tree", "param_spec", "tp_axis",
           "tree_paths"]
