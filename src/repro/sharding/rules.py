"""Logical-axis sharding rules: DP / FSDP / TP / EP / SP on the production mesh.

Mesh axes:
  pod    - pure data parallelism across pods (params replicated per pod)
  data   - data parallelism + FSDP weight sharding
  model  - tensor parallelism (heads / d_ff / vocab) + expert parallelism

Activations use logical names resolved against whatever mesh is active, so
model code works on the single-pod (data, model) mesh, the multi-pod
(pod, data, model) mesh, and unsharded CPU tests (no-op).
"""
from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


def _active_mesh():
    """The mesh currently in scope, or None.

    jax >= 0.5 exposes jax.sharding.get_abstract_mesh(); on older releases
    fall back to the physical mesh bound by `with mesh:` (thread_resources).
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        return getter()
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return m if m.axis_names else None
    except Exception:
        return None


def _mesh_axis_size(mesh, name: str) -> int:
    shape = mesh.shape
    if hasattr(shape, "get"):
        return shape.get(name, 1)
    return dict(zip(mesh.axis_names, shape.values())).get(name, 1)


def _axis_names() -> Tuple[str, ...]:
    m = _active_mesh()
    return tuple(m.axis_names) if m is not None and m.axis_names else ()


# public aliases (model code reuses the version-compat mesh lookup)
active_mesh = _active_mesh
mesh_axis_size = _mesh_axis_size


def dp_axes(names: Optional[Tuple[str, ...]] = None):
    names = _axis_names() if names is None else names
    ax = tuple(a for a in ("pod", "data") if a in names)
    return ax if ax else None


def tp_axis(names: Optional[Tuple[str, ...]] = None):
    names = _axis_names() if names is None else names
    return "model" if "model" in names else None


# --------------------------------------------------------------------------
# Activation constraints (logical names)
# --------------------------------------------------------------------------

_ACT_SPECS = {
    # (batch, seq, d_model) between blocks: batch over DP axes, SEQ over the
    # model axis (Megatron-style sequence parallelism) - the layer-boundary
    # residual stream and remat checkpoints are 1/|model| the size; XLA
    # all-gathers seq before attention/MLP and reduce-scatters after.
    "btd": lambda dp, tp: P(dp, tp, None),
    # (batch, seq, heads, head_dim): heads over TP
    "bshd": lambda dp, tp: P(dp, None, tp, None),
    # K/V for sequence-parallel attention: replicated over the model axis
    # (gathered ONCE per layer, outside the flash KV-block scan)
    "kv_rep": lambda dp, tp: P(dp, None, None, None),
    # token rows replicated over the model axis (MoE dispatch staging)
    "btd_rep": lambda dp, tp: P(dp, None, None),
    # (batch, seq, d_ff): hidden over TP
    "btf": lambda dp, tp: P(dp, None, tp),
    # (batch, seq, vocab): vocab over TP
    "btv": lambda dp, tp: P(dp, None, tp),
    # (batch, seq, topk, d) MoE combine: seq over TP like the residual stream
    "bskd": lambda dp, tp: P(dp, tp, None, None),
    # (batch, experts, capacity, d): batch over DP, experts over TP (EP).
    # Two alternatives were measured and REFUTED (EXPERIMENTS.md S.Perf):
    # replicating the expert activations (V9) or scatter-add combine (V8)
    # both make the SPMD partitioner move the full expert buffers in fp32.
    "becd": lambda dp, tp: P(dp, tp, None, None),
    "becf": lambda dp, tp: P(dp, tp, None, None),
}


def constrain(x: jax.Array, logical: str) -> jax.Array:
    """Apply a sharding constraint if a mesh is active; no-op otherwise.

    "bshd" is shape-aware: shard heads over the model axis when the head
    count divides it; otherwise fall back to sharding the sequence
    (context parallelism) so GQA group reshapes stay shard-local instead of
    forcing XLA to all-gather the whole tensor."""
    names = _axis_names()
    if not names:
        return x
    dp = dp_axes(names)
    tp = tp_axis(names)
    if logical == "bshd" and tp is not None:
        mesh = _active_mesh()
        tp_n = _mesh_axis_size(mesh, "model")
        # PREFER sequence sharding (context parallelism): projections and
        # the attention output then stay sequence-local, eliminating the
        # per-layer residual all-gather + partial-sum all-reduce entirely;
        # only K/V blocks are broadcast inside the flash scan.  Head
        # sharding is the fallback when the sequence does not divide.
        if x.shape[1] % tp_n == 0:
            spec = P(dp, tp, None, None)
        elif x.shape[2] % tp_n == 0:
            spec = P(dp, None, tp, None)
        else:
            spec = P(dp, None, None, None)
    else:
        spec = _ACT_SPECS[logical](dp, tp)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


# --------------------------------------------------------------------------
# Parameter sharding rules (path regex -> spec builder)
# --------------------------------------------------------------------------
# Param layouts (all may carry a leading stacked-layer axis, detected by
# ndim mismatch and padded with None):
#   embed        (V, D)        vocab over model, D over data (FSDP)
#   lm_head      (V, D)
#   wq/wk/wv     (D, N)        D over data, N (heads*hd) over model
#   wo           (N, D)
#   mlp in/gate  (D, F)
#   mlp out      (F, D)
#   router       (D, E)        replicated E
#   experts_in   (E, D, F)     experts over model (EP), D over data
#   experts_out  (E, F, D)
#   ssm in/out   (D, X) / (X, D)
#   norms, biases, small vectors: replicated

_PARAM_RULES = [
    (r"(embed|lm_head|cls_head)$", lambda dp, tp: P(tp, dp)),
    (r"(wq|wk|wv|w_in|w_gate|in_proj|router_dense|r_proj|k_proj|v_proj|g_proj|w_proj)$",
     lambda dp, tp: P(dp, tp)),
    (r"(wo|w_out|out_proj)$", lambda dp, tp: P(tp, dp)),
    (r"(experts_in|experts_gate)$", lambda dp, tp: P(tp, dp, None)),
    (r"(experts_out)$", lambda dp, tp: P(tp, None, dp)),
    (r"(router)$", lambda dp, tp: P(dp, None)),
    (r"(conv_w)$", lambda dp, tp: P(None, tp)),
    (r"(pos_embed)$", lambda dp, tp: P(None, dp)),
]


def param_spec(path: str, ndim: int, names: Tuple[str, ...]) -> P:
    """Resolve the PartitionSpec for a parameter by its tree path."""
    dp = dp_axes(names)
    tp = tp_axis(names)
    leaf = path.split("/")[-1]
    stacked = "blocks" in path or "layers" in path or "encoder" in path \
        or "decoder" in path
    for pat, rule in _PARAM_RULES:
        if re.search(pat, leaf):
            spec = rule(dp, tp)
            base = len(spec)
            if ndim > base:
                # leading stacked-layer axes -> replicated
                spec = P(*([None] * (ndim - base) + list(spec)))
            elif ndim < base:
                return P()        # degenerate (e.g. smoke configs)
            return spec
    return P()                    # norms / scalars / biases: replicated


def tree_paths(tree):
    """(path, leaf) pairs with '/'-joined key paths."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out.append(("/".join(parts), leaf))
    return out


def param_sharding_tree(params, mesh) -> "jax.tree_util.PyTreeDef":
    """NamedSharding pytree matching `params` for the given mesh."""
    names = tuple(mesh.axis_names)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    shardings = []
    for kp, leaf in flat:
        parts = []
        for k in kp:
            parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
        path = "/".join(parts)
        spec = param_spec(path, getattr(leaf, "ndim", 0), names)
        shardings.append(jax.sharding.NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, shardings)


def cache_spec(names: Tuple[str, ...], *, seq_sharded: bool,
               seq_axis: str = "data") -> P:
    """KV cache (L, B, S, H_kv, D).

    - default: batch over DP, KV heads over TP
    - seq_sharded + seq_axis="data": batch=1 long-context decode - shard the
      SEQUENCE over the data axis (sequence-parallel KV: the paper's tier
      split applied across chips)
    - seq_sharded + seq_axis="model": KV head count does not divide the
      model axis - shard the sequence there instead of replicating the
      cache across it."""
    dp = dp_axes(names)
    tp = tp_axis(names)
    if seq_sharded and seq_axis == "data":
        return P(None, None, "data" if "data" in names else None, tp, None)
    if seq_sharded:
        return P(None, dp, tp, None, None)
    return P(None, dp, None, tp, None)
