"""Training launcher.

Smoke scale runs anywhere:
    PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke

On a real TPU fleet, drop --smoke: the full config is built, the production
mesh is constructed from the actual devices, and state/batch shardings come
from the same rules the dry-run validates.
"""
import argparse

import jax

from ..compat import use_mesh
from ..configs import get_config, get_smoke_config
from ..configs.base import TrainConfig
from .mesh import make_debug_mesh, make_production_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args(argv)

    from ..train.trainer import Trainer

    if args.smoke:
        cfg = get_smoke_config(args.arch)
        tcfg = TrainConfig(global_batch=args.global_batch or 8,
                           seq_len=args.seq or 64, total_steps=args.steps,
                           warmup_steps=5, checkpoint_dir=args.ckpt_dir,
                           grad_compression="int8" if args.compress_grads
                           else "")
        tr = Trainer(cfg, tcfg)
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        tcfg = TrainConfig(global_batch=args.global_batch or 256,
                           seq_len=args.seq or 4096, total_steps=args.steps,
                           remat="full", checkpoint_dir=args.ckpt_dir,
                           grad_compression="int8" if args.compress_grads
                           else "")
        from ..launch.specs import train_cell
        from ..configs.base import ShapeSpec
        shape = ShapeSpec("train", tcfg.seq_len, tcfg.global_batch, "train")
        with use_mesh(mesh):
            _, _, shardings = train_cell(cfg, shape, mesh, tcfg)
            tr = Trainer(cfg, tcfg, mesh=mesh, state_shardings=shardings[0])
    out = tr.run()
    print(f"finished at step {out['final_step']}; "
          f"last loss {out['metrics'][-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
