import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # keep remat-saved scan stacks in bf16: WLICM otherwise hoists the
    # backward loop's per-step fp32 converts into a whole-stack fp32 copy
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion")
os.environ["REPRO_MIXED_DOTS"] = "1"  # compile-only: native mixed-precision dots

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell
against the production meshes, with ShapeDtypeStruct stand-ins (no device
allocation), and record memory / cost / collective analysis for the
roofline.

The two XLA_FLAGS lines above MUST precede every other import (jax locks
the device count at first init).

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out experiments/dryrun
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax

from ..compat import use_mesh
from ..configs import ASSIGNED_ARCHS, SHAPES, get_config
from ..configs.base import TrainConfig
from .hlo_cost import analyze as hlo_analyze
from .mesh import make_production_mesh
from .specs import prefill_cell, serve_cell, train_cell

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of one HLO shape string like 'bf16[16,128,4096]' (or a tuple)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in (per-partition) HLO."""
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # result-type = op-name(...)  -- match '= <collective>(' occurrences
        for op in COLLECTIVE_OPS:
            marker = f" {op}("
            alt = f" {op}-start("
            if marker in stripped or alt in stripped:
                lhs = stripped.split("=", 1)
                if len(lhs) != 2:
                    continue
                # result type precedes '=' after the '%name ' prefix:
                #   %x = bf16[2,4]{1,0} all-reduce(...)
                rhs = lhs[1].strip()
                type_part = rhs.split(op)[0]
                b = _shape_bytes(type_part)
                out[op]["count"] += 1
                out[op]["bytes"] += b
                break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             skip_execute: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single",
           "mesh_shape": list(mesh.devices.shape),
           "n_devices": int(mesh.devices.size)}

    t0 = time.time()
    with use_mesh(mesh):
        if shape.kind == "train":
            tcfg = TrainConfig(global_batch=shape.global_batch,
                               seq_len=shape.seq_len, remat="full")
            step, args, shardings = train_cell(cfg, shape, mesh, tcfg)
            fn = jax.jit(step, in_shardings=shardings)
        elif shape.kind == "prefill":
            step, args, shardings = prefill_cell(cfg, shape, mesh)
            fn = jax.jit(step, in_shardings=shardings)
        else:
            step, args, shardings = serve_cell(cfg, shape, mesh)
            fn = jax.jit(step, in_shardings=shardings)

        lowered = fn.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_size_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_size_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_size_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_size_bytes":
                int(getattr(mem, "generated_code_size_in_bytes", 0)),
        }
        rec["memory"]["total_per_device_bytes"] = (
            rec["memory"]["argument_size_bytes"]
            + rec["memory"]["output_size_bytes"]
            + rec["memory"]["temp_size_bytes"])

        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0] if cost else {}
        rec["cost"] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "transcendentals": float(cost.get("transcendentals", 0.0)),
        }
        hlo = compiled.as_text()
        rec["collectives"] = parse_collectives(hlo)
        rec["hlo_lines"] = hlo.count("\n")
        # corrected static analysis: while-loop (scan) bodies weighted by
        # their trip counts (XLA's cost_analysis counts them once)
        corr = hlo_analyze(hlo)
        rec["corrected"] = {
            "flops": corr["flops"],
            "bytes_proxy": corr["bytes"],
            "transcendentals": corr["transcendentals"],
            "collective_bytes": corr["collective_bytes"],
            "collectives": corr["collectives"],
            "while_trip_counts": corr["while_trip_counts"],
        }
    return rec


def all_cells():
    """Applicable (arch, shape) cells.  long_500k only for sub-quadratic
    archs (see DESIGN.md S.Arch-applicability)."""
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape_name in SHAPES:
            if shape_name == "long_500k" and not cfg.subquadratic:
                continue
            yield arch, shape_name


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = list(all_cells()) if args.all else [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch, shape_name in cells:
        for mp in meshes:
            tag = f"{arch}__{shape_name}__{'multi' if mp else 'single'}"
            out_path = outdir / f"{tag}.json"
            if out_path.exists():
                print(f"[skip] {tag} (exists)", flush=True)
                continue
            print(f"[run ] {tag}", flush=True)
            try:
                rec = run_cell(arch, shape_name, mp)
                out_path.write_text(json.dumps(rec, indent=1))
                print(f"[ ok ] {tag}: compile {rec['compile_s']}s, "
                      f"mem/dev {rec['memory']['total_per_device_bytes']/2**30:.2f} GiB, "
                      f"flops {rec['cost']['flops']:.3e}, "
                      f"coll {rec['collectives']['total_bytes']/2**20:.1f} MiB",
                      flush=True)
            except Exception as e:
                failures += 1
                err = {"arch": arch, "shape": shape_name,
                       "mesh": "multi" if mp else "single",
                       "error": repr(e),
                       "traceback": traceback.format_exc()}
                (outdir / f"{tag}.error.json").write_text(
                    json.dumps(err, indent=1))
                print(f"[FAIL] {tag}: {e!r}", flush=True)
    print(f"done; {failures} failures", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
