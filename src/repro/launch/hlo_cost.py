"""Static HLO cost analyzer with correct while-loop (scan) accounting.

XLA's compiled.cost_analysis() counts each while-body ONCE, which
undercounts scan-over-layers models by ~n_layers.  This analyzer parses the
per-partition HLO text, builds the computation call graph (fusion calls,
reduce to_apply, while body/condition), extracts each while loop's trip
count from its condition computation, and accumulates:

  * dot FLOPs            (2 x prod(result dims) x prod(contracting dims))
  * collective bytes     (all-gather / all-reduce / reduce-scatter /
                          all-to-all / collective-permute result bytes)
  * memory-traffic proxy (sum of materialized result-buffer bytes; post-
                          fusion HLO materializes each non-trivial result)

weighted by the execution multiplicity of the computation they live in.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional

DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
               "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
               "f64": 8}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_CALLEE_RE = re.compile(r"(?:calls|to_apply|body|condition)=%([\w.\-]+)")
_OP_RE = re.compile(r"^\(?[^=]*?\)?\s*([\w\-]+)\(")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "copy-done", "copy-start", "after-all",
                   "partition-id", "replica-id", "iota"}


def _shape_dims(type_str: str):
    """First shape in a type string -> (dtype, [dims]).  Tuples: all shapes."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",")] if dims else []
        out.append((dt, d))
    return out


def _nbytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    op: str
    rhs: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # name -> type str
    callees: List[str] = field(default_factory=list)
    while_edges: List[tuple] = field(default_factory=list)  # (body, cond)
    branch_groups: List[List[str]] = field(default_factory=list)
    fusion_internal: set = field(default_factory=set)


def parse_hlo(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    cur_fusion_internal = set()
    entry = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        header = (line.startswith(("%", "ENTRY")) and "{" in line
                  and "->" in line)
        if header:
            m = re.match(r"(?:ENTRY\s+)?%([\w.\-]+)", line.strip())
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None or not s or s == "}":
            if s == "}":
                cur = None
            continue
        dm = _DEF_RE.match(s)
        if not dm:
            continue
        name, rhs = dm.groups()
        # op name: first word after the type signature's closing
        opm = re.search(r"(?:\}|\]|\))\s*([\w\-]+)\(", rhs)
        if opm:
            op = opm.group(1)
        else:
            head = rhs.split("(")[0].split()
            op = head[-1] if head else "?"
        cur.instrs.append(Instr(name, op, rhs))
        cur.shapes[name] = rhs.split(op + "(")[0] if op + "(" in rhs else rhs
        if op == "while":
            bm = re.search(r"body=%([\w.\-]+)", rhs)
            cm = re.search(r"condition=%([\w.\-]+)", rhs)
            if bm and cm:
                cur.while_edges.append((bm.group(1), cm.group(1)))
        elif op == "conditional":
            # exclusive branches: charge the AVERAGE cost (branches of the
            # gemma3 local/global pattern have near-identical dot counts)
            branches = re.findall(
                r"(?:true_computation|false_computation)=%([\w.\-]+)", rhs)
            bg = re.search(r"branch_computations=\{([^}]*)\}", rhs)
            if bg:
                branches = re.findall(r"%([\w.\-]+)", bg.group(1))
            if branches:
                cur.branch_groups.append(branches)
        else:
            for cm in _CALLEE_RE.finditer(rhs):
                cur.callees.append(cm.group(1))
                if op in ("fusion", "reduce", "reduce-window", "scatter",
                          "sort", "map", "select-and-scatter", "all-reduce",
                          "reduce-scatter"):
                    cur_fusion_internal.add(cm.group(1))
    comps["__entry__"] = comps[entry]
    comps["__entry__"].fusion_internal = cur_fusion_internal
    return comps


def _trip_count(cond: Computation) -> int:
    """Largest s32 scalar constant in the condition computation ~ trip count."""
    best = 1
    for ins in cond.instrs:
        for m in _CONST_RE.finditer(ins.rhs):
            best = max(best, int(m.group(1)))
    return best


def _operand_names(rhs: str) -> List[str]:
    inner = rhs[rhs.index("("):] if "(" in rhs else rhs
    return re.findall(r"%([\w.\-]+)", inner)


def _dot_flops(ins: Instr, comp: Computation) -> float:
    res = _shape_dims(ins.rhs.split(ins.op + "(")[0])
    if not res:
        return 0.0
    _, rdims = res[0]
    n_res = 1
    for d in rdims:
        n_res *= d
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rhs)
    contract = 1
    ops = _operand_names(ins.rhs)
    if cm and ops:
        lhs_type = comp.shapes.get(ops[0], "")
        lhs_shapes = _shape_dims(lhs_type)
        if lhs_shapes:
            _, ldims = lhs_shapes[0]
            for idx in (int(x) for x in cm.group(1).split(",") if x):
                if idx < len(ldims):
                    contract *= ldims[idx]
    return 2.0 * n_res * contract


def analyze(hlo: str) -> dict:
    comps = parse_hlo(hlo)
    entry = comps["__entry__"]

    # per-computation local costs.  Instructions living inside fusion /
    # reducer bodies are not materialized; their bytes are excluded (the
    # fusion call's RESULT is counted at the call site).
    fusion_internal = getattr(comps["__entry__"], "fusion_internal", set())
    local = {}
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        flops = 0.0
        coll = {k: {"count": 0, "bytes": 0.0, "bytes_tpu": 0.0}
                for k in COLLECTIVE_OPS}
        bytes_out = 0.0
        transcend = 0.0
        count_bytes = name not in fusion_internal
        # TPU-equivalent collective accounting.  Two CPU-backend artifacts
        # inflate the raw numbers (see EXPERIMENTS.md S.Roofline):
        #  (1) CPU float-normalization promotes every bf16 collective to
        #      f32 (bf16 collectives are native on TPU)   -> halve f32.
        #  (2) the CPU pass pipeline lacks reduce-scatter-creator, so a
        #      TPU reduce-scatter appears as all-reduce + partition-id
        #      slice -> cost the sliced result, not the full buffer.
        ar_slice_factor: Dict[str, float] = {}
        for ins in comp.instrs:
            if "partition-id" not in ins.rhs and "dynamic-slice" not in ins.rhs:
                continue
            ts = ins.rhs.split(ins.op + "(")[0] if ins.op + "(" in ins.rhs \
                else ins.rhs
            out_b = _nbytes(ts)
            for o in _operand_names(ins.rhs):
                src = comp.shapes.get(o, "")
                if "all-reduce" in src or o.startswith("all-reduce"):
                    in_b = _nbytes(src)
                    if in_b > out_b > 0:
                        ar_slice_factor[o] = out_b / in_b
        for ins in comp.instrs:
            type_str = ins.rhs.split(ins.op + "(")[0] if ins.op + "(" in ins.rhs \
                else ins.rhs
            if ins.op == "dot":
                flops += _dot_flops(ins, comp)
            base_op = ins.op.replace("-start", "")
            if base_op in COLLECTIVE_OPS:
                b = _nbytes(type_str)
                b_tpu = b / 2 if type_str.strip().startswith("f32") else b
                b_tpu *= ar_slice_factor.get(ins.name, 1.0)
                coll[base_op]["count"] += 1
                coll[base_op]["bytes"] += b
                coll[base_op]["bytes_tpu"] += b_tpu
            if ins.op in ("exponential", "tanh", "log", "rsqrt", "power"):
                transcend += _nbytes(type_str) / 4.0
            if not count_bytes:
                continue
            if ins.op not in _SKIP_BYTES_OPS and not ins.op.endswith("-done"):
                if ins.op == "dynamic-update-slice":
                    # in-place: traffic = the written slice, not the buffer
                    ops_ = _operand_names(ins.rhs)
                    upd = comp.shapes.get(ops_[1], "") if len(ops_) > 1 else ""
                    bytes_out += 2.0 * _nbytes(upd)
                else:
                    bytes_out += _nbytes(type_str)
        local[name] = (flops, coll, bytes_out, transcend)

    # multiplicities via DFS from entry
    mult: Dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, depth=0):
        if depth > 64 or name not in comps:
            return
        mult[name] += m
        comp = comps[name]
        for body, cond in comp.while_edges:
            t = _trip_count(comps[cond]) if cond in comps else 1
            visit(body, m * t, depth + 1)
            visit(cond, m * (t + 1), depth + 1)
        for branches in comp.branch_groups:
            for b in branches:
                visit(b, m / max(len(branches), 1), depth + 1)
        for callee in comp.callees:
            visit(callee, m, depth + 1)

    visit(entry.name, 1.0)

    total = {"flops": 0.0, "bytes": 0.0, "transcendentals": 0.0,
             "collectives": {k: {"count": 0.0, "bytes": 0.0, "bytes_tpu": 0.0}
                             for k in COLLECTIVE_OPS},
             "while_trip_counts": []}
    for name, m in mult.items():
        if name not in local:
            continue
        fl, coll, by, tr = local[name]
        total["flops"] += m * fl
        total["bytes"] += m * by
        total["transcendentals"] += m * tr
        for k, v in coll.items():
            total["collectives"][k]["count"] += m * v["count"]
            total["collectives"][k]["bytes"] += m * v["bytes"]
            total["collectives"][k]["bytes_tpu"] += m * v["bytes_tpu"]
    for name, comp in comps.items():
        for body, cond in comp.while_edges:
            if cond in comps:
                total["while_trip_counts"].append(_trip_count(comps[cond]))
    total["collective_bytes"] = sum(
        v["bytes"] for v in total["collectives"].values())
    total["collective_bytes_tpu"] = sum(
        v["bytes_tpu"] for v in total["collectives"].values())
    return total
