"""Serving launcher (continuous batching).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke
"""
import argparse

import jax
import numpy as np

from ..configs import get_config, get_smoke_config
from ..configs.base import ServeConfig
from ..models import build_model
from ..serve.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params,
                      ServeConfig(max_batch=4, max_seq=128,
                                  max_new_tokens=16))
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        eng.submit(rng.integers(1, cfg.vocab_size, size=6).tolist())
    done = eng.run_until_done()
    print(f"served {len(done)} requests, "
          f"{sum(len(r.out_tokens) for r in done)} tokens")


if __name__ == "__main__":
    main()
