"""Serving launcher (continuous batching, dense or paged KV cache).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --paged \
        --page-size 16 --num-pages 64

--paged serves through the paged KV cache (serve/paged_cache.py): a global
page pool + block table instead of one dense (max_batch, max_seq) strip per
slot.  --num-pages 0 sizes the pool to dense-equivalent capacity; smaller
pools trade admission backpressure for KV memory.
"""
import argparse

import jax
import numpy as np

from ..configs import get_config, get_smoke_config
from ..configs.base import ServeConfig
from ..models import build_model
from ..serve.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--paged", action="store_true",
                    help="serve through the paged KV cache")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=0,
                    help="page pool size (0 = dense-equivalent)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    scfg = ServeConfig(max_batch=args.max_batch, max_seq=args.max_seq,
                       max_new_tokens=16, paged=args.paged,
                       page_size=args.page_size, num_pages=args.num_pages)
    eng = ServeEngine(model, params, scfg)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        eng.submit(rng.integers(1, cfg.vocab_size, size=6).tolist())
    done = eng.run_until_done()
    mode = f"paged (page={scfg.page_size}, pool={scfg.pool_pages()})" \
        if args.paged else "dense"
    print(f"served {len(done)} requests, "
          f"{sum(len(r.out_tokens) for r in done)} tokens "
          f"[{mode} KV cache, {eng.kv_cache_bytes() / 1e6:.2f} MB]")


if __name__ == "__main__":
    main()
