"""ShapeDtypeStruct input specs + shardings for every (arch x shape) cell.

No device allocation ever happens here - everything is abstract (eval_shape
+ NamedSharding), the pattern that makes the 512-device dry-run possible on
a single-host CPU container.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import SHAPES, ModelConfig, ShapeSpec, TrainConfig
from ..models import Model, build_model
from ..sharding.rules import cache_spec, dp_axes, param_sharding_tree, tp_axis


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def sanitize(shardings, shapes, mesh):
    """Drop PartitionSpec entries that do not evenly divide the dimension
    (batch=1 decode, odd vocab sizes, head counts < mesh axis, ...).
    pjit requires divisibility for explicit in_shardings."""
    def fix(sh, spec_shape):
        if not isinstance(sh, NamedSharding):
            return sh
        dims = spec_shape.shape
        spec = list(sh.spec) + [None] * (len(dims) - len(sh.spec))
        new = []
        for d, entry in zip(dims, spec):
            if entry is not None and d % _axis_size(mesh, entry) != 0:
                entry = None
            new.append(entry)
        return NamedSharding(mesh, P(*new))

    return jax.tree_util.tree_map(fix, shardings, shapes)


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Training / prefill batch ShapeDtypeStructs."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    batch = {}
    if cfg.family == "vlm":
        s_text = S - cfg.frontend_tokens
        batch["tokens"] = sds((B, s_text), jnp.int32)
        batch["vision_embeds"] = sds((B, cfg.frontend_tokens, cfg.d_model), dt)
    elif cfg.family == "audio":
        batch["tokens"] = sds((B, S), jnp.int32)
        batch["audio_embeds"] = sds((B, S, cfg.d_model), dt)
    else:
        batch["tokens"] = sds((B, S), jnp.int32)
    return batch


def batch_shardings(cfg: ModelConfig, mesh) -> Dict[str, Any]:
    names = tuple(mesh.axis_names)
    dp = dp_axes(names)
    out = {"tokens": NamedSharding(mesh, P(dp, None))}
    if cfg.family == "vlm":
        out["vision_embeds"] = NamedSharding(mesh, P(dp, None, None))
    if cfg.family == "audio":
        out["audio_embeds"] = NamedSharding(mesh, P(dp, None, None))
    return out


# ---------------------------------------------------------------------------
# decode specs (serve_step: one new token against a prefilled cache)
# ---------------------------------------------------------------------------

def decode_specs(model: Model, cfg: ModelConfig, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: model.init_cache(B, S, enc_len=S))
    tokens = sds((B, 1), jnp.int32)
    lens = sds((B,), jnp.int32)
    return cache, tokens, lens


def _cache_leaf_spec(path: str, leaf, names, *, seq_sharded: bool,
                     seq_axis: str = "data"):
    dp = dp_axes(names)
    tp = tp_axis(names)
    nd = len(leaf.shape)
    if path.endswith(("k", "v")) and nd == 5:
        # (L/A, B, S, Hkv, D) attention caches
        return cache_spec(names, seq_sharded=seq_sharded, seq_axis=seq_axis)
    if path.endswith("ssm") and nd == 5:      # (L, B, H, P, N)
        return P(None, dp, tp, None, None)
    if path.endswith("wkv") and nd == 5:      # (L, B, H, K, V)
        return P(None, dp, tp, None, None)
    if path.endswith("conv") and nd == 4:     # (L, B, k-1, d_in)
        return P(None, dp, None, tp)
    if nd == 3:                               # (L, B, D) rwkv shift states
        return P(None, dp, None)
    return P()


def cache_shardings(cache, mesh, *, seq_sharded: bool,
                    seq_axis: str = "data"):
    names = tuple(mesh.axis_names)
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        spec = _cache_leaf_spec(path, leaf, names, seq_sharded=seq_sharded,
                                seq_axis=seq_axis)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# full cell assembly
# ---------------------------------------------------------------------------

def train_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, tcfg: TrainConfig):
    """Returns (step_fn, arg_specs, in_shardings) for a train_step cell."""
    from ..train.train_step import (TrainState, init_train_state,
                                    make_train_step)
    model = build_model(cfg)
    step = make_train_step(model, tcfg)
    state_shape = jax.eval_shape(
        lambda: init_train_state(model, jax.random.PRNGKey(0), tcfg))
    pshard = param_sharding_tree(state_shape.params, mesh)
    mshard = param_sharding_tree(state_shape.opt.m, mesh)
    state_shard = TrainState(
        params=pshard,
        opt=type(state_shape.opt)(
            step=NamedSharding(mesh, P()), m=mshard,
            v=param_sharding_tree(state_shape.opt.v, mesh)),
        ef=param_sharding_tree(state_shape.ef, mesh)
        if tcfg.grad_compression else {})
    bspecs = batch_specs(cfg, shape)
    bshard = batch_shardings(cfg, mesh)
    args = (state_shape, bspecs)
    shardings = sanitize((state_shard, bshard), args, mesh)
    return step, args, shardings


def prefill_cell(cfg: ModelConfig, shape: ShapeSpec, mesh):
    from ..serve.serve_step import make_prefill_step
    model = build_model(cfg)
    step = make_prefill_step(model)
    params_shape = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))
    pshard = param_sharding_tree(params_shape, mesh)
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: model.init_cache(B, S, enc_len=S))
    p_seq_sharded, p_seq_axis = _kv_seq_sharded(cfg, shape, mesh)
    cshard = cache_shardings(cache, mesh, seq_sharded=p_seq_sharded,
                             seq_axis=p_seq_axis)
    bspecs = batch_specs(cfg, shape)
    bshard = batch_shardings(cfg, mesh)
    args = (params_shape, bspecs, cache)
    shardings = sanitize((pshard, bshard, cshard), args, mesh)
    return step, args, shardings


def _kv_seq_sharded(cfg: ModelConfig, shape: ShapeSpec, mesh) -> bool:
    """Shard the KV-cache SEQUENCE dimension when either (a) the batch is too
    small for the data axis (batch-1 long-context decode) or (b) the KV head
    count does not divide the model axis - otherwise the cache would be
    REPLICATED across the model axis (e.g. llava decode: 68 GiB/device)."""
    data_size = mesh.shape.get("data", 1)
    model_size = mesh.shape.get("model", 1)
    small_batch = shape.global_batch < data_size
    kv_indivisible = cfg.n_kv_heads % model_size != 0
    if small_batch:
        return True, "data"
    if kv_indivisible:
        return True, "model"
    return False, "data"


def serve_cell(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """Decode cells: serve_step(params, cache, tokens, lens)."""
    from ..serve.serve_step import make_serve_step
    model = build_model(cfg)
    names = tuple(mesh.axis_names)
    data_size = mesh.shape.get("data", 1)
    seq_sharded, seq_axis = _kv_seq_sharded(cfg, shape, mesh)
    step = make_serve_step(model, seq_parallel=seq_sharded)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pshard = param_sharding_tree(params_shape, mesh)
    cache, tokens, lens = decode_specs(model, cfg, shape)
    cshard = cache_shardings(cache, mesh, seq_sharded=seq_sharded,
                             seq_axis=seq_axis)
    dp = dp_axes(names) if shape.global_batch >= data_size else None
    tshard = NamedSharding(mesh, P(dp, None))
    lshard = NamedSharding(mesh, P(dp))
    args = (params_shape, cache, tokens, lens)
    shardings = sanitize((pshard, cshard, tshard, lshard), args, mesh)
    return step, args, shardings
