"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run (launch/dryrun.py) sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import
to obtain placeholder devices for the production meshes.
"""
from __future__ import annotations

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (data, model); 2x16x16 = 512 chips across
    two pods (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many devices the host actually has."""
    return make_mesh((n_data, n_model), ("data", "model"))
