"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run (launch/dryrun.py) sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import
to obtain placeholder devices for the production meshes.
"""
from __future__ import annotations

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (data, model); 2x16x16 = 512 chips across
    two pods (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many devices the host actually has."""
    return make_mesh((n_data, n_model), ("data", "model"))


def make_serve_mesh(tp_degree: int):
    """(1, tp_degree) serving mesh over the FIRST tp_degree devices.

    Unlike make_mesh (which spans every device), a serve mesh may be a
    strict subset of the host's devices - a TP replica under the fleet
    router owns tp_degree devices while other replicas own the rest, and
    on CPU CI the forced device count (4) exceeds the tp=2 test meshes.
    Axis names match sharding/rules.py: serving shards only "model" (the
    head axis); "data" stays 1 per replica (the router is the data axis).
    """
    import jax
    import numpy as np

    devs = jax.devices()
    if tp_degree < 1:
        raise ValueError(f"tp_degree must be >= 1, got {tp_degree}")
    if len(devs) < tp_degree:
        raise ValueError(
            f"tp_degree={tp_degree} needs at least that many devices, have "
            f"{len(devs)} (on CPU, set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={tp_degree} "
            f"before jax imports)")
    arr = np.array(devs[:tp_degree]).reshape(1, tp_degree)
    return jax.sharding.Mesh(arr, ("data", "model"))
