"""AdamW with global-norm clipping, built from scratch (no optax).

Optimizer state is a pytree mirroring params (fp32 m, v), so the launch
layer shards it with the same rules as the parameters (FSDP over "data").
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(grads, state: AdamWState, params, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    fstep = step.astype(jnp.float32)
    bc1 = 1.0 - jnp.power(b1, fstep)
    bc2 = 1.0 - jnp.power(b2, fstep)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1.0 - b1) * gf
        v_new = b2 * v + (1.0 - b2) * gf * gf
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    fstep = step.astype(jnp.float32)
    warm = fstep / jnp.maximum(warmup, 1)
    prog = jnp.clip((fstep - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(fstep < warmup, warm, cos)
