from .adamw import (AdamWState, adamw_init, adamw_update, clip_by_global_norm,
                    cosine_schedule, global_norm)
from .compression import compress_grads, compressed_bytes, ef_init

__all__ = ["AdamWState", "adamw_init", "adamw_update", "clip_by_global_norm",
           "cosine_schedule", "global_norm", "compress_grads",
           "compressed_bytes", "ef_init"]
