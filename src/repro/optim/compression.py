"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-tensor quantization before the gradient all-reduce (4x bandwidth
vs fp32, 2x vs bf16), with an error-feedback residual buffer so the
quantization error is re-injected next step and training stays unbiased to
first order.  The quantize->dequantize pair here is value-faithful to the
wire format; on a real fleet the all-reduce itself runs on the int8 payload.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def ef_init(params) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads, ef_buf):
    """Returns (dequantized grads as seen after the int8 all-reduce,
    new error-feedback buffer)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quantize_int8(gf)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef_buf)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return new_g, new_e


def compressed_bytes(params) -> int:
    """Wire bytes per all-reduce with int8 payload (vs 4x for fp32)."""
    return sum(int(p.size) + 4 for p in jax.tree_util.tree_leaves(params))
