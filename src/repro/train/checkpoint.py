"""Sharding-aware checkpointing with atomic writes and elastic restore.

- save: flatten the state pytree to path-keyed arrays, write .npz to a tmp
  file, fsync, atomic-rename, and record a manifest (step, digest, paths) -
  a torn/partial checkpoint can never be mistaken for a valid one.
- restore: rebuild the pytree and device_put each leaf with the shardings of
  the *current* mesh - restoring a checkpoint onto a different mesh shape
  (elastic scale-up/down) is just a different sharding tree.
- retention: keep the last K valid checkpoints.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import time
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np
from ml_dtypes import bfloat16 as _bf16

_STEP_RE = re.compile(r"step_(\d+)$")


def _encode(arr: np.ndarray):
    """npz cannot round-trip bfloat16; store as uint16 view + dtype tag."""
    if arr.dtype == _bf16:
        return arr.view(np.uint16), "bfloat16"
    return arr, str(arr.dtype)


def _decode(arr: np.ndarray, dtype_tag: str):
    if dtype_tag == "bfloat16":
        return arr.view(_bf16)
    return arr


def _flatten(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        out[path] = np.asarray(leaf)
    return out


def _digest(arrays: dict) -> str:
    h = hashlib.sha256()
    for k in sorted(arrays):
        h.update(k.encode())
        h.update(np.ascontiguousarray(arrays[k]).tobytes()[:1 << 20])
    return h.hexdigest()[:16]


def save_checkpoint(directory: str, step: int, state: Any,
                    keep: int = 3) -> str:
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    ck = d / f"step_{step}"
    tmp = d / f".tmp_step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    arrays = _flatten(state)
    encoded, dtypes = {}, {}
    for k, v in arrays.items():
        enc, tag = _encode(v)
        encoded[k.replace("/", "|")] = enc
        dtypes[k] = tag
    npz_tmp = tmp / "arrays.npz"
    with open(npz_tmp, "wb") as f:
        np.savez(f, **encoded)
        f.flush()
        os.fsync(f.fileno())
    manifest = {"step": step, "time": time.time(),
                "digest": _digest(arrays),
                "dtypes": dtypes,
                "n_arrays": len(arrays)}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if ck.exists():
        shutil.rmtree(ck)
    os.rename(tmp, ck)                      # atomic publish

    # retention
    steps = sorted(all_checkpoints(directory))
    for s in steps[:-keep]:
        shutil.rmtree(d / f"step_{s}", ignore_errors=True)
    return str(ck)


def all_checkpoints(directory: str):
    d = Path(directory)
    if not d.exists():
        return []
    out = []
    for p in d.iterdir():
        m = _STEP_RE.search(p.name)
        if m and (p / "manifest.json").exists():
            out.append(int(m.group(1)))
    return sorted(out)


def latest_checkpoint(directory: str) -> Optional[int]:
    steps = all_checkpoints(directory)
    return steps[-1] if steps else None


def _validate(ck: Path) -> bool:
    try:
        manifest = json.loads((ck / "manifest.json").read_text())
        with np.load(ck / "arrays.npz") as z:
            return len(z.files) == manifest["n_arrays"]
    except Exception:
        return False


def restore_checkpoint(directory: str, step: int, state_template: Any,
                       mesh=None, sharding_tree: Any = None) -> Tuple[Any, int]:
    """Restore `step` into the structure of `state_template`, placing leaves
    with `sharding_tree` (elastic: works for any current mesh)."""
    ck = Path(directory) / f"step_{step}"
    if not _validate(ck):
        raise IOError(f"checkpoint {ck} failed validation")
    manifest = json.loads((ck / "manifest.json").read_text())
    dtypes = manifest.get("dtypes", {})
    with np.load(ck / "arrays.npz") as z:
        arrays = {k.replace("|", "/"):
                  _decode(z[k], dtypes.get(k.replace("|", "/"), str(z[k].dtype)))
                  for k in z.files}

    flat, treedef = jax.tree_util.tree_flatten_with_path(state_template)
    shard_flat = None
    if sharding_tree is not None:
        shard_flat = treedef.flatten_up_to(sharding_tree)
    leaves = []
    for i, (kp, leaf) in enumerate(flat):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        arr = arrays[path]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def restore_latest(directory: str, state_template: Any, mesh=None,
                   sharding_tree: Any = None) -> Optional[Tuple[Any, int]]:
    """Restore the newest VALID checkpoint, skipping corrupt ones."""
    for step in reversed(all_checkpoints(directory)):
        try:
            return restore_checkpoint(directory, step, state_template,
                                      mesh=mesh, sharding_tree=sharding_tree)
        except Exception:
            continue
    return None
