from .train_step import TrainState, init_train_state, make_train_step

__all__ = ["TrainState", "init_train_state", "make_train_step"]
from .checkpoint import (all_checkpoints, latest_checkpoint,
                         restore_checkpoint, restore_latest, save_checkpoint)
from .trainer import Trainer
