"""Fault-tolerant trainer: checkpoint/restart, straggler watchdog, metrics.

Restart semantics: on construction the trainer restores the newest valid
checkpoint (if any) and the data pipeline resumes from the same step index
deterministically.  A preemption/failure can therefore kill the process at
any point and `Trainer(...).run()` continues where it left off - this is
exercised by tests/test_checkpoint.py with a simulated mid-run crash.

Straggler mitigation (single-host analogue): a step-time watchdog tracks a
robust moving estimate; steps slower than `straggler_factor` x median are
counted and logged, and non-essential host work (metrics serialization) is
skipped while lagging, keeping the input pipeline ahead of the device.
"""
from __future__ import annotations

import json
import statistics
import time
from pathlib import Path
from typing import Callable, Dict, Optional

import jax

from ..configs.base import ModelConfig, TrainConfig
from ..data.pipeline import DataPipeline
from ..models import build_model
from .checkpoint import latest_checkpoint, restore_latest, save_checkpoint
from .train_step import TrainState, init_train_state, make_train_step


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, mesh=None,
                 state_shardings=None, fail_at_step: Optional[int] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.model = build_model(cfg)
        self.pipeline = DataPipeline(cfg, tcfg, mesh=mesh)
        self.fail_at_step = fail_at_step          # test hook: simulated crash
        self.metrics_log = []
        self._step_times = []
        self.straggler_factor = 3.0
        self.straggler_events = 0

        step_fn = make_train_step(self.model, tcfg)
        if mesh is not None and state_shardings is not None:
            self.train_step = jax.jit(step_fn, in_shardings=(state_shardings,
                                                             None),
                                      donate_argnums=(0,))
        else:
            self.train_step = jax.jit(step_fn, donate_argnums=(0,))
        self.state_shardings = state_shardings

        # ---- restore-or-init -------------------------------------------
        template = jax.eval_shape(
            lambda: init_train_state(self.model, jax.random.PRNGKey(tcfg.seed),
                                     tcfg))
        restored = restore_latest(tcfg.checkpoint_dir, template,
                                  mesh=mesh, sharding_tree=state_shardings)
        if restored is not None:
            self.state, self.start_step = restored
            self.start_step += 1
        else:
            self.state = init_train_state(
                self.model, jax.random.PRNGKey(tcfg.seed), tcfg)
            self.start_step = 0

    # --------------------------------------------------------------------
    def run(self, n_steps: Optional[int] = None) -> Dict:
        tcfg = self.tcfg
        end = min(self.start_step + (n_steps or tcfg.total_steps),
                  tcfg.total_steps)
        step = self.start_step
        while step < end:
            if self.fail_at_step is not None and step == self.fail_at_step:
                raise RuntimeError(f"simulated failure at step {step}")
            t0 = time.time()
            batch = self.pipeline.device_batch(step)
            self.state, metrics = self.train_step(self.state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            self._watchdog(step, dt)
            if step % tcfg.log_every == 0 or step == end - 1:
                metrics.update(step=step, step_time_s=round(dt, 4))
                self.metrics_log.append(metrics)
            if tcfg.checkpoint_every and (step + 1) % tcfg.checkpoint_every == 0:
                save_checkpoint(tcfg.checkpoint_dir, step, self.state,
                                keep=tcfg.keep_checkpoints)
            step += 1
        save_checkpoint(tcfg.checkpoint_dir, step - 1, self.state,
                        keep=tcfg.keep_checkpoints)
        return {"final_step": step - 1,
                "metrics": self.metrics_log,
                "straggler_events": self.straggler_events}

    # --------------------------------------------------------------------
    def _watchdog(self, step: int, dt: float):
        self._step_times.append(dt)
        if len(self._step_times) >= 8:
            med = statistics.median(self._step_times[-32:])
            if dt > self.straggler_factor * med:
                self.straggler_events += 1
        if len(self._step_times) > 256:
            self._step_times = self._step_times[-64:]
