"""Training step: loss -> grad -> clip -> (compress) -> AdamW update.

Pure function of (params, opt_state, batch, step) so the launch layer can
jit it with explicit in/out shardings; gradients are averaged across the
data axes implicitly by XLA's SPMD all-reduce (overlapped with the backward
pass by the scheduler), optionally on an int8 payload with error feedback.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, TrainConfig
from ..models import Model
from ..optim import (AdamWState, adamw_init, adamw_update,
                     clip_by_global_norm, compress_grads, cosine_schedule,
                     ef_init)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    ef: Any              # error-feedback buffers ({} when compression off)


def init_train_state(model: Model, key, tcfg: TrainConfig) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=adamw_init(params),
                      ef=ef_init(params) if tcfg.grad_compression else {})


def make_train_step(model: Model, tcfg: TrainConfig):
    remat = tcfg.remat != "none"

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        def loss_fn(p):
            return model.loss(p, batch, remat=remat)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        ef = state.ef
        if tcfg.grad_compression == "int8":
            grads, ef = compress_grads(grads, ef)
        lr = cosine_schedule(state.opt.step, base_lr=tcfg.learning_rate,
                             warmup=tcfg.warmup_steps, total=tcfg.total_steps)
        new_params, new_opt = adamw_update(
            grads, state.opt, state.params, lr=lr, b1=tcfg.b1, b2=tcfg.b2,
            weight_decay=tcfg.weight_decay)
        out = {"loss": loss, "ce": metrics["ce"], "aux": metrics["aux"],
               "grad_norm": gnorm, "lr": lr}
        return TrainState(new_params, new_opt, ef), out

    return train_step
