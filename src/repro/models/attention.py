"""Attention module: MHA / GQA, RoPE, sliding-window, QK-norm, cross-attn,
KV-cache decode (incl. sequence-parallel long-context decode)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from ..sharding import constrain
from .layers import dense, dense_init, pdtype, rms_head_norm, rope


def attn_init(key, cfg: ModelConfig):
    dt = pdtype(cfg)
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.n_heads * hd, cfg.n_kv_heads * hd
    ks = jax.random.split(key, 6)
    p = {"wq": dense_init(ks[0], d, nq, dt),
         "wk": dense_init(ks[1], d, nkv, dt),
         "wv": dense_init(ks[2], d, nkv, dt),
         "wo": dense_init(ks[3], nq, d, dt, scale=1.0 / math.sqrt(nq))}
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _qkv(params, x, cfg: ModelConfig, kv_x=None):
    B = x.shape[0]
    kv_src = x if kv_x is None else kv_x
    q = dense(params["wq"], x).reshape(B, x.shape[1], cfg.n_heads, cfg.head_dim)
    k = dense(params["wk"], kv_src).reshape(
        B, kv_src.shape[1], cfg.n_kv_heads, cfg.head_dim)
    v = dense(params["wv"], kv_src).reshape(
        B, kv_src.shape[1], cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_head_norm(q, params["q_norm"])
        k = rms_head_norm(k, params["k_norm"])
    return q, k, v


def attn_forward(params, x, cfg: ModelConfig, *, causal: bool = True,
                 window: int = 0, positions: Optional[jax.Array] = None,
                 kv_x: Optional[jax.Array] = None,
                 impl: Optional[str] = None) -> jax.Array:
    """Full-sequence attention (training / prefill).  x: (B, S, D)."""
    q, k, v = _qkv(params, x, cfg, kv_x)
    if cfg.use_rope and kv_x is None:
        pos = positions if positions is not None \
            else jnp.arange(x.shape[1])
        q = rope(q, pos, cfg.rope_theta, cfg.rope_scaling)
        k = rope(k, pos, cfg.rope_theta, cfg.rope_scaling)
    q = constrain(q, "bshd")
    # gather K/V across the sequence shards once, before the block scan
    k = constrain(k, "kv_rep")
    v = constrain(v, "kv_rep")
    o = ops.flash_attention(q, k, v, causal=causal, window=window,
                            logit_softcap=cfg.attn_logit_softcap, impl=impl)
    o = constrain(o, "bshd")
    B, S = x.shape[:2]
    return dense(params["wo"], o.reshape(B, S, cfg.n_heads * cfg.head_dim))


def attn_prefill(params, x, cfg: ModelConfig, cache_k, cache_v, *,
                 window: int = 0, impl: Optional[str] = None):
    """Prefill: run full attention AND fill the cache prefix.

    cache_k/v: (B, S_max, Hkv, D).  Assumes prefill starts at position 0.
    Returns (y, cache_k, cache_v)."""
    q, k, v = _qkv(params, x, cfg)
    S = x.shape[1]
    if cfg.use_rope:
        pos = jnp.arange(S)
        q = rope(q, pos, cfg.rope_theta, cfg.rope_scaling)
        k = rope(k, pos, cfg.rope_theta, cfg.rope_scaling)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, 0, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, 0, 0, 0))
    o = ops.flash_attention(q, k, v, causal=True, window=window,
                            logit_softcap=cfg.attn_logit_softcap, impl=impl)
    B = x.shape[0]
    y = dense(params["wo"], o.reshape(B, S, cfg.n_heads * cfg.head_dim))
    return y, cache_k, cache_v


def attn_prefill_paged(params, x, cfg: ModelConfig, k_pages, v_pages,
                       page_ids, *, window: int = 0,
                       impl: Optional[str] = None):
    """Prefill one sequence's prompt into its KV pages.

    x: (1, S, D) with S a multiple of the page size (pad the prompt
    upstream; trailing pad K/V is masked by `lens` at decode time and gets
    overwritten as decode advances).  k/v_pages: (P, page_size, Hkv, D)
    global pool; page_ids: (S // page_size,) pages owned by this sequence,
    position-major.  Returns (y, k_pages, v_pages)."""
    q, k, v = _qkv(params, x, cfg)
    S = x.shape[1]
    page_size = k_pages.shape[1]
    n = S // page_size
    if cfg.use_rope:
        pos = jnp.arange(S)
        q = rope(q, pos, cfg.rope_theta, cfg.rope_scaling)
        k = rope(k, pos, cfg.rope_theta, cfg.rope_scaling)
    kp = k[0].reshape(n, page_size, cfg.n_kv_heads, cfg.head_dim)
    vp = v[0].reshape(n, page_size, cfg.n_kv_heads, cfg.head_dim)
    k_pages = k_pages.at[page_ids].set(kp.astype(k_pages.dtype))
    v_pages = v_pages.at[page_ids].set(vp.astype(v_pages.dtype))
    o = ops.flash_attention(q, k, v, causal=True, window=window,
                            logit_softcap=cfg.attn_logit_softcap, impl=impl)
    y = dense(params["wo"], o.reshape(1, S, cfg.n_heads * cfg.head_dim))
    return y, k_pages, v_pages


def _tp_pool_constrain(pages, tp_mesh):
    """Pin a KV page pool to its head-sharded layout on the serve mesh.

    The engine commits the pools head-sharded at init; this re-asserts the
    layout on the scatter output inside jit (per-layer pool slices inside
    the layer scan carry no committed sharding of their own), so the
    scatter stays a local per-shard write instead of a resharding round
    trip.  The scattered K/V values are computed from replicated
    activations, so the write is pure data movement - sharding it cannot
    change any attention result."""
    if tp_mesh is None:
        return pages
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        pages, NamedSharding(tp_mesh, P(None, None, "model", None)))


def attn_decode_paged(params, x, cfg: ModelConfig, k_pages, v_pages,
                      block_table, lens, *, window: int = 0,
                      impl: Optional[str] = None, tp_mesh=None):
    """Single-token decode through the block table.

    x: (B, 1, D); k/v_pages: (P, page_size, Hkv, D) global pool;
    block_table: (B, n_max) page ids; lens: (B,) current lengths (the new
    token's K/V is scattered into page lens // page_size at offset
    lens % page_size).  Idle slots (lens == 0, block-table row zeroed) write
    into the reserved null page 0, never into live pages.
    tp_mesh: head-shard the pools and the decode kernel across the serve
    mesh's "model" axis (kernels/ops.py paged_flash_decode); the attention
    output gathers back to replicated so wo and everything after run with
    tp=1 numerics.  Returns (y, k_pages, v_pages)."""
    B = x.shape[0]
    q, k, v = _qkv(params, x, cfg)
    if cfg.use_rope:
        q = rope(q, lens[:, None], cfg.rope_theta, cfg.rope_scaling)
        k = rope(k, lens[:, None], cfg.rope_theta, cfg.rope_scaling)
    page_size = k_pages.shape[1]
    bidx = jnp.arange(B)
    page = block_table[bidx, lens // page_size]
    off = lens % page_size
    k_pages = k_pages.at[page, off].set(k[:, 0].astype(k_pages.dtype))
    v_pages = v_pages.at[page, off].set(v[:, 0].astype(v_pages.dtype))
    k_pages = _tp_pool_constrain(k_pages, tp_mesh)
    v_pages = _tp_pool_constrain(v_pages, tp_mesh)
    o = ops.paged_flash_decode(q, k_pages, v_pages, block_table, lens + 1,
                               window=window,
                               logit_softcap=cfg.attn_logit_softcap,
                               impl=impl, tp_mesh=tp_mesh)
    y = dense(params["wo"], o.reshape(B, 1, cfg.n_heads * cfg.head_dim))
    return y, k_pages, v_pages


def attn_prefill_chunks_paged(params, x, cfg: ModelConfig, k_pages, v_pages,
                              page_tables, offsets, true_lens, *,
                              q_lens=None, window: int = 0,
                              impl: Optional[str] = None, tp_mesh=None):
    """Prefill a RAGGED BATCH of mid-prompt chunks - K chunks of K
    different sequences, each at its own prompt position - into their
    pages, in one pass.

    x: (K, S, D); row k holds a contiguous run of prompt tokens at
    absolute positions offsets[k] + arange(S), zero-padded past its real
    length (true_lens[k] - offsets[k]).  Pages already holding K/V for
    positions < offsets[k] (cached prefix + earlier chunks) sit at the
    front of row k's block-table row page_tables[k].  Each row's chunk
    K/V is scattered token-by-token through its table row - a chunk need
    not start on a page boundary - with PAD positions redirected to the
    null page 0, so two chunks of the SAME sequence packed into one batch
    never collide (row A's pad tail would otherwise race row B's real
    writes at the same positions).  Then all rows' queries attend over
    every earlier position AND their own chunk via the offset-causal
    batched kernel (kernels/paged_prefill.py), so packing the
    scheduler's whole per-tick chunk plan into ONE launch is exact.
    Dead padding rows (true_len == 0, all-null table row) write only to
    the null page and return garbage rows the caller discards.
    Returns (y, k_pages, v_pages)."""
    q, k, v = _qkv(params, x, cfg)
    K, S = x.shape[:2]
    page_size = k_pages.shape[1]
    n_max = page_tables.shape[1]
    pos = jnp.asarray(offsets, jnp.int32)[:, None] + jnp.arange(S)[None, :]
    if cfg.use_rope:
        q = rope(q, pos, cfg.rope_theta, cfg.rope_scaling)
        k = rope(k, pos, cfg.rope_theta, cfg.rope_scaling)
    valid = pos < jnp.asarray(true_lens, jnp.int32)[:, None]    # (K, S)
    pidx = jnp.minimum(pos // page_size, n_max - 1)
    pages = jnp.where(valid, jnp.take_along_axis(page_tables, pidx, axis=1),
                      0)
    offs = jnp.where(valid, pos % page_size, 0)
    k_pages = k_pages.at[pages, offs].set(k.astype(k_pages.dtype))
    v_pages = v_pages.at[pages, offs].set(v.astype(v_pages.dtype))
    k_pages = _tp_pool_constrain(k_pages, tp_mesh)
    v_pages = _tp_pool_constrain(v_pages, tp_mesh)
    o = ops.batched_paged_prefill_attention(
        q, k_pages, v_pages, page_tables, offsets, true_lens, q_lens,
        window=window, logit_softcap=cfg.attn_logit_softcap, impl=impl,
        tp_mesh=tp_mesh)
    y = dense(params["wo"], o.reshape(K, S, cfg.n_heads * cfg.head_dim))
    return y, k_pages, v_pages


def attn_prefill_chunk_paged(params, x, cfg: ModelConfig, k_pages, v_pages,
                             page_row, offset, *, window: int = 0,
                             impl: Optional[str] = None):
    """Prefill one MID-PROMPT chunk of one sequence's prompt into its
    pages: the K=1 special case of attn_prefill_chunks_paged.

    x: (1, S, D) holds a contiguous run of prompt tokens at absolute
    positions offset + arange(S) - the uncached suffix after a
    prefix-cache hit (serve/prefix_cache.py), or a single budget chunk.
    Every position of x is treated as real (true_len = offset + S): the
    historical single-row contract, where trailing pad K/V lands in the
    sequence's own reserved pages and is masked by `lens` at decode time.
    Returns (y, k_pages, v_pages)."""
    off = jnp.asarray(offset, jnp.int32).reshape(1)
    return attn_prefill_chunks_paged(
        params, x, cfg, k_pages, v_pages,
        jnp.asarray(page_row, jnp.int32)[None], off, off + x.shape[1],
        window=window, impl=impl)


# the prefix-cache suffix is the final-chunk special case
attn_prefill_suffix_paged = attn_prefill_chunk_paged


def attn_decode(params, x, cfg: ModelConfig, cache_k, cache_v, lens, *,
                window: int = 0, impl: Optional[str] = None,
                seq_parallel: bool = False, cross: bool = False):
    """Single-token decode.  x: (B, 1, D); cache: (B, S_max, Hkv, D);
    lens: (B,) current lengths (the new token is written at lens).

    cross=True: cross-attention - cache holds precomputed encoder K/V of
    length `lens`; no cache update, no RoPE.
    Returns (y, cache_k, cache_v)."""
    B = x.shape[0]
    q, k, v = _qkv(params, x, cfg)
    if not cross:
        if cfg.use_rope:
            q = rope(q, lens[:, None], cfg.rope_theta, cfg.rope_scaling)
            k = rope(k, lens[:, None], cfg.rope_theta, cfg.rope_scaling)
        # scatter the new K/V at position `lens` per sequence
        bidx = jnp.arange(B)
        cache_k = cache_k.at[bidx, lens].set(k[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[bidx, lens].set(v[:, 0].astype(cache_v.dtype))
        attend_len = lens + 1
    else:
        attend_len = lens

    if seq_parallel:
        # naive form: XLA SPMD partitions the softmax reductions over the
        # seq-sharded cache (partial-softmax merge across chips)
        o = ops.decode_attention_naive(q, cache_k, cache_v, attend_len,
                                       logit_softcap=cfg.attn_logit_softcap)
    else:
        o = ops.flash_decode(q, cache_k, cache_v, attend_len, window=window,
                             logit_softcap=cfg.attn_logit_softcap,
                             impl=impl)
    y = dense(params["wo"], o.reshape(B, 1, cfg.n_heads * cfg.head_dim))
    return y, cache_k, cache_v
