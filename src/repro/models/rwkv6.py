"""RWKV6 (Finch) block: time-mix with data-dependent decay + channel-mix.

Attention-free: the paper's attention-fusion schedule is inapplicable (see
DESIGN.md S.Arch-applicability); the WKV recurrence kernel applies the same
fusion principle instead (state stays VMEM-resident across the chunk).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from ..sharding import constrain
from .layers import dense, dense_init, pdtype


def rwkv6_init(key, cfg: ModelConfig):
    dt = pdtype(cfg)
    d = cfg.d_model
    H = cfg.n_heads
    K = d // H
    ks = jax.random.split(key, 10)
    lora = max(32, d // 32)
    p = {
        # time-mix
        "r_proj": dense_init(ks[0], d, d, dt),
        "k_proj": dense_init(ks[1], d, d, dt),
        "v_proj": dense_init(ks[2], d, d, dt),
        "g_proj": dense_init(ks[3], d, d, dt),
        "out_proj": dense_init(ks[4], d, d, dt, scale=1.0 / math.sqrt(d)),
        # data-dependent decay: w = exp(-exp(w_base + tanh(x @ w_a) @ w_b))
        "w_base": jnp.full((d,), -1.0, jnp.float32),
        "w_a": dense_init(ks[5], d, lora, dt),
        "w_b": dense_init(ks[6], lora, d, dt, scale=0.01),
        "u": (jax.random.normal(ks[7], (H, K), jnp.float32) * 0.1),
        # token-shift interpolation weights per stream
        "mix": (jnp.ones((5, d), jnp.float32) * 0.5).astype(dt),
        "ln_x": jnp.ones((d,), dt),        # per-head group norm scale
        # channel-mix
        "cm_k": dense_init(ks[8], d, cfg.d_ff, dt),
        "cm_v": dense_init(ks[9], cfg.d_ff, d, dt,
                           scale=1.0 / math.sqrt(cfg.d_ff)),
        "cm_mix": (jnp.ones((1, d), jnp.float32) * 0.5).astype(dt),
    }
    return p


def _token_shift(x, x_prev_last=None):
    """shifted[t] = x[t-1]; position 0 uses x_prev_last (decode carry)."""
    B, S, D = x.shape
    if x_prev_last is None:
        first = jnp.zeros((B, 1, D), x.dtype)
    else:
        first = x_prev_last[:, None, :]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _mix(x, shifted, mu):
    return x * mu.astype(x.dtype) + shifted * (1.0 - mu).astype(x.dtype)


def _decay(params, xw):
    wf = params["w_base"] + jnp.tanh(
        dense(params["w_a"], xw).astype(jnp.float32)) @ \
        params["w_b"].astype(jnp.float32)
    # clamp so w >= exp(-exp(0.75)) ~= exp(-2.1): keeps the chunked kernel's
    # cumulative-decay rescaling inside fp32 range (kernels/rwkv6_scan.py)
    wf = jnp.clip(wf, -8.0, 0.75)
    return jnp.exp(-jnp.exp(wf))            # in (0, 1)


def _group_norm(y, scale, H):
    """Per-head normalization of the WKV output.  y: (B, S, D)."""
    B, S, D = y.shape
    yh = y.reshape(B, S, H, D // H).astype(jnp.float32)
    mean = jnp.mean(yh, -1, keepdims=True)
    var = jnp.mean(jnp.square(yh - mean), -1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + 1e-5)
    return (yh.reshape(B, S, D) * scale.astype(jnp.float32)).astype(y.dtype)


def rwkv6_time_mix(params, x, cfg: ModelConfig, x_prev=None, wkv_state=None,
                   impl=None, return_state=False):
    """x: (B,S,D).  Training/prefill when wkv_state is None; otherwise the
    single-step decode path (S==1).  Returns (y, (x_last, new_state))."""
    B, S, D = x.shape
    H = cfg.n_heads
    K = D // H
    shifted = _token_shift(x, x_prev)
    mu = params["mix"]
    xr = _mix(x, shifted, mu[0])
    xk = _mix(x, shifted, mu[1])
    xv = _mix(x, shifted, mu[2])
    xw = _mix(x, shifted, mu[3])
    xg = _mix(x, shifted, mu[4])

    r = dense(params["r_proj"], xr).reshape(B, S, H, K)
    k = dense(params["k_proj"], xk).reshape(B, S, H, K)
    v = dense(params["v_proj"], xv).reshape(B, S, H, K)
    g = jax.nn.silu(dense(params["g_proj"], xg).astype(jnp.float32))
    w = _decay(params, xw).reshape(B, S, H, K)

    if wkv_state is None:
        # gather the chunk streams across the sequence shards ONCE before
        # the chunked scan (XLA otherwise re-gathers the stacked chunks on
        # every scan iteration - measured 13.8 TiB/step; EXPERIMENTS.md D1)
        r = constrain(r, "kv_rep")
        k = constrain(k, "kv_rep")
        v = constrain(v, "kv_rep")
        w = constrain(w, "kv_rep")
        if return_state:
            from ..kernels import ref as kref
            y, new_state = kref.rwkv6_scan_chunked_state(r, k, v, w,
                                                         params["u"])
        else:
            y = ops.rwkv6_scan(r, k, v, w, params["u"], impl=impl)
            new_state = None
    else:
        s_new, y1 = ops.rwkv6_step(wkv_state, r[:, 0], k[:, 0], v[:, 0],
                                   w[:, 0], params["u"])
        y = y1[:, None].reshape(B, S, H, K)
        new_state = s_new
    y = y.reshape(B, S, D)
    y = _group_norm(y, params["ln_x"], H)
    y = (y.astype(jnp.float32) * g).astype(x.dtype)
    return dense(params["out_proj"], y), (x[:, -1], new_state)


def rwkv6_channel_mix(params, x, cfg: ModelConfig, x_prev=None):
    shifted = _token_shift(x, x_prev)
    xk = _mix(x, shifted, params["cm_mix"][0])
    h = jnp.square(jax.nn.relu(dense(params["cm_k"], xk).astype(jnp.float32)))
    return dense(params["cm_v"], h.astype(x.dtype)), x[:, -1]


def rwkv6_init_state(cfg: ModelConfig, batch: int):
    H = cfg.n_heads
    K = cfg.d_model // H
    dt = jnp.dtype(cfg.dtype)
    return {"wkv": jnp.zeros((batch, H, K, K), jnp.float32),
            "tm_prev": jnp.zeros((batch, cfg.d_model), dt),
            "cm_prev": jnp.zeros((batch, cfg.d_model), dt)}
