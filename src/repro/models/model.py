"""Top-level language-model API: build_model(cfg) -> init / forward / loss /
cache / prefill / decode_step, for every architecture family.

Batch dict keys:
  tokens        (B, S)  text / decoder tokens (int32)
  vision_embeds (B, P, d_model)   [vlm stub frontend]
  audio_embeds  (B, S_enc, d_model) [audio stub frontend]
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import constrain
from . import transformer as T
from .layers import (apply_norm, embed, embed_init, norm_init, pdtype,
                     sinusoidal_positions, unembed)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    forward: Callable
    loss: Callable
    init_cache: Callable
    prefill: Callable
    decode_step: Callable
    # paged KV-cache prompt prefill (attention families only; see
    # serve/paged_cache.py for the host-side allocator)
    prefill_paged: Optional[Callable] = None
    # prefix-cached suffix prefill: only the uncached tail of the prompt is
    # computed, attending over cached pages via the block table
    # (serve/prefix_cache.py owns the host-side radix tree)
    prefill_suffix: Optional[Callable] = None
    # mid-prompt chunk prefill for token-budget scheduling: any contiguous
    # chunk of a prompt prefills through the same offset-causal block-table
    # kernel, attending over everything already written (cached prefix +
    # earlier chunks).  The suffix above is the final-chunk special case.
    # (serve/scheduler.py owns the host-side chunk planning)
    prefill_chunk: Optional[Callable] = None
    # ragged batched chunk prefill: K chunks of K different sequences, each
    # with its own block-table row / offset / cursor, in ONE call - the
    # one-launch serve tick packs a whole tick's chunk plan through this.
    # prefill_chunk above is its K=1 special case.
    prefill_chunks: Optional[Callable] = None
    # speculative verification: the same ragged chunk pass, but returning
    # EVERY position's logits (K, S, V) instead of each row's last - one
    # launch scores a whole draft chain per row so the serve engine can
    # accept/reject it in place (serve/serve_step.py make_spec_verify_step)
    verify_chunks: Optional[Callable] = None


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family

    # ---------------- init -------------------------------------------------
    def init(key):
        k_emb, k_blocks = jax.random.split(key)
        params: Dict[str, Any] = {"tok": embed_init(k_emb, cfg),
                                  "final_norm": norm_init(cfg)}
        if fam in ("dense", "moe", "vlm"):
            params["blocks"] = T.stack_init(k_blocks, cfg)
        elif fam == "hybrid":
            params["blocks"] = T.hybrid_init(k_blocks, cfg)
        elif fam == "ssm":
            params["blocks"] = T.rwkv_init(k_blocks, cfg)
        elif fam == "audio":
            params["blocks"] = T.encdec_init(k_blocks, cfg)
        else:
            raise ValueError(f"unknown family {fam}")
        return params

    # ---------------- embedding helpers ------------------------------------
    def _embed_tokens(params, tokens, offset: int = 0):
        x = embed(params["tok"], tokens, cfg)
        if not cfg.use_rope and not cfg.rwkv:
            # sinusoidal absolute positions (OPT / whisper decoder)
            pos = sinusoidal_positions(tokens.shape[1], cfg.d_model, offset)
            x = x + pos[None].astype(x.dtype)
        return x

    def _assemble_input(params, batch):
        x = _embed_tokens(params, batch["tokens"])
        prefix = 0
        if fam == "vlm" and "vision_embeds" in batch:
            v = batch["vision_embeds"].astype(x.dtype)
            x = jnp.concatenate([v, x], axis=1)
            prefix = v.shape[1]
        return x, prefix

    # ---------------- forward ----------------------------------------------
    def forward(params, batch, *, impl=None, remat=False):
        """Returns (logits (B,S,V), aux_loss)."""
        if fam == "audio":
            enc = batch["audio_embeds"]
            pos = sinusoidal_positions(enc.shape[1], cfg.d_model)
            enc = enc + pos[None].astype(enc.dtype)
            x_dec = _embed_tokens(params, batch["tokens"])
            x, aux = T.encdec_forward(params["blocks"], enc, x_dec, cfg,
                                      impl=impl, remat=remat)
        else:
            x, _prefix = _assemble_input(params, batch)
            x = constrain(x, "btd")
            if fam in ("dense", "moe", "vlm"):
                x, aux = T.stack_forward(params["blocks"], x, cfg, impl=impl,
                                         remat=remat)
            elif fam == "hybrid":
                x, aux = T.hybrid_forward(params["blocks"], x, cfg,
                                          impl=impl, remat=remat)
            else:
                x, aux = T.rwkv_forward(params["blocks"], x, cfg, impl=impl,
                                        remat=remat)
        x = apply_norm(params["final_norm"], x, cfg)
        logits = unembed(params["tok"], x, cfg)
        logits = constrain(logits.astype(jnp.float32), "btv")
        return logits, aux

    # ---------------- loss --------------------------------------------------
    def loss(params, batch, *, impl=None, remat=False, aux_weight=0.01):
        logits, aux = forward(params, batch, impl=impl, remat=remat)
        tokens = batch["tokens"]
        labels = batch.get("labels", tokens)
        prefix = 0
        if fam == "vlm" and "vision_embeds" in batch:
            prefix = batch["vision_embeds"].shape[1]
            logits = logits[:, prefix:]
        # next-token prediction
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
        tgt = labels[:, 1:]
        nll = -jnp.take_along_axis(lp, tgt[..., None], -1)[..., 0]
        mask = batch.get("loss_mask")
        if mask is not None:
            mask = mask[:, 1:].astype(jnp.float32)
            ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        else:
            ce = jnp.mean(nll)
        total = ce + aux_weight * aux
        return total, {"ce": ce, "aux": aux}

    # ---------------- cache -------------------------------------------------
    def init_cache(batch_size: int, max_len: int, enc_len: int = 0, *,
                   page_size: int = 0, num_pages: int = 0):
        """Dense layout by default; page_size > 0 selects the paged layout:
        a global (L, num_pages, page_size, Hkv, D) page pool shared by all
        sequences plus a (batch, ceil(max_len/page_size)) block table.  Page
        0 is reserved as the null page (see serve/paged_cache.py)."""
        dt = pdtype(cfg)
        if page_size > 0:
            if fam not in ("dense", "moe", "vlm"):
                raise ValueError(
                    f"paged KV cache needs an attention family, got {fam}")
            from ..configs.base import dense_equivalent_pages, pages_for_tokens
            L = cfg.n_layers
            n_max = pages_for_tokens(max_len, page_size)
            if num_pages <= 0:
                num_pages = dense_equivalent_pages(batch_size, max_len,
                                                   page_size)
            shp = (L, num_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
            return {"k_pages": jnp.zeros(shp, dt),
                    "v_pages": jnp.zeros(shp, dt),
                    "block_table": jnp.zeros((batch_size, n_max), jnp.int32)}
        if fam in ("dense", "moe", "vlm"):
            L = cfg.n_layers
            shp = (L, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim)
            return {"k": jnp.zeros(shp, dt), "v": jnp.zeros(shp, dt)}
        if fam == "hybrid":
            return T.hybrid_init_cache(cfg, batch_size, max_len)
        if fam == "ssm":
            return T.rwkv_init_cache(cfg, batch_size, max_len)
        if fam == "audio":
            return T.encdec_init_cache(cfg, batch_size, max_len,
                                       enc_len or max_len)
        raise ValueError(fam)

    # ---------------- prefill ------------------------------------------------
    def prefill(params, batch, cache, *, impl=None):
        """Fill the cache with the prompt; returns (last_logits, cache, lens)."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        if fam in ("dense", "moe", "vlm"):
            x, prefix = _assemble_input(params, batch)
            x, cache = T.stack_prefill(params["blocks"], x, cfg, cache,
                                       impl=impl)
            lens = jnp.full((B,), S + prefix, jnp.int32)
        elif fam == "audio":
            enc = batch["audio_embeds"]
            pos = sinusoidal_positions(enc.shape[1], cfg.d_model)
            enc_in = enc + pos[None].astype(enc.dtype)
            x_dec = _embed_tokens(params, tokens)
            x, cache = T.encdec_prefill(params["blocks"], enc_in, x_dec, cfg,
                                        cache, impl=impl)
            lens = jnp.full((B,), S, jnp.int32)
        elif fam == "hybrid":
            x, _ = _assemble_input(params, batch)
            x, cache = T.hybrid_prefill(params["blocks"], x, cfg, cache,
                                        impl=impl)
            lens = jnp.full((B,), S, jnp.int32)
        elif fam == "ssm":
            x, _ = _assemble_input(params, batch)
            x, cache = T.rwkv_prefill(params["blocks"], x, cfg, cache,
                                      impl=impl)
            lens = jnp.full((B,), S, jnp.int32)
        else:
            return _prefill_via_decode(params, batch, cache, impl=impl)
        # prompts padded to a bucketed length carry their real lengths in
        # batch["true_lens"]; trailing pad K/V is masked by `lens` downstream
        tl = batch.get("true_lens")
        if tl is not None:
            lens = jnp.asarray(tl, jnp.int32) + (lens - S)
        x = apply_norm(params["final_norm"], x, cfg)
        x_last = x[:, -1:] if tl is None else \
            jnp.take_along_axis(x, (lens - 1)[:, None, None], axis=1)
        logits = unembed(params["tok"], x_last, cfg)
        return logits.astype(jnp.float32), cache, lens

    # ---------------- paged prefill -----------------------------------------
    def prefill_paged(params, batch, cache, page_ids, *, impl=None):
        """Prefill ONE sequence's prompt (B=1) into its KV pages.

        batch: {"tokens": (1, S_pad), "true_lens": (1,) optional} with S_pad
        a multiple of the page size; page_ids: (S_pad // page_size,) pages
        owned by the sequence; cache: the paged layout from init_cache.
        Returns (last_logits, cache, lens)."""
        if fam not in ("dense", "moe", "vlm"):
            raise ValueError(
                f"paged prefill needs an attention family, got {fam}")
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = _embed_tokens(params, tokens)
        x = constrain(x, "btd")
        x, cache = T.stack_prefill_paged(params["blocks"], x, cfg, cache,
                                         page_ids, impl=impl)
        tl = batch.get("true_lens")
        lens = jnp.full((B,), S, jnp.int32) if tl is None \
            else jnp.asarray(tl, jnp.int32)
        x = apply_norm(params["final_norm"], x, cfg)
        x_last = x[:, -1:] if tl is None else \
            jnp.take_along_axis(x, (lens - 1)[:, None, None], axis=1)
        logits = unembed(params["tok"], x_last, cfg)
        return logits.astype(jnp.float32), cache, lens

    def prefill_chunks(params, batch, cache, page_tables, *, impl=None,
                       tp_mesh=None):
        """Prefill a RAGGED BATCH of mid-prompt chunks: K chunks of K
        different sequences, each at its own prompt position, in ONE pass
        (the serve engine's one-launch tick packs every chunk the
        scheduler planned into a single call here).

        batch: {"tokens": (K, S_pad) chunk tokens (each row zero-padded),
                "offset": (K,) absolute position of each row's first token,
                "true_lens": (K,) cursor AFTER each row's last real token
                (= offset + real chunk length)}; page_tables: (K, n_max)
        per-row block-table rows.  Every row's queries attend causally
        over everything already resident - cached prefix pages, earlier
        chunks' K/V (including other rows of the SAME call, when two
        chunks of one sequence are packed together with ordered offsets),
        and the row's own chunk - through the offset-causal batched
        block-table kernel (kernels/paged_prefill.py), so composing
        chunks left to right reproduces the monolithic prefill exactly.
        Dead padding rows carry true_lens == 0 and an all-null table row;
        their logits are garbage the caller drops.
        Returns (chunk_last_logits (K, 1, V), cache, cursors (K,)): each
        row's logits are those of its LAST real token (meaningful for
        final chunks, whose cursor equals the prompt length and whose
        logits seed decoding)."""
        if fam not in ("dense", "moe", "vlm"):
            raise ValueError(
                f"chunked prefill needs an attention family, got {fam}")
        tokens = batch["tokens"]
        B, S = tokens.shape
        offs = jnp.asarray(batch["offset"], jnp.int32)
        lens = jnp.asarray(batch["true_lens"], jnp.int32)
        x = embed(params["tok"], tokens, cfg)
        if not cfg.use_rope and not cfg.rwkv:
            # absolute sinusoidal positions start at each row's offset
            tbl = sinusoidal_positions(65536, cfg.d_model)
            pos = jnp.minimum(offs[:, None] + jnp.arange(S)[None, :], 65535)
            x = x + jnp.take(tbl, pos, axis=0).astype(x.dtype)
        x = constrain(x, "btd")
        x, cache = T.stack_prefill_chunks_paged(params["blocks"], x, cfg,
                                                cache, page_tables, offs,
                                                lens, impl=impl,
                                                tp_mesh=tp_mesh)
        x = apply_norm(params["final_norm"], x, cfg)
        # each row's last REAL token sits at chunk index lens - offset - 1
        # (clamped to 0 for dead padding rows, whose logits are dropped)
        idx = jnp.maximum(lens - offs - 1, 0)
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        logits = unembed(params["tok"], x_last, cfg)
        return logits.astype(jnp.float32), cache, lens

    def verify_chunks(params, batch, cache, page_tables, *, impl=None,
                      tp_mesh=None):
        """Score a ragged batch of SPECULATIVE DRAFT CHAINS: row k holds
        [pending token, draft_1 .. draft_m] at absolute positions
        batch["offset"][k] + arange(S) - exactly the prefill_chunks
        contract (each row's K/V scatters into its pages, then the
        offset-causal batched kernel attends over everything resident) -
        but returns EVERY position's logits, because acceptance needs the
        target distribution at each chain position, not just the last.

        batch adds "q_lens" (K,): the per-row REAL query count (1 + m),
        fed to the kernel's draft-length lane so pad positions come back
        as exactly-zero rows (deterministic logits whatever the pad lanes
        hold).  Returns (logits (K, S, V) float32, cache).  Writing the
        whole chain's K/V is speculative too: positions past the accepted
        frontier are simply left behind the row's `lens` - masked by the
        causal/true_len tests everywhere KV is read - and overwritten by
        whatever decodes next, so rejection needs no page bookkeeping."""
        if fam not in ("dense", "moe", "vlm"):
            raise ValueError(
                f"speculative verification needs an attention family, "
                f"got {fam}")
        tokens = batch["tokens"]
        B, S = tokens.shape
        offs = jnp.asarray(batch["offset"], jnp.int32)
        lens = jnp.asarray(batch["true_lens"], jnp.int32)
        qls = jnp.asarray(batch["q_lens"], jnp.int32)
        x = embed(params["tok"], tokens, cfg)
        if not cfg.use_rope and not cfg.rwkv:
            tbl = sinusoidal_positions(65536, cfg.d_model)
            pos = jnp.minimum(offs[:, None] + jnp.arange(S)[None, :], 65535)
            x = x + jnp.take(tbl, pos, axis=0).astype(x.dtype)
        x = constrain(x, "btd")
        x, cache = T.stack_prefill_chunks_paged(params["blocks"], x, cfg,
                                                cache, page_tables, offs,
                                                lens, q_lens=qls, impl=impl,
                                                tp_mesh=tp_mesh)
        x = apply_norm(params["final_norm"], x, cfg)
        logits = unembed(params["tok"], x, cfg)
        return logits.astype(jnp.float32), cache

    def prefill_chunk(params, batch, cache, page_row, *, impl=None):
        """Prefill one MID-PROMPT chunk of one sequence's prompt: the K=1
        special case of prefill_chunks.

        batch: {"tokens": (1, S_pad), "offset": (1,), "true_lens": (1,)}
        - exactly the batched layout with one row; page_row: (n_max,) the
        sequence's block-table row.  Returns (chunk_last_logits, cache,
        cursor).

        The prefix-cache suffix path is the final-chunk special case:
        cursor == full prompt length (Model.prefill_suffix aliases this)."""
        return prefill_chunks(params, batch, cache,
                              jnp.asarray(page_row, jnp.int32)[None],
                              impl=impl)

    # prefix-cached suffix prefill IS a chunk prefill whose cursor is the
    # full prompt length - kept under its established name
    prefill_suffix = prefill_chunk

    def _fill_cross_cache(params, cache, enc_out):
        from .layers import dense
        dec = params["blocks"]["decoder"]
        B, Se, _ = enc_out.shape

        def body(_, xs):
            p, ck, cv = xs
            ca = p["cross_attn"]
            k = dense(ca["wk"], enc_out).reshape(B, Se, cfg.n_kv_heads,
                                                 cfg.head_dim)
            v = dense(ca["wv"], enc_out).reshape(B, Se, cfg.n_kv_heads,
                                                 cfg.head_dim)
            return None, (k.astype(ck.dtype), v.astype(cv.dtype))

        _, (ck, cv) = jax.lax.scan(body, None,
                                   (dec, cache["cross_k"], cache["cross_v"]))
        out = dict(cache)
        out["cross_k"], out["cross_v"] = ck, cv
        return out

    def _prefill_via_decode(params, batch, cache, *, impl=None):
        """Sequential prefill through decode_step (recurrent families and the
        whisper decoder); exact, used at example/smoke scale."""
        tokens = batch["tokens"]
        B, S = tokens.shape

        def body(carry, t):
            cache, lens, _ = carry
            logits, cache = decode_step(params, tokens[:, t][:, None], lens,
                                        cache, impl=impl)
            return (cache, lens + 1, logits), None

        B = tokens.shape[0]
        dummy = jnp.zeros((B, 1, cfg.vocab_size), jnp.float32)
        (cache, lens, logits), _ = jax.lax.scan(
            body, (cache, jnp.zeros((B,), jnp.int32), dummy), jnp.arange(S))
        return logits, cache, lens

    # ---------------- decode -------------------------------------------------
    def decode_step(params, tokens, lens, cache, *, impl=None,
                    seq_parallel=False, enc_lens=None, tp_mesh=None):
        """tokens: (B,1); lens: (B,) positions to write.  Returns
        (logits (B,1,V), new_cache).  tp_mesh head-shards the paged decode
        across the serve mesh (attention families with a paged cache
        only)."""
        if fam == "audio":
            x = embed(params["tok"], tokens, cfg)
            pos = jax.vmap(lambda l: sinusoidal_positions(1, cfg.d_model, 0)
                           )(lens)  # position folded via rope-free decoder
            x = x + jnp.take(sinusoidal_positions(cfg.max_seq if cfg.max_seq
                                                  < 65536 else 65536,
                                                  cfg.d_model),
                             lens, axis=0)[:, None].astype(x.dtype)
            el = enc_lens if enc_lens is not None \
                else jnp.full_like(lens, cache["cross_k"].shape[2])
            x, cache = T.encdec_decode(params["blocks"], x, cfg, cache, lens,
                                       el, impl=impl,
                                       seq_parallel=seq_parallel)
        else:
            x = embed(params["tok"], tokens, cfg)
            if not cfg.use_rope and not cfg.rwkv:
                tbl = sinusoidal_positions(65536, cfg.d_model)
                x = x + jnp.take(tbl, jnp.minimum(lens, 65535),
                                 axis=0)[:, None].astype(x.dtype)
            if fam in ("dense", "moe", "vlm"):
                if "k_pages" in cache:
                    if seq_parallel:
                        raise ValueError(
                            "paged decode does not compose with the "
                            "sequence-parallel cache layout")
                    x, cache = T.stack_decode_paged(params["blocks"], x, cfg,
                                                    cache, lens, impl=impl,
                                                    tp_mesh=tp_mesh)
                else:
                    x, cache = T.stack_decode(params["blocks"], x, cfg, cache,
                                              lens, impl=impl,
                                              seq_parallel=seq_parallel)
            elif fam == "hybrid":
                x, cache = T.hybrid_decode(params["blocks"], x, cfg, cache,
                                           lens, impl=impl,
                                           seq_parallel=seq_parallel)
            else:
                x, cache = T.rwkv_decode(params["blocks"], x, cfg, cache,
                                         lens, impl=impl,
                                         seq_parallel=seq_parallel)
        x = apply_norm(params["final_norm"], x, cfg)
        logits = unembed(params["tok"], x, cfg)
        return logits.astype(jnp.float32), cache

    is_attn = fam in ("dense", "moe", "vlm")
    return Model(cfg=cfg, init=init, forward=forward, loss=loss,
                 init_cache=init_cache, prefill=prefill,
                 decode_step=decode_step,
                 prefill_paged=prefill_paged if is_attn else None,
                 prefill_suffix=prefill_suffix if is_attn else None,
                 prefill_chunk=prefill_chunk if is_attn else None,
                 prefill_chunks=prefill_chunks if is_attn else None,
                 verify_chunks=verify_chunks if is_attn else None)
