"""Shared neural-net building blocks (pure-functional, dict params)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


@jax.custom_vjp
def grad_cast(x):
    """Identity whose COTANGENT is cast to the primal dtype.  Mixed-precision
    dot transposes otherwise produce fp32 cotangents for bf16 primals, which
    then flow at full size through scatter/gather/collective backwards."""
    return x


def _grad_cast_fwd(x):
    return x, jnp.zeros((0,), x.dtype)    # dtype token (residuals must be arrays)


def _grad_cast_bwd(tok, g):
    return (g.astype(tok.dtype),)


grad_cast.defvjp(_grad_cast_fwd, _grad_cast_bwd)


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def dense(w, x):
    """x: (..., d_in) @ w: (d_in, d_out), bf16-native dot.

    No preferred_element_type=f32 here: the TPU MXU accumulates fp32
    internally for bf16 dots, and a bf16 result keeps the row-parallel
    partial-sum all-reduce (and FSDP weight all-gathers) at half the bytes.
    Requesting f32 results makes SPMD carry every projection collective in
    fp32 (measured 2x collective-term regression; see EXPERIMENTS.md S.Perf).
    """
    y = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())))
    return grad_cast(y.astype(x.dtype))


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((d,), pdtype(cfg))}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), pdtype(cfg)),
                "bias": jnp.zeros((d,), pdtype(cfg))}
    return {}   # nonparam_ln (OLMo): no learned affine


def apply_norm(params, x, cfg: ModelConfig, eps: float = 1e-6):
    # grad_cast: the norm computes in fp32, which would otherwise make the
    # cotangent of its input fp32 - and that cotangent is exactly what the
    # sequence-parallel gather/reduce-scatter transpose pair carries.
    x = grad_cast(x)
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), -1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    if cfg.norm == "layernorm":
        y = y * params["scale"].astype(jnp.float32) \
            + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(x, scale, eps: float = 1e-6):
    """Per-head RMS norm (gemma3/qwen3 QK-norm).  x: (..., head_dim)."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embedding (with dynamic scaling - paper Section V)
# --------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float,
         scaling: float = 1.0) -> jax.Array:
    """x: (B, S, H, D); positions: (S,) or (B, S)."""
    D = x.shape[-1]
    half = D // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    pos = positions.astype(jnp.float32) / scaling
    if pos.ndim == 1:
        ang = pos[:, None] * freqs[None, :]                  # (S, half)
        ang = ang[None, :, None, :]                          # (1,S,1,half)
    else:
        ang = pos[:, :, None] * freqs[None, None, :]         # (B,S,half)
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], -1).astype(x.dtype)


def sinusoidal_positions(seq: int, d: int, offset: int = 0) -> jax.Array:
    pos = jnp.arange(offset, offset + seq, dtype=jnp.float32)[:, None]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (math.log(10_000.0) / half))
    ang = pos * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d: Optional[int] = None,
             f: Optional[int] = None):
    d = d or cfg.d_model
    f = f or cfg.d_ff
    dt = pdtype(cfg)
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], d, f, dt),
         "w_out": dense_init(ks[1], f, d, dt, scale=1.0 / math.sqrt(f))}
    if cfg.act == "silu":
        p["w_gate"] = dense_init(ks[2], d, f, dt)
    return p


def mlp(params, x, cfg: ModelConfig):
    h = dense(params["w_in"], x)
    if cfg.act == "silu":
        h = jax.nn.silu(dense(params["w_gate"], x).astype(jnp.float32)) \
            * h.astype(jnp.float32)
        h = h.astype(x.dtype)
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return dense(params["w_out"], h)


# --------------------------------------------------------------------------
# Embedding
# --------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig):
    dt = pdtype(cfg)
    p = {"embed": (jax.random.normal(key, (cfg.vocab_size, cfg.d_model),
                                     jnp.float32) * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["lm_head"] = (jax.random.normal(
            k2, (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02).astype(dt)
    return p


def embed(params, tokens, cfg: ModelConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family == "dense" and cfg.qk_norm:     # gemma-style input scaling
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(params, x, cfg: ModelConfig):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jax.lax.dot_general(
        x, table, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    return logits
