"""Mamba2 (SSD) block: gated selective state-space with depthwise conv.

Decode state = (conv window buffer, SSM state h).  The chunked scan kernel
(kernels/mamba2_scan.py) applies the paper's fusion principle to the
attention-free chain: decay/inject/output stay VMEM-resident per chunk.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from ..sharding import constrain
from .layers import dense, dense_init, pdtype


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads or max(1, d_in // 64)      # head channel P = 64
    P = d_in // H
    N = cfg.ssm_state
    return d_in, H, P, N


def mamba2_init(key, cfg: ModelConfig):
    dt = pdtype(cfg)
    d = cfg.d_model
    d_in, H, P, N = _dims(cfg)
    ks = jax.random.split(key, 5)
    # in_proj emits [z (d_in) | x (d_in) | B (N) | C (N) | dt (H)]
    proj_out = 2 * d_in + 2 * N + H
    p = {
        "in_proj": dense_init(ks[0], d, proj_out, dt),
        "out_proj": dense_init(ks[1], d_in, d, dt, scale=1.0 / math.sqrt(d_in)),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv, d_in), jnp.float32)
                   * (1.0 / math.sqrt(cfg.ssm_conv))).astype(dt),
        "A_log": jnp.zeros((H,), jnp.float32),           # A = exp(A_log) > 0
        "dt_bias": jnp.full((H,), math.log(math.e - 1), jnp.float32),
        "gate_norm": jnp.ones((d_in,), dt),
    }
    return p


def _split(proj, cfg: ModelConfig):
    d_in, H, P, N = _dims(cfg)
    z = proj[..., :d_in]
    x = proj[..., d_in:2 * d_in]
    Bm = proj[..., 2 * d_in:2 * d_in + N]
    Cm = proj[..., 2 * d_in + N:2 * d_in + 2 * N]
    dt = proj[..., 2 * d_in + 2 * N:]
    return z, x, Bm, Cm, dt


def _causal_conv(x, w):
    """Depthwise causal conv.  x: (B, S, C); w: (k, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1]].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    return out.astype(x.dtype)


def _gated_out(params, y, z, cfg: ModelConfig):
    d_in = y.shape[-1]
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
    yf = yf * params["gate_norm"].astype(jnp.float32)
    return dense(params["out_proj"], yf.astype(y.dtype))


def mamba2_forward(params, x, cfg: ModelConfig, impl=None):
    """x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    d_in, H, P, N = _dims(cfg)
    proj = dense(params["in_proj"], x)
    z, xs, Bm, Cm, dt = _split(proj, cfg)
    xs = jax.nn.silu(_causal_conv(xs, params["conv_w"]).astype(jnp.float32)
                     ).astype(x.dtype)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = jnp.exp(params["A_log"])
    xh = constrain(xs.reshape(B, S, H, P), "kv_rep")   # gather-once (D1)
    dt = constrain(dt, "btd_rep")
    Bm = constrain(Bm, "btd_rep")
    Cm = constrain(Cm, "btd_rep")
    y = ops.mamba2_scan(xh, dt, A, Bm, Cm, impl=impl)       # (B,S,H,P)
    return _gated_out(params, y.reshape(B, S, d_in), z, cfg)


def mamba2_prefill(params, x, cfg: ModelConfig, impl=None):
    """Full-sequence prefill: (y, state) with the final SSM state and conv
    window, so decode continues exactly where the prompt ended."""
    from ..kernels import ref as kref
    B, S, D = x.shape
    d_in, H, P, N = _dims(cfg)
    proj = dense(params["in_proj"], x)
    z, xs, Bm, Cm, dt = _split(proj, cfg)
    xs = jax.nn.silu(_causal_conv(xs, params["conv_w"]).astype(jnp.float32)
                     ).astype(x.dtype)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = jnp.exp(params["A_log"])
    xh = constrain(xs.reshape(B, S, H, P), "kv_rep")
    y, h_fin = kref.mamba2_scan_chunked_state(
        xh, constrain(dtf, "btd_rep"), A,
        constrain(Bm, "btd_rep"), constrain(Cm, "btd_rep"))
    out = _gated_out(params, y.reshape(B, S, d_in), z, cfg)
    # conv window: last (ssm_conv-1) PRE-conv inputs
    _, xs_raw, _, _, _ = _split(proj, cfg)
    conv_win = xs_raw[:, S - (cfg.ssm_conv - 1):, :].astype(
        jnp.dtype(cfg.dtype))
    return out, {"conv": conv_win, "ssm": h_fin}


def mamba2_init_state(cfg: ModelConfig, batch: int):
    d_in, H, P, N = _dims(cfg)
    return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_in), jnp.dtype(cfg.dtype)),
            "ssm": jnp.zeros((batch, H, P, N), jnp.float32)}


def mamba2_decode(params, x, cfg: ModelConfig, state):
    """x: (B, 1, D); returns (y (B,1,D), new_state)."""
    B = x.shape[0]
    d_in, H, P, N = _dims(cfg)
    proj = dense(params["in_proj"], x)[:, 0]                 # (B, proj)
    z, xs, Bm, Cm, dt = _split(proj, cfg)
    # conv over the stored window + current input
    win = jnp.concatenate([state["conv"], xs[:, None, :]], axis=1)  # (B,k,d_in)
    w = params["conv_w"]
    xc = jnp.sum(win.astype(jnp.float32) * w.astype(jnp.float32)[None], axis=1)
    xc = jax.nn.silu(xc).astype(x.dtype)
    new_conv = win[:, 1:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = jnp.exp(params["A_log"])
    h, y = ops.mamba2_step(state["ssm"], xc.reshape(B, H, P), dt, A, Bm, Cm)
    y = _gated_out(params, y.reshape(B, 1, d_in),
                   z.reshape(B, 1, d_in), cfg)
    return y, {"conv": new_conv, "ssm": h}
