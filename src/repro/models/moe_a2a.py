"""Expert-parallel MoE dispatch with an EXPLICIT all-to-all (shard_map).

Phase-C of the perf log showed that XLA's SPMD partitioner cannot infer the
token->expert exchange: it gathers the K-expanded token rows (B, S*K, D) per
layer (8 GiB fp32 for qwen3-235B).  This module routes tokens manually:

  per model-shard (tp shards, E/tp experts each):
    1. route local tokens; destination shard = expert_id // (E/tp)
    2. compact rows per destination (cumsum slots, pair capacity C_pair)
    3. all_to_all  (tp, C_pair, D) token rows + int metadata
    4. receiver dispatches to its local (E/tp, C_loc, D) expert buffers,
       runs the gated-MLP experts, scatters replies back into the recv slots
    5. all_to_all back; the sender gathers each row's reply from the
       (dst, slot) coordinates it recorded, applies gates, sums over K

Wire cost per layer ~= 2 x (local rows x D) exchanged once - the 16x
reduction over the SPMD-inferred gather estimated in EXPERIMENTS.md §Perf C.

Capacity semantics: drops can occur at the pair level (C_pair) and the
expert level (C_loc); with the default factors both are >= the per-row
capacity of models/moe.py, so at moderate imbalance the two paths agree
exactly (tests/test_moe_a2a.py).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


def _positions_within(groups: jax.Array, n_groups: int) -> jax.Array:
    """Slot index of each element within its group (first-come order)."""
    onehot = jax.nn.one_hot(groups, n_groups, dtype=jnp.int32)
    return jnp.take_along_axis(jnp.cumsum(onehot, 0) - 1,
                               groups[:, None], 1)[:, 0]


def moe_ffn_a2a_local(params, x_local, cfg: ModelConfig, *,
                      axis: str = "model") -> Tuple[jax.Array, jax.Array]:
    """Per-shard body (call inside shard_map over `axis`).

    x_local: (B, S_local, D) - this shard's token slice.
    params:  router replicated; experts_* sharded on the expert dim
             (leading-axis slice of E/tp experts is this shard's).
    Returns (y_local (B, S_local, D), aux_loss).
    """
    B, S_l, D = x_local.shape
    E, K = cfg.moe_experts, cfg.moe_top_k
    from ..compat import axis_size
    tp = axis_size(axis)
    my = jax.lax.axis_index(axis)
    e_loc = E // tp

    # ---- 1. routing ------------------------------------------------------
    xt = x_local.reshape(B * S_l, D)
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gate_vals, top_idx = jax.lax.top_k(probs, K)            # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    me = jnp.mean(probs, 0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_idx, E, dtype=jnp.float32), 1), 0)
    aux = E * jnp.sum(jax.lax.pmean(me, axis)
                      * jax.lax.pmean(ce, axis)) / K

    T = B * S_l
    rows = jnp.repeat(jnp.arange(T), K)                     # (T*K,)
    flat_e = top_idx.reshape(-1)
    flat_g = gate_vals.reshape(-1)
    dst = flat_e // e_loc                                   # destination shard
    loc_e = flat_e % e_loc                                  # expert on dst

    # ---- 2. compact per destination (pair capacity) -----------------------
    c_pair = int(math.ceil(T * K / tp * cfg.moe_capacity_factor))
    slot = _positions_within(dst, tp)
    keep = slot < c_pair
    slot = jnp.where(keep, slot, c_pair)                    # c_pair = drop

    send_x = jnp.zeros((tp, c_pair + 1, D), x_local.dtype) \
        .at[dst, slot].set(xt[rows])
    send_le = jnp.full((tp, c_pair + 1), e_loc, jnp.int32) \
        .at[dst, slot].set(loc_e)                           # e_loc = inert

    # ---- 3. all-to-all ------------------------------------------------------
    recv_x = jax.lax.all_to_all(send_x[:, :c_pair], axis, 0, 0, tiled=False)
    recv_le = jax.lax.all_to_all(send_le[:, :c_pair], axis, 0, 0, tiled=False)
    recv_x = recv_x.reshape(tp * c_pair, D)
    recv_le = recv_le.reshape(tp * c_pair)

    # ---- 4. local expert dispatch + compute --------------------------------
    c_loc = int(math.ceil(tp * c_pair / e_loc * cfg.moe_capacity_factor))
    eslot = _positions_within(recv_le, e_loc + 1)           # +1: inert group
    ekeep = (eslot < c_loc) & (recv_le < e_loc)
    eslot = jnp.where(ekeep, eslot, c_loc)
    le_safe = jnp.where(recv_le < e_loc, recv_le, 0)

    buf = jnp.zeros((e_loc, c_loc + 1, D), x_local.dtype) \
        .at[jnp.where(ekeep, le_safe, 0), eslot].add(
            jnp.where(ekeep[:, None], recv_x, 0))
    ein = buf[:, :c_loc]

    w_in = params["experts_in"]
    h = jnp.einsum("ecd,edf->ecf", ein.astype(jnp.float32),
                   w_in.astype(jnp.float32))
    if cfg.act == "silu":
        g = jnp.einsum("ecd,edf->ecf", ein.astype(jnp.float32),
                       params["experts_gate"].astype(jnp.float32))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    out = jnp.einsum("ecf,efd->ecd", h,
                     params["experts_out"].astype(jnp.float32))
    out = out.astype(x_local.dtype)

    # scatter replies back into the recv slot layout
    out_pad = jnp.pad(out, ((0, 0), (0, 1), (0, 0)))
    reply = jnp.where(ekeep[:, None], out_pad[le_safe, eslot], 0.0)
    reply = reply.reshape(tp, c_pair, D)

    # ---- 5. all-to-all back + sender-side combine ---------------------------
    back = jax.lax.all_to_all(reply, axis, 0, 0, tiled=False)
    back_pad = jnp.pad(back, ((0, 0), (0, 1), (0, 0)))      # drop slot
    contrib = back_pad[dst, slot] * jnp.where(keep, flat_g, 0.0)[:, None] \
        .astype(x_local.dtype)
    y = jnp.zeros((T, D), jnp.float32).at[rows].add(
        contrib.astype(jnp.float32))
    return y.reshape(B, S_l, D).astype(x_local.dtype), aux


def make_sharded_moe(cfg: ModelConfig, mesh, *, axis: str = "model"):
    """shard_map-wrapped MoE FFN: tokens sharded on seq over `axis`, expert
    weights sharded on the expert dim, router replicated."""
    from jax.sharding import PartitionSpec as P
    pspec = {"router": P(None, None),
             "experts_in": P(axis, None, None),
             "experts_out": P(axis, None, None)}
    if cfg.act == "silu":
        pspec["experts_gate"] = P(axis, None, None)

    def fn(params, x):
        return moe_ffn_a2a_local(params, x, cfg, axis=axis)

    from ..compat import shard_map
    return shard_map(
        fn, mesh=mesh,
        in_specs=(pspec, P(None, axis, None)),
        out_specs=(P(None, axis, None), P()))
