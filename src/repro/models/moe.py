"""Mixture-of-Experts FFN: top-k routing, capacity-based dispatch.

Dispatch is per batch row (capacity = S*K/E per row): the position cumsum
runs along the *unsharded* in-row axis, so under SPMD the whole routing
pipeline partitions cleanly over (batch -> data, experts -> model) with the
token->expert exchange lowering to an all-to-all between the data and model
axes (expert parallelism).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import constrain
from .layers import dense_init, grad_cast, pdtype


def _edot(pattern, a, w):
    """Expert einsum with fp32 accumulation (see kernels.ref.mixed_einsum)."""
    from ..kernels.ref import mixed_einsum
    return mixed_einsum(pattern, a, w)


def moe_init(key, cfg: ModelConfig):
    dt = pdtype(cfg)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    ks = jax.random.split(key, 4)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f)
    p = {
        "router": (jax.random.normal(ks[0], (d, e), jnp.float32)
                   * scale_in).astype(jnp.float32),
        "experts_in": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                       * scale_in).astype(dt),
        "experts_out": (jax.random.normal(ks[2], (e, f, d), jnp.float32)
                        * scale_out).astype(dt),
    }
    if cfg.act == "silu":
        p["experts_gate"] = (jax.random.normal(ks[3], (e, d, f), jnp.float32)
                             * scale_in).astype(dt)
    return p


def moe_ffn(params, x, cfg: ModelConfig):
    """x: (B, S, D) -> ((B, S, D), aux_loss)."""
    B, S, D = x.shape
    E, K = cfg.moe_experts, cfg.moe_top_k

    # opt-in explicit all-to-all expert-parallel dispatch (shard_map): the
    # SPMD partitioner cannot infer the token->expert exchange and gathers
    # the K-expanded rows per layer (EXPERIMENTS.md S.Perf Phase C/F).
    import os
    if os.environ.get("REPRO_MOE_A2A"):
        from ..sharding import active_mesh, mesh_axis_size
        mesh = active_mesh()
        names = tuple(mesh.axis_names) if mesh is not None else ()
        if "model" in names:
            tp = mesh_axis_size(mesh, "model")
            if tp > 1 and E % tp == 0 and S % tp == 0:
                from .moe_a2a import moe_ffn_a2a_local
                from jax.sharding import PartitionSpec as P
                pspec = {k: (P("model", None, None) if k.startswith("experts")
                             else P(None, None)) for k in params}
                from ..compat import shard_map
                fn = shard_map(
                    lambda p, xx: moe_ffn_a2a_local(p, xx, cfg),
                    mesh=mesh,
                    in_specs=(pspec, P(None, "model", None)),
                    out_specs=(P(None, "model", None), P()))
                return fn(params, x)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, top_idx = jax.lax.top_k(probs, K)               # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # Switch-style load-balancing auxiliary loss
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_idx, E, dtype=jnp.float32), 2),
                  axis=(0, 1))
    aux_loss = E * jnp.sum(me * ce) / K

    # ---- per-row capacity dispatch -----------------------------------------
    C = int(math.ceil(S * K / E * cfg.moe_capacity_factor))
    flat_e = top_idx.reshape(B, S * K)                         # (B, S*K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)              # (B, S*K, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - 1                  # in-row cumsum
    pos = jnp.take_along_axis(pos_in_e, flat_e[..., None],
                              2)[..., 0]                       # (B, S*K)
    keep = pos < C
    pos = jnp.where(keep, pos, C)                              # slot C = drop

    tok_idx = jnp.arange(S * K) // K                           # (S*K,)
    # K-expanded token rows, sequence-sharded like the residual stream
    xk = constrain(jnp.take(x, tok_idx, axis=1), "btd")        # (B,S*K,D)

    def row_scatter(xkr, fe, fp):
        buf = jnp.zeros((E, C + 1, D), xkr.dtype)
        return buf.at[fe, fp].add(xkr)

    buf = jax.vmap(row_scatter)(xk, flat_e, pos)               # (B,E,C+1,D)
    expert_in = buf[:, :, :C]
    expert_in = grad_cast(constrain(expert_in, "becd"))

    # ---- expert computation (gated MLP) ------------------------------------
    h = _edot("becd,edf->becf", expert_in, params["experts_in"])
    if cfg.act == "silu":
        g = _edot("becd,edf->becf", expert_in, params["experts_gate"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = grad_cast(constrain(h.astype(x.dtype), "becf"))
    out = _edot("becf,efd->becd", h, params["experts_out"]).astype(x.dtype)
    out = grad_cast(constrain(out, "becd"))

    # ---- combine -------------------------------------------------------
    # Fold the gate weight into the expert output while still in the small
    # EP-sharded (B, E, C, D) layout; gather each token row\'s K expert
    # outputs and sum in bf16.  (The inverse scatter-add combine (V8) and
    # replicated-activation variants (V9) were measured and refuted - see
    # EXPERIMENTS.md S.Perf.)
    gates = jnp.where(keep, gate_vals.reshape(B, S * K), 0.0)  # (B, S*K)
    gate_buf = jax.vmap(
        lambda ge, fe, fp: jnp.zeros((E, C + 1), jnp.float32).at[fe, fp]
        .add(ge))(gates, flat_e, pos)                          # (B, E, C+1)
    out = out * gate_buf[:, :, :C, None].astype(out.dtype)
    out_pad = jnp.pad(out, ((0, 0), (0, 0), (0, 1), (0, 0)))   # drop slot
    gathered = jax.vmap(lambda o, fe, fp: o[fe, fp])(
        out_pad, flat_e, pos)                                  # (B,S*K,D) bf16
    gathered = constrain(gathered, "btd").reshape(B, S, K, D)
    y = jnp.sum(gathered, axis=2)                              # bf16 K-sum
    return y, aux_loss
