"""Block assembly and scan-over-layers stacks for every architecture family.

Families:
  dense / moe / vlm : [norm -> attn -> res] [norm -> (mlp|moe) -> res]
                      gemma3 pattern: every `global_every`-th layer global,
                      the rest sliding-window (lax.cond on a per-layer flag)
  hybrid (zamba2)   : mamba2 blocks; after every k-th block a SHARED-weight
                      attention+MLP block (weights closed over, not scanned)
  ssm (rwkv6)       : time-mix + channel-mix
  audio (whisper)   : encoder stack (non-causal) + decoder stack (causal
                      self-attn + cross-attn)

All stacks scan over layer-stacked parameter pytrees (leading L axis), which
keeps HLO size and compile time independent of depth.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..compat import optimization_barrier
from ..configs.base import ModelConfig
from ..sharding import constrain
from .attention import (attn_decode, attn_decode_paged, attn_forward,
                        attn_init, attn_prefill, attn_prefill_chunk_paged,
                        attn_prefill_chunks_paged, attn_prefill_paged)
from .layers import apply_norm, grad_cast, mlp, mlp_init, norm_init, pdtype
from .mamba2 import (mamba2_decode, mamba2_forward, mamba2_init,
                     mamba2_init_state, mamba2_prefill)
from .moe import moe_ffn, moe_init
from .rwkv6 import (rwkv6_channel_mix, rwkv6_init, rwkv6_init_state,
                    rwkv6_time_mix)


def _stack_trees(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _layer_windows(cfg: ModelConfig):
    """Per-layer is_global flags for the gemma3 local:global pattern."""
    if cfg.sliding_window and cfg.global_every:
        return jnp.array(
            [1 if (i % cfg.global_every == cfg.global_every - 1) else 0
             for i in range(cfg.n_layers)], jnp.int32)
    return jnp.ones((cfg.n_layers,), jnp.int32)


# ===========================================================================
# generic attention+ffn block
# ===========================================================================

def block_init(key, cfg: ModelConfig, cross: bool = False):
    ks = jax.random.split(key, 5)
    p = {"n1": norm_init(cfg), "attn": attn_init(ks[0], cfg),
         "n2": norm_init(cfg)}
    if cross:
        p["cross_attn"] = attn_init(ks[2], cfg)
        p["n_cross"] = norm_init(cfg)
    if cfg.moe_experts:
        p["moe"] = moe_init(ks[1], cfg)
    else:
        p["mlp"] = mlp_init(ks[1], cfg)
    return p


def block_forward(p, x, cfg: ModelConfig, *, causal=True, window=0,
                  enc_out=None, impl=None):
    """Returns (x, aux_loss).

    Attention/MLP outputs are constrained back to the sequence-sharded
    layout BEFORE the residual add, so the row-parallel matmul partial sums
    lower to a reduce-scatter rather than a full all-reduce (Megatron-style
    sequence parallelism; ~16x less collective traffic per boundary)."""
    h = attn_forward(p["attn"], apply_norm(p["n1"], x, cfg), cfg,
                     causal=causal, window=window, impl=impl)
    x = x + constrain(h, "btd")
    if enc_out is not None:
        h = attn_forward(p["cross_attn"], apply_norm(p["n_cross"], x, cfg),
                         cfg, causal=False, kv_x=enc_out, impl=impl)
        x = x + constrain(h, "btd")
    aux = jnp.zeros((), jnp.float32)
    y_in = apply_norm(p["n2"], x, cfg)
    if cfg.moe_experts:
        y, aux = moe_ffn(p["moe"], y_in, cfg)
    else:
        y = mlp(p["mlp"], y_in, cfg)
    return x + constrain(y, "btd"), aux


# ===========================================================================
# decoder-only stack (dense / moe / vlm / gemma3)
# ===========================================================================

def stack_init(key, cfg: ModelConfig):
    layers = [block_init(jax.random.fold_in(key, i), cfg)
              for i in range(cfg.n_layers)]
    return _stack_trees(layers)


def _windowed(cfg: ModelConfig, flag, attn_call):
    """Run `attn_call(window)` under the gemma3 local:global per-layer cond
    (window must be static for masking, so both paths live under lax.cond).
    Shared by all four cache-walking stacks below."""
    if cfg.sliding_window and cfg.global_every:
        return jax.lax.cond(flag > 0,
                            lambda: attn_call(0),
                            lambda: attn_call(cfg.sliding_window))
    return attn_call(cfg.sliding_window)


def _ffn_tail(p, x, cfg: ModelConfig):
    """Post-attention half of a block: norm -> (moe|mlp) -> residual."""
    y_in = apply_norm(p["n2"], x, cfg)
    if cfg.moe_experts:
        y, _ = moe_ffn(p["moe"], y_in, cfg)
    else:
        y = mlp(p["mlp"], y_in, cfg)
    return x + y


def stack_forward(params, x, cfg: ModelConfig, *, impl=None, remat=False):
    flags = _layer_windows(cfg)

    def body(carry, xs):
        x, aux = carry
        p, flag = xs
        # barrier: keep the remat-saved residual in bf16 (XLA otherwise
        # hoists the first fp32 convert of the recompute into the save);
        # grad_cast: keep the residual COTANGENT bf16 so the per-layer
        # sequence-parallel all-gather/all-reduce pair moves half the bytes
        x = grad_cast(optimization_barrier(x))
        x = constrain(x, "btd")
        if cfg.sliding_window and cfg.global_every:
            x, a = jax.lax.cond(
                flag > 0,
                lambda: block_forward(p, x, cfg, window=0, impl=impl),
                lambda: block_forward(p, x, cfg, window=cfg.sliding_window,
                                      impl=impl))
        else:
            x, a = block_forward(p, x, cfg, window=cfg.sliding_window,
                                 impl=impl)
        return (x, aux + a), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               (params, flags))
    return x, aux


def stack_prefill(params, x, cfg: ModelConfig, cache, *, impl=None):
    """cache: {"k": (L,B,S,Hkv,D), "v": ...}.  Prefill from position 0."""
    flags = _layer_windows(cfg)

    def body(x, xs):
        p, ck, cv, flag = xs
        x = constrain(x, "btd")
        h_in = apply_norm(p["n1"], x, cfg)
        h, ck, cv = _windowed(
            cfg, flag,
            lambda w: attn_prefill(p["attn"], h_in, cfg, ck, cv, window=w,
                                   impl=impl))
        return _ffn_tail(p, x + h, cfg), (ck, cv)

    x, (ck, cv) = jax.lax.scan(body, x,
                               (params, cache["k"], cache["v"], flags))
    return x, {"k": ck, "v": cv}


def stack_decode(params, x, cfg: ModelConfig, cache, lens, *, impl=None,
                 seq_parallel=False):
    flags = _layer_windows(cfg)

    def body(x, xs):
        p, ck, cv, flag = xs
        h_in = apply_norm(p["n1"], x, cfg)
        h, ck, cv = _windowed(
            cfg, flag,
            lambda w: attn_decode(p["attn"], h_in, cfg, ck, cv, lens,
                                  window=w, impl=impl,
                                  seq_parallel=seq_parallel))
        return _ffn_tail(p, x + h, cfg), (ck, cv)

    x, (ck, cv) = jax.lax.scan(body, x,
                               (params, cache["k"], cache["v"], flags))
    return x, {"k": ck, "v": cv}


def stack_prefill_paged(params, x, cfg: ModelConfig, cache, page_ids, *,
                        impl=None):
    """Paged prefill of ONE sequence (B=1), x: (1, S, D) with S a multiple
    of the page size.  cache: {"k_pages"/"v_pages": (L, P, page, Hkv, D),
    "block_table": (B, n_max)}; page_ids: (S // page,) pages owned by the
    sequence.  The block table itself is host-managed (serve/paged_cache.py)
    and passes through untouched."""
    flags = _layer_windows(cfg)

    def body(x, xs):
        p, kp, vp, flag = xs
        x = constrain(x, "btd")
        h_in = apply_norm(p["n1"], x, cfg)
        h, kp, vp = _windowed(
            cfg, flag,
            lambda w: attn_prefill_paged(p["attn"], h_in, cfg, kp, vp,
                                         page_ids, window=w, impl=impl))
        return _ffn_tail(p, x + h, cfg), (kp, vp)

    x, (kp, vp) = jax.lax.scan(
        body, x, (params, cache["k_pages"], cache["v_pages"], flags))
    return x, {"k_pages": kp, "v_pages": vp,
               "block_table": cache["block_table"]}


def stack_prefill_chunks_paged(params, x, cfg: ModelConfig, cache,
                               page_tables, offsets, true_lens, *,
                               q_lens=None, impl=None, tp_mesh=None):
    """Paged prefill of a RAGGED BATCH of mid-prompt chunks - K chunks of
    K different sequences at K different prompt positions, ONE pass
    through the stack: x: (K, S, D), row k at absolute positions
    offsets[k] + arange(S) and zero-padded past true_lens[k].
    page_tables: (K, n_max) per-row block-table rows - pages already
    holding K/V (cached prefix + earlier chunks) first, then the pages
    each chunk and decode will fill.  Two chunks of the SAME sequence may
    share a batch (ordered offsets): each layer scatters every row's K/V
    before its attention reads the pool, so the later chunk sees the
    earlier one exactly as if they had run back to back.  The block table
    itself is host-managed (serve/paged_cache.py) and passes through
    untouched."""
    flags = _layer_windows(cfg)

    def body(x, xs):
        p, kp, vp, flag = xs
        x = constrain(x, "btd")
        h_in = apply_norm(p["n1"], x, cfg)
        h, kp, vp = _windowed(
            cfg, flag,
            lambda w: attn_prefill_chunks_paged(p["attn"], h_in, cfg, kp,
                                                vp, page_tables, offsets,
                                                true_lens, q_lens=q_lens,
                                                window=w, impl=impl,
                                                tp_mesh=tp_mesh))
        return _ffn_tail(p, x + h, cfg), (kp, vp)

    x, (kp, vp) = jax.lax.scan(
        body, x, (params, cache["k_pages"], cache["v_pages"], flags))
    return x, {"k_pages": kp, "v_pages": vp,
               "block_table": cache["block_table"]}


def stack_prefill_chunk_paged(params, x, cfg: ModelConfig, cache, page_row,
                              offset, *, impl=None):
    """Paged prefill of ONE mid-prompt chunk of ONE sequence: the K=1
    special case of stack_prefill_chunks_paged (every position of x
    treated as real - the historical single-row contract).  x: (1, S, D)
    at absolute positions offset + arange(S); page_row: (n_max,)."""
    off = jnp.asarray(offset, jnp.int32).reshape(1)
    return stack_prefill_chunks_paged(
        params, x, cfg, cache, jnp.asarray(page_row, jnp.int32)[None], off,
        off + x.shape[1], impl=impl)


# the prefix-cache suffix is the final-chunk special case
stack_prefill_suffix_paged = stack_prefill_chunk_paged


def stack_decode_paged(params, x, cfg: ModelConfig, cache, lens, *,
                       impl=None, tp_mesh=None):
    """Batched single-token decode through the block table (all layers share
    one table; each layer owns its own page pool slab)."""
    flags = _layer_windows(cfg)
    bt = cache["block_table"]

    def body(x, xs):
        p, kp, vp, flag = xs
        h_in = apply_norm(p["n1"], x, cfg)
        h, kp, vp = _windowed(
            cfg, flag,
            lambda w: attn_decode_paged(p["attn"], h_in, cfg, kp, vp, bt,
                                        lens, window=w, impl=impl,
                                        tp_mesh=tp_mesh))
        return _ffn_tail(p, x + h, cfg), (kp, vp)

    x, (kp, vp) = jax.lax.scan(
        body, x, (params, cache["k_pages"], cache["v_pages"], flags))
    return x, {"k_pages": kp, "v_pages": vp, "block_table": bt}


# ===========================================================================
# hybrid stack (zamba2): mamba2 + shared attention block
# ===========================================================================

def hybrid_init(key, cfg: ModelConfig):
    layers = [mamba2_init(jax.random.fold_in(key, i), cfg)
              for i in range(cfg.n_layers)]
    shared = block_init(jax.random.fold_in(key, 10_000), cfg)
    return {"mamba": _stack_trees(layers), "shared": shared}


def n_shared_applications(cfg: ModelConfig) -> int:
    k = cfg.shared_attn_every
    return cfg.n_layers // k if k else 0


def hybrid_forward(params, x, cfg: ModelConfig, *, impl=None, remat=False):
    k = cfg.shared_attn_every
    shared = params["shared"]

    def body(x, xs):
        p, idx = xs
        x = grad_cast(optimization_barrier(x))
        x = constrain(x, "btd")
        x = x + mamba2_forward(p, x, cfg, impl=impl)
        if k:
            x = jax.lax.cond(
                (idx % k) == (k - 1),
                lambda x: block_forward(shared, x, cfg, impl=impl)[0],
                lambda x: x, x)
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, (params["mamba"],
                                  jnp.arange(cfg.n_layers)))
    return x, jnp.zeros((), jnp.float32)


def hybrid_init_cache(cfg: ModelConfig, batch: int, max_len: int):
    d_in = cfg.ssm_expand * cfg.d_model
    st = mamba2_init_state(cfg, batch)
    L = cfg.n_layers
    A = max(n_shared_applications(cfg), 1)
    dt = jnp.dtype(cfg.dtype)
    return {
        "conv": jnp.zeros((L,) + st["conv"].shape, st["conv"].dtype),
        "ssm": jnp.zeros((L,) + st["ssm"].shape, st["ssm"].dtype),
        "shared_k": jnp.zeros((A, batch, max_len, cfg.n_kv_heads,
                               cfg.head_dim), dt),
        "shared_v": jnp.zeros((A, batch, max_len, cfg.n_kv_heads,
                               cfg.head_dim), dt),
    }


def hybrid_prefill(params, x, cfg: ModelConfig, cache, *, impl=None):
    """Full-sequence hybrid prefill: chunked SSD scans fill the per-layer
    conv/SSM states; the shared attention block prefills its KV caches."""
    k = cfg.shared_attn_every
    shared = params["shared"]

    def shared_prefill(x, sk_all, sv_all, app_idx):
        sk = sk_all[app_idx]
        sv = sv_all[app_idx]
        h_in = apply_norm(shared["n1"], x, cfg)
        h, sk, sv = attn_prefill(shared["attn"], h_in, cfg, sk, sv,
                                 impl=impl)
        x = x + h
        y = mlp(shared["mlp"], apply_norm(shared["n2"], x, cfg), cfg)
        sk_all = jax.lax.dynamic_update_index_in_dim(sk_all, sk, app_idx, 0)
        sv_all = jax.lax.dynamic_update_index_in_dim(sv_all, sv, app_idx, 0)
        return x + y, sk_all, sv_all

    def body(carry, xs):
        x, sk_all, sv_all = carry
        p, idx = xs
        y, st = mamba2_prefill(p, x, cfg, impl=impl)
        x = x + y
        if k:
            x, sk_all, sv_all = jax.lax.cond(
                (idx % k) == (k - 1),
                lambda x, sk, sv: shared_prefill(x, sk, sv, idx // k),
                lambda x, sk, sv: (x, sk, sv),
                x, sk_all, sv_all)
        return (x, sk_all, sv_all), (st["conv"], st["ssm"])

    (x, sk, sv), (conv, ssm) = jax.lax.scan(
        body, (x, cache["shared_k"], cache["shared_v"]),
        (params["mamba"], jnp.arange(cfg.n_layers)))
    return x, {"conv": conv, "ssm": ssm, "shared_k": sk, "shared_v": sv}


def hybrid_decode(params, x, cfg: ModelConfig, cache, lens, *, impl=None,
                  seq_parallel=False):
    k = cfg.shared_attn_every
    shared = params["shared"]

    def shared_apply(x, sk_all, sv_all, app_idx):
        sk = sk_all[app_idx]
        sv = sv_all[app_idx]
        h_in = apply_norm(shared["n1"], x, cfg)
        h, sk, sv = attn_decode(shared["attn"], h_in, cfg, sk, sv, lens,
                                impl=impl, seq_parallel=seq_parallel)
        x = x + h
        y = mlp(shared["mlp"], apply_norm(shared["n2"], x, cfg), cfg)
        sk_all = jax.lax.dynamic_update_index_in_dim(sk_all, sk, app_idx, 0)
        sv_all = jax.lax.dynamic_update_index_in_dim(sv_all, sv, app_idx, 0)
        return x + y, sk_all, sv_all

    def body(carry, xs):
        x, sk_all, sv_all = carry
        p, conv, ssm, idx = xs
        y, new_state = mamba2_decode(p, x, cfg, {"conv": conv, "ssm": ssm})
        x = x + y
        if k:
            x, sk_all, sv_all = jax.lax.cond(
                (idx % k) == (k - 1),
                lambda x, sk, sv: shared_apply(x, sk, sv, idx // k),
                lambda x, sk, sv: (x, sk, sv),
                x, sk_all, sv_all)
        return (x, sk_all, sv_all), (new_state["conv"], new_state["ssm"])

    (x, sk, sv), (conv, ssm) = jax.lax.scan(
        body, (x, cache["shared_k"], cache["shared_v"]),
        (params["mamba"], cache["conv"], cache["ssm"],
         jnp.arange(cfg.n_layers)))
    return x, {"conv": conv, "ssm": ssm, "shared_k": sk, "shared_v": sv}


# ===========================================================================
# rwkv stack
# ===========================================================================

def rwkv_init(key, cfg: ModelConfig):
    layers = []
    for i in range(cfg.n_layers):
        ki = jax.random.fold_in(key, i)
        layers.append({"n1": norm_init(cfg), "n2": norm_init(cfg),
                       "mix": rwkv6_init(ki, cfg)})
    return _stack_trees(layers)


def rwkv_forward(params, x, cfg: ModelConfig, *, impl=None, remat=False):
    def body(x, p):
        x = grad_cast(optimization_barrier(x))
        x = constrain(x, "btd")
        h, _ = rwkv6_time_mix(p["mix"], apply_norm(p["n1"], x, cfg), cfg,
                              impl=impl)
        x = x + h
        h, _ = rwkv6_channel_mix(p["mix"], apply_norm(p["n2"], x, cfg), cfg)
        return x + h, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params)
    return x, jnp.zeros((), jnp.float32)


def rwkv_init_cache(cfg: ModelConfig, batch: int, max_len: int):
    st = rwkv6_init_state(cfg, batch)
    L = cfg.n_layers
    return {k: jnp.zeros((L,) + v.shape, v.dtype) for k, v in st.items()}


def rwkv_prefill(params, x, cfg: ModelConfig, cache, *, impl=None):
    """Full-sequence RWKV prefill via the state-returning chunked WKV scan."""
    def body(x, p):
        xin = apply_norm(p["n1"], x, cfg)
        h, (tm_last, wkv) = rwkv6_time_mix(p["mix"], xin, cfg, impl=impl,
                                           return_state=True)
        x = x + h
        xin = apply_norm(p["n2"], x, cfg)
        h, cm_last = rwkv6_channel_mix(p["mix"], xin, cfg)
        return x + h, (wkv, tm_last, cm_last)

    x, (wkv, tm, cm) = jax.lax.scan(body, x, params)
    return x, {"wkv": wkv, "tm_prev": tm, "cm_prev": cm}


def rwkv_decode(params, x, cfg: ModelConfig, cache, lens, *, impl=None,
                seq_parallel=False):
    def body(x, xs):
        p, wkv, tm_prev, cm_prev = xs
        xin = apply_norm(p["n1"], x, cfg)
        h, (tm_last, new_wkv) = rwkv6_time_mix(
            p["mix"], xin, cfg, x_prev=tm_prev, wkv_state=wkv, impl=impl)
        x = x + h
        xin = apply_norm(p["n2"], x, cfg)
        h, cm_last = rwkv6_channel_mix(p["mix"], xin, cfg, x_prev=cm_prev)
        return x + h, (new_wkv, tm_last, cm_last)

    x, (wkv, tm, cm) = jax.lax.scan(
        body, x, (params, cache["wkv"], cache["tm_prev"], cache["cm_prev"]))
    return x, {"wkv": wkv, "tm_prev": tm, "cm_prev": cm}


# ===========================================================================
# encoder-decoder (whisper)
# ===========================================================================

def encdec_init(key, cfg: ModelConfig):
    enc = [block_init(jax.random.fold_in(key, i), cfg)
           for i in range(cfg.encoder_layers)]
    dec = [block_init(jax.random.fold_in(key, 1000 + i), cfg, cross=True)
           for i in range(cfg.n_layers)]
    return {"encoder": _stack_trees(enc), "decoder": _stack_trees(dec),
            "enc_norm": norm_init(cfg)}


def encode(params, x_enc, cfg: ModelConfig, *, impl=None):
    def body(x, p):
        x, _ = block_forward(p, x, cfg, causal=False, impl=impl)
        return x, None
    x, _ = jax.lax.scan(body, x_enc, params["encoder"])
    return apply_norm(params["enc_norm"], x, cfg)


def encdec_forward(params, x_enc, x_dec, cfg: ModelConfig, *, impl=None,
                   remat=False):
    enc_out = encode(params, x_enc, cfg, impl=impl)

    def body(x, p):
        x, _ = block_forward(p, x, cfg, causal=True, enc_out=enc_out,
                             impl=impl)
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x_dec, params["decoder"])
    return x, jnp.zeros((), jnp.float32)


def encdec_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                      enc_len: int):
    dt = jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    mk = lambda s: jnp.zeros((L, batch, s, cfg.n_kv_heads, cfg.head_dim), dt)
    return {"self_k": mk(max_len), "self_v": mk(max_len),
            "cross_k": mk(enc_len), "cross_v": mk(enc_len)}


def encdec_prefill(params, x_enc, x_dec, cfg: ModelConfig, cache, *,
                   impl=None):
    """Full-sequence decoder prefill: fills self-attn and cross-attn caches
    in one pass (no per-token scan)."""
    enc_out = encode(params, x_enc, cfg, impl=impl)
    from .layers import dense

    def body(x, xs):
        p, sk, sv, ck, cv = xs
        h_in = apply_norm(p["n1"], x, cfg)
        h, sk, sv = attn_prefill(p["attn"], h_in, cfg, sk, sv, impl=impl)
        x = x + h
        # cross-attention: fill cross cache from encoder output
        B, Se, _ = enc_out.shape
        ca = p["cross_attn"]
        ckv = dense(ca["wk"], enc_out).reshape(B, Se, cfg.n_kv_heads,
                                               cfg.head_dim)
        cvv = dense(ca["wv"], enc_out).reshape(B, Se, cfg.n_kv_heads,
                                               cfg.head_dim)
        ck = jax.lax.dynamic_update_slice(ck, ckv.astype(ck.dtype),
                                          (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, cvv.astype(cv.dtype),
                                          (0, 0, 0, 0))
        h = attn_forward(p["cross_attn"], apply_norm(p["n_cross"], x, cfg),
                         cfg, causal=False, kv_x=enc_out, impl=impl)
        x = x + h
        y = mlp(p["mlp"], apply_norm(p["n2"], x, cfg), cfg)
        return x + y, (sk, sv, ck, cv)

    x, (sk, sv, ck, cv) = jax.lax.scan(
        body, x_dec, (params["decoder"], cache["self_k"], cache["self_v"],
                      cache["cross_k"], cache["cross_v"]))
    return x, {"self_k": sk, "self_v": sv, "cross_k": ck, "cross_v": cv}


def encdec_decode(params, x, cfg: ModelConfig, cache, lens, enc_lens, *,
                  impl=None, seq_parallel=False):
    """One decoder token; cross K/V already in the cache."""
    def body(x, xs):
        p, sk, sv, ck, cv = xs
        h_in = apply_norm(p["n1"], x, cfg)
        h, sk, sv = attn_decode(p["attn"], h_in, cfg, sk, sv, lens,
                                impl=impl, seq_parallel=seq_parallel)
        x = x + h
        h_in = apply_norm(p["n_cross"], x, cfg)
        h, _, _ = attn_decode(p["cross_attn"], h_in, cfg, ck, cv, enc_lens,
                              impl=impl, cross=True,
                              seq_parallel=seq_parallel)
        x = x + h
        y = mlp(p["mlp"], apply_norm(p["n2"], x, cfg), cfg)
        return x + y, (sk, sv)

    x, (sk, sv) = jax.lax.scan(
        body, x, (params["decoder"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    return x, {"self_k": sk, "self_v": sv,
               "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
