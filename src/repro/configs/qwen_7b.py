"""Qwen2-7B-class GQA: the paper's GQA evaluation model.  [arXiv:2309.16609]"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen-7b", family="dense",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
        d_ff=18944, vocab_size=152064, head_dim=128,
        norm="rmsnorm", act="silu", rope_theta=1_000_000.0,
        tie_embeddings=False,
    )


def get_smoke_config() -> ModelConfig:
    return get_config().replace(
        name="qwen-7b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256)
