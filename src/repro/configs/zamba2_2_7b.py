"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (MHA kv=32) d_ff=10240
vocab=32000, ssm_state=64.  Mamba2 backbone with a SHARED-WEIGHT attention
block applied every 6th layer (weight sharing across applications).
[arXiv:2411.15242; hf]
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab_size=32000, head_dim=80,
        norm="rmsnorm", act="gelu",
        ssm_state=64, ssm_expand=2, ssm_conv=4,
        shared_attn_every=6,
        tie_embeddings=True,
    )


def get_smoke_config() -> ModelConfig:
    return get_config().replace(
        name="zamba2-2.7b-smoke", n_layers=6, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
        ssm_state=16, shared_attn_every=3)
