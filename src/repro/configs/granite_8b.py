"""granite-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.

Llama-architecture code model.  [arXiv:2405.04324; hf]
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b", family="dense",
        n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=49152, head_dim=128,
        norm="rmsnorm", act="silu", rope_theta=10_000.0,
        tie_embeddings=False,
    )


def get_smoke_config() -> ModelConfig:
    return get_config().replace(
        name="granite-8b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256)
