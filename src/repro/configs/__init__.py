"""Config registry: --arch <id> resolution for every assigned architecture."""
from __future__ import annotations

import importlib
from typing import Dict

from .base import SHAPES, MeshConfig, ModelConfig, ServeConfig, ShapeSpec, TrainConfig

# assigned architectures (10) + the paper's own evaluation models (2)
ARCH_MODULES: Dict[str, str] = {
    "llava-next-34b": "llava_next_34b",
    "granite-3-2b": "granite_3_2b",
    "gemma3-4b": "gemma3_4b",
    "granite-8b": "granite_8b",
    "olmo-1b": "olmo_1b",
    "whisper-base": "whisper_base",
    "zamba2-2.7b": "zamba2_2_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "opt-6.7b": "opt_6_7b",
    "qwen-7b": "qwen_7b",
}

ASSIGNED_ARCHS = [
    "llava-next-34b", "granite-3-2b", "gemma3-4b", "granite-8b", "olmo-1b",
    "whisper-base", "zamba2-2.7b", "qwen3-moe-235b-a22b", "olmoe-1b-7b",
    "rwkv6-1.6b",
]


def _module(arch: str):
    try:
        return importlib.import_module(f".{ARCH_MODULES[arch]}", __package__)
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; one of {sorted(ARCH_MODULES)}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).get_config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).get_smoke_config()


__all__ = [
    "ARCH_MODULES", "ASSIGNED_ARCHS", "SHAPES", "MeshConfig", "ModelConfig",
    "ServeConfig", "ShapeSpec", "TrainConfig", "get_config", "get_smoke_config",
]
