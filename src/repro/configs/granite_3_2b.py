"""granite-3-2b [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.

GQA llama-family dense decoder.  [hf:ibm-granite/granite-3.0-2b-base; hf]
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b", family="dense",
        n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8,
        d_ff=8192, vocab_size=49155, head_dim=64,
        norm="rmsnorm", act="silu", rope_theta=10_000.0,
        tie_embeddings=True,
    )


def get_smoke_config() -> ModelConfig:
    return get_config().replace(
        name="granite-3-2b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256)
