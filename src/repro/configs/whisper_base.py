"""whisper-base [audio]: 6L d_model=512 8H (MHA kv=8) d_ff=2048 vocab=51865.

Encoder-decoder; the conv audio frontend is a STUB (input_specs() provides
precomputed frame embeddings (B, S_enc, d_model)).  [arXiv:2212.04356]
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="audio",
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
        d_ff=2048, vocab_size=51865, head_dim=64,
        norm="layernorm", act="gelu", use_rope=False,
        encoder_layers=6, frontend="audio",
        tie_embeddings=True,
    )


def get_smoke_config() -> ModelConfig:
    return get_config().replace(
        name="whisper-base-smoke", n_layers=2, encoder_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256)
