"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (MHA kv=16) d_ff=1024 vocab=50304,
64 experts top-8.  [arXiv:2409.02060; hf]
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab_size=50304, head_dim=128,
        norm="rmsnorm", act="silu", rope_theta=10_000.0,
        moe_experts=64, moe_top_k=8,
        tie_embeddings=False,
    )


def get_smoke_config() -> ModelConfig:
    return get_config().replace(
        name="olmoe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=32, vocab_size=256,
        moe_experts=8, moe_top_k=2)
