"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.

5:1 local:global attention (every 6th layer global, rest sliding-window),
128k context, QK-norm.  [hf:google/gemma-3-1b-pt; unverified]
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b", family="dense",
        n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
        d_ff=10240, vocab_size=262144, head_dim=256,
        norm="rmsnorm", act="gelu", rope_theta=1_000_000.0,
        qk_norm=True, sliding_window=1024, global_every=6,
        tie_embeddings=True, max_seq=131_072,
    )


def get_smoke_config() -> ModelConfig:
    return get_config().replace(
        name="gemma3-4b-smoke", n_layers=6, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        sliding_window=32, global_every=3)
