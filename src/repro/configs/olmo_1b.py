"""olmo-1b [dense]: 16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304.

Non-parametric LayerNorm (no learned scale/bias).  [arXiv:2402.00838; hf]
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b", family="dense",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab_size=50304, head_dim=128,
        norm="nonparam_ln", act="silu", rope_theta=10_000.0,
        tie_embeddings=True,
    )


def get_smoke_config() -> ModelConfig:
    return get_config().replace(
        name="olmo-1b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256)
