"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, 128 experts top-8, QK-norm.  [hf:Qwen/Qwen3-30B-A3B; hf]
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
        d_ff=1536, vocab_size=151936, head_dim=128,
        norm="rmsnorm", act="silu", rope_theta=1_000_000.0,
        qk_norm=True, moe_experts=128, moe_top_k=8,
        tie_embeddings=False,
    )


def get_smoke_config() -> ModelConfig:
    return get_config().replace(
        name="qwen3-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=32, vocab_size=256,
        moe_experts=8, moe_top_k=2)
