"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

AnyRes tiling vision frontend is a STUB: input_specs() provides precomputed
patch embeddings (B, frontend_tokens, d_model) prepended to the text tokens.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=20480, vocab_size=64000, head_dim=128,
        norm="rmsnorm", act="silu", rope_theta=5_000_000.0,
        tie_embeddings=False,
        frontend="vision", frontend_tokens=576,
    )


def get_smoke_config() -> ModelConfig:
    return get_config().replace(
        name="llava-next-34b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        frontend_tokens=8)
