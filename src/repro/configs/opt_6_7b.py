"""OPT-6.7B: the paper's MHA evaluation model.  [arXiv:2205.01068]"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="opt-6.7b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=16384, vocab_size=50272, head_dim=128,
        norm="layernorm", act="gelu", use_rope=False,
        tie_embeddings=True,
    )


def get_smoke_config() -> ModelConfig:
    return get_config().replace(
        name="opt-6.7b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256)
