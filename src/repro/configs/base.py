"""Config system: model / mesh / train / serve configuration dataclasses.

Every assigned architecture provides `get_config()` returning the exact
published configuration, and `get_smoke_config()` returning a reduced config
of the same family for CPU smoke tests.  The full configs are exercised only
through the dry-run (ShapeDtypeStruct lowering, no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # --- numerics / layers -------------------------------------------------
    norm: str = "rmsnorm"       # rmsnorm | layernorm | nonparam_ln
    act: str = "silu"           # silu | gelu
    dtype: str = "bfloat16"
    tie_embeddings: bool = True
    qk_norm: bool = False       # gemma3-style per-head RMS norm of q and k

    # --- position / attention pattern --------------------------------------
    rope_theta: float = 10_000.0
    rope_scaling: float = 1.0   # dynamic RoPE scaling (paper's long-seq trick)
    sliding_window: int = 0     # 0 = full attention
    global_every: int = 0       # gemma3: every Nth layer is global, rest local
    attn_logit_softcap: float = 0.0
    use_rope: bool = True       # olmo/whisper use learned/sinusoidal instead

    # --- MoE ----------------------------------------------------------------
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba2) --------------------------------------------------------
    ssm_state: int = 0
    ssm_heads: int = 0          # 0 -> derived
    ssm_expand: int = 2
    ssm_conv: int = 4

    # --- hybrid (zamba2) ------------------------------------------------------
    shared_attn_every: int = 0  # one shared-weight attn block every k ssm blocks

    # --- RWKV ------------------------------------------------------------------
    rwkv: bool = False

    # --- encoder-decoder (whisper) ----------------------------------------------
    encoder_layers: int = 0     # > 0 => enc-dec

    # --- modality frontend stubs ---------------------------------------------
    frontend: str = ""          # "" | "vision" | "audio"
    frontend_tokens: int = 0    # vision: patch embeddings prepended

    max_seq: int = 524_288

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, \
            f"{self.name}: n_heads must be divisible by n_kv_heads"

    # ---- derived -----------------------------------------------------------
    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.rwkv or (self.family == "ssm" and not self.rwkv)

    @property
    def subquadratic(self) -> bool:
        """True if decode-time state does not grow O(seq) with full attention
        (SSM / hybrid / linear attention) - gates the long_500k shape."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks), for 6ND math."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        n_ffn_mats = 3 if self.act == "silu" else 2
        if self.moe_experts:
            ffn = n_ffn_mats * d * f * self.moe_experts + d * self.moe_experts
        else:
            ffn = n_ffn_mats * d * f
        if self.rwkv:
            per_layer = d * d * 4 + d * f * 2 + 10 * d
        elif self.family in ("ssm", "hybrid"):
            # Mamba2 block: in_proj (z, x, B, C, dt), depthwise conv, out_proj.
            # zamba2-style hybrids put the MLP only in the shared attn block.
            d_in = self.ssm_expand * d
            ssm = d * (2 * d_in + 2 * self.ssm_state) + d_in * d
            per_layer = ssm
        else:
            per_layer = attn + ffn
        total = self.n_layers * per_layer + v * d * (1 if self.tie_embeddings else 2)
        if self.shared_attn_every:
            total += attn + n_ffn_mats * d * f
        if self.encoder_layers:
            total += self.encoder_layers * (attn + ffn) + attn * self.n_layers
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of the experts)."""
        if not self.moe_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        n_ffn_mats = 3 if self.act == "silu" else 2
        dense_ffn_all = self.n_layers * n_ffn_mats * d * f * self.moe_experts
        dense_ffn_active = self.n_layers * n_ffn_mats * d * f * self.moe_top_k
        return int(self.param_count() - dense_ffn_all + dense_ffn_active)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class MeshConfig:
    """Production mesh description (see launch/mesh.py)."""
    multi_pod: bool = False

    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 256
    seq_len: int = 4096
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    remat: str = "none"         # none | full | dots
    grad_compression: str = ""  # "" | int8
    seed: int = 0
    log_every: int = 10


def pages_for_tokens(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold n_tokens (ceil-div, >= 1).  THE page math -
    ServeConfig, the model cache init, the allocator and the capacity
    helpers all route through here."""
    if page_size < 1:
        raise ValueError(f"page_size must be >= 1, got {page_size}")
    return max(1, -(-n_tokens // page_size))


def dense_equivalent_pages(batch: int, max_len: int, page_size: int) -> int:
    """Pool size matching dense capacity, plus the reserved null page 0."""
    return batch * pages_for_tokens(max_len, page_size) + 1


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 4096
    prefill_chunk: int = 512
    max_new_tokens: int = 64
    temperature: float = 0.0    # 0 = greedy
    seed: int = 0               # PRNG seed for temperature > 0 sampling
    # device-side sampling filters (serve/sampling.py), applied in the
    # standard order logits / temperature -> top-k -> top-p -> categorical;
    # both are no-ops at temperature 0 (greedy bypasses the filters)
    top_k: int = 0              # keep only the k highest logits (0 = off)
    top_p: float = 1.0          # nucleus filter mass (1.0 = off)
    # finishing a request before max_new_tokens: eos_id (engine-wide) and/or
    # per-request submit(..., stop_tokens=...) end generation the tick the
    # token is produced, freeing its pages immediately
    eos_id: Optional[int] = None

    # --- token-budget scheduler (serve/scheduler.py) ------------------------
    # chunked=True replaces monolithic admission-time prefill with
    # Sarathi-style chunked prefill mixed into decode ticks: every tick gets
    # `tick_token_budget` tokens of work; each decoding slot consumes 1 and
    # the remainder is filled with prompt chunks (multiples of
    # `prefill_chunk`), so decode latency stays flat while long prompts
    # stream in.  Paged mode only (chunks prefill through the offset-causal
    # block-table kernel, kernels/paged_prefill.py).
    chunked: bool = False
    tick_token_budget: int = 0  # tokens of work (decode + prefill) per tick
    admission_policy: str = "fifo"   # fifo | sjf (shortest prompt first)
    # cap on prefill chunks planned per tick (0 = budget-limited only).
    # Bounds the ragged chunk-batch width - and, at 1, pins every pack to
    # the K=1 kernel bucket, which makes replays bit-stable across
    # different schedules (the deterministic-replay mode the preemption
    # parity tests and --preempt-trace bench run in).
    max_chunks_per_tick: int = 0
    # batched=True (default) packs every prefill chunk the scheduler plans
    # for a tick into ONE ragged batched kernel launch (K rows bucketed to
    # a power of two to bound recompiles), samples final-chunk tokens
    # device-side, and folds all per-slot updates into vectorized masked
    # ops - a steady-state tick costs one prefill launch + one decode
    # launch + one device->host transfer regardless of traffic.  False
    # keeps the sequential one-launch-per-chunk path (the parity oracle).
    batched: bool = True

    # --- decode-priority budget shaping (serve/scheduler.py) ----------------
    # decode_priority=True caps the prefill share of every tick at
    # max_prefill_fraction * tick_token_budget AFTER decode slots have taken
    # their token each, so a burst of queued long prefills can never inflate
    # the per-tick work (and therefore the work-clock TBT of every in-flight
    # decode) up to the full budget: steady-state decode TBT is bounded by
    # n_decode + floor(max_prefill_fraction * budget) instead of budget.
    # Chunked mode only.
    decode_priority: bool = False
    max_prefill_fraction: float = 0.5   # of tick_token_budget, (0, 1]

    # --- preemption (serve/engine.py) ---------------------------------------
    # preemption=True lets admission SHED lower-priority load when the page
    # pool runs dry instead of merely backpressuring: a queued request that
    # outranks a running one (submit(priority=...), higher wins) may preempt
    # it - the victim's non-shared pages return to the pool (prefix-cache
    # pages survive via refcounts), the victim parks QUEUED->RESUMING, and
    # on re-admission the prefix cache re-matches whatever pages survived
    # while only the lost remainder is re-prefilled through the chunk path.
    # Victim order: lowest-priority first; PREFILLING (most recently
    # admitted first) before DECODING (longest-remaining first).  Requires
    # chunked=True (the resume path is the chunk path).  Equal-priority
    # requests never preempt each other, so all-default-priority traffic
    # behaves exactly like preemption=False.
    preemption: bool = False

    # --- SLO-driven priority aging (serve/scheduler.py) ---------------------
    # priority_aging=True lets queued (and preempted/parked) requests age
    # into higher ADMISSION priority: every priority_age_tokens of
    # work-clock age adds +1 effective priority, so a low-priority request
    # outranks a priority-P stream after at most (P + 1) *
    # priority_age_tokens tokens of engine work - a deterministic
    # starvation bound.  Aging affects queue ordering only; preemption
    # keeps using base priority (an aged request never evicts running
    # work, which rules out preempt/re-preempt cycles).
    priority_aging: bool = False
    priority_age_tokens: int = 256   # work tokens of age per +1 priority

    # --- self-speculative decoding (serve/engine.py + serve/drafting.py) ----
    # speculative=True drafts up to spec_k tokens per decoding request per
    # tick by prompt-lookup over the request's OWN token history (n-gram
    # match, no second model) and verifies the whole chain in one launch
    # through the batched chunk kernel: accepted tokens emit together, the
    # first mismatch emits the target model's own token instead, so every
    # verify launch nets >= 1 token and greedy outputs stay equivalent to
    # non-speculative decoding.  Rejected positions simply fall past the
    # new `lens` frontier - the causal mask hides them and later writes
    # overwrite them, so rollback costs nothing and page reservations are
    # untouched (admission already reserved the worst case).  Drafted
    # tokens consume tick budget like prefill tokens; the work clock
    # advances only for ACCEPTED tokens so TTFT/TBT stay comparable with
    # speculation on or off.  Requires chunked=True and batched=True (the
    # verify path is the batched chunk path).
    speculative: bool = False
    spec_k: int = 4             # max drafted tokens per request per tick
    spec_ngram: int = 3         # longest n-gram the drafter matches on

    # --- paged KV cache (serve/paged_cache.py) ------------------------------
    # paged=True stores K/V in a global page pool indexed through a block
    # table instead of one dense (max_batch, max_seq) strip per slot; only
    # attention families (dense / moe / vlm) support it.  max_seq must be a
    # multiple of page_size (enforced by ServeEngine).
    paged: bool = False
    page_size: int = 16         # tokens per page (TPU wants >= 128 in prod)
    num_pages: int = 0          # 0 = dense-equivalent capacity (+ null page)
    # soft capacity cap: the allocator exposes only this many pages to
    # admission while the DEVICE pool stays num_pages, so capacity pressure
    # (backpressure, preemption) can be dialed without changing any array
    # shape - no recompiles between a pressured run and a full-capacity
    # oracle, and both execute the very same compiled steps (which is what
    # keeps their greedy outputs bit-comparable).  0 = the whole pool.
    usable_pages: int = 0

    # --- prefix cache (serve/prefix_cache.py) -------------------------------
    # prefix_cache=True keeps finished requests' prompt pages in a radix
    # tree keyed by page-sized token blocks; new requests reuse the longest
    # cached prefix (refcounted, copy-on-write) and prefill only the
    # uncached suffix.  Paged mode only.
    prefix_cache: bool = False
    # keep at least this fraction of the pool free by LRU-evicting
    # unreferenced cached pages after completions (0 = evict only when an
    # admission would otherwise run out of pages)
    prefix_evict_watermark: float = 0.0

    # --- request deadlines (serve/scheduler.py + serve/engine.py) -----------
    # Default per-request deadline in WORK-CLOCK tokens (0 = no deadline).
    # A request whose work-clock age (engine work executed since its
    # submit) reaches its deadline before it finishes is expired with a
    # TIMEOUT status at the top of the next tick: its slot and pages are
    # freed (valid prefix pages publish into the prefix cache, exactly
    # like preemption) the same tick, so an expired request can never hang
    # the engine or strand capacity.  Per-request submit(deadline=...)
    # overrides this default; deadlines are deterministic because the work
    # clock is.
    default_deadline_tokens: int = 0

    # --- telemetry (serve/telemetry.py) -------------------------------------
    # The metrics registry is ALWAYS on - it is the typed backing store of
    # engine.stats() / scheduler.stats() and costs a handful of host-side
    # counter writes per tick.  telemetry=True additionally turns on the
    # SPAN TRACER: per-request lifecycle spans and per-tick engine/launch
    # spans in a bounded ring buffer (telemetry_spans records), exportable
    # as Chrome trace-event JSON via engine.export_trace() for Perfetto.
    # Tracing is host-side only: it adds zero jitted calls and zero
    # device->host syncs, and outputs stay bit-identical either way.
    telemetry: bool = False
    telemetry_spans: int = 65536

    # --- tensor parallelism (launch/mesh.py + kernels/ops.py) ---------------
    # tp_degree > 1 shards the engine across devices on the HEAD axis: the
    # KV page pool is head-sharded (one Hkv/tp slice per device), the paged
    # flash-decode and batched-chunk/verify kernels run under shard_map with
    # the block table replicated as scalar-prefetch state, and attention
    # outputs all-gather back to replicated before the output projection -
    # so every other op (projections, FFN/MoE, sampling) computes on
    # replicated values with the same float summation order as tp=1, which
    # is what keeps greedy outputs bit-identical to the single-device
    # engine.  Requires paged=True, chunked=True, batched=True (the
    # one-launch tick paths are the sharded paths), n_kv_heads divisible by
    # tp_degree (checked by ServeEngine against the model config), and at
    # least tp_degree JAX devices (use
    # XLA_FLAGS=--xla_force_host_platform_device_count=N on CPU).
    tp_degree: int = 1

    def validate(self) -> "ServeConfig":
        """Scheduler-level config validation (called by ServeEngine).

        Degenerate knob combinations fail HERE with a clear error instead
        of hanging the tick loop: a chunked engine whose budget cannot fit
        one decode sweep plus one prefill chunk would starve prefill
        forever (decode slots consume the whole budget every tick)."""
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, "
                             f"got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 = off), "
                             f"got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.admission_policy not in ("fifo", "sjf"):
            raise ValueError(f"admission_policy must be 'fifo' or 'sjf', "
                             f"got {self.admission_policy!r}")
        if self.chunked:
            if not self.paged:
                raise ValueError(
                    "chunked prefill scheduling requires paged=True (chunks "
                    "prefill through the block-table kernel)")
            if self.prefill_chunk < 1 or self.prefill_chunk % self.page_size:
                raise ValueError(
                    f"prefill_chunk ({self.prefill_chunk}) must be a "
                    f"positive multiple of page_size ({self.page_size}) so "
                    f"every chunk starts on a page boundary")
            if self.tick_token_budget < self.max_batch + self.prefill_chunk:
                raise ValueError(
                    f"tick_token_budget ({self.tick_token_budget}) must be "
                    f">= max_batch + prefill_chunk "
                    f"({self.max_batch} + {self.prefill_chunk}) or prefill "
                    f"can starve behind a full decode batch")
        if self.decode_priority:
            if not self.chunked:
                raise ValueError("decode_priority shaping requires "
                                 "chunked=True (it caps the per-tick "
                                 "prefill share)")
            if not 0.0 < self.max_prefill_fraction <= 1.0:
                raise ValueError(
                    f"max_prefill_fraction must be in (0, 1], got "
                    f"{self.max_prefill_fraction}")
            if int(self.max_prefill_fraction
                   * self.tick_token_budget) < self.prefill_chunk:
                raise ValueError(
                    f"max_prefill_fraction * tick_token_budget "
                    f"({self.max_prefill_fraction} * "
                    f"{self.tick_token_budget}) must fit at least one "
                    f"prefill_chunk ({self.prefill_chunk}) or prefill "
                    f"starves forever")
        if self.max_chunks_per_tick < 0:
            raise ValueError(f"max_chunks_per_tick must be >= 0, got "
                             f"{self.max_chunks_per_tick}")
        if self.speculative:
            if not self.chunked or not self.batched:
                raise ValueError(
                    "speculative decoding requires chunked=True and "
                    "batched=True (draft chains verify through the "
                    "batched chunk path)")
            if self.spec_k < 1:
                raise ValueError(f"spec_k must be >= 1, got {self.spec_k}")
            if self.spec_ngram < 1:
                raise ValueError(f"spec_ngram must be >= 1, "
                                 f"got {self.spec_ngram}")
        if self.default_deadline_tokens < 0:
            raise ValueError(
                f"default_deadline_tokens must be >= 0 (0 = no deadline), "
                f"got {self.default_deadline_tokens}")
        if self.telemetry_spans < 1:
            raise ValueError(f"telemetry_spans must be >= 1, "
                             f"got {self.telemetry_spans}")
        if self.preemption and not self.chunked:
            raise ValueError("preemption requires chunked=True (a preempted "
                             "request resumes through the chunked prefill "
                             "path)")
        if self.priority_aging and self.priority_age_tokens < 1:
            raise ValueError(
                f"priority_age_tokens must be >= 1 when priority_aging is "
                f"on, got {self.priority_age_tokens}")
        if self.tp_degree < 1:
            raise ValueError(f"tp_degree must be >= 1, got {self.tp_degree}")
        if self.tp_degree > 1 and not (self.paged and self.chunked
                                       and self.batched):
            raise ValueError(
                f"tp_degree={self.tp_degree} requires paged=True, "
                f"chunked=True and batched=True (tensor parallelism shards "
                f"the paged one-launch tick paths; got paged={self.paged}, "
                f"chunked={self.chunked}, batched={self.batched})")
        if self.usable_pages:
            if not self.paged:
                raise ValueError("usable_pages requires paged=True")
            if not 1 <= self.usable_pages <= self.pool_pages() - 1:
                raise ValueError(
                    f"usable_pages ({self.usable_pages}) must be in "
                    f"[1, {self.pool_pages() - 1}] (pool "
                    f"{self.pool_pages()} incl. the null page)")
        return self

    def pages_per_seq(self) -> int:
        return pages_for_tokens(self.max_seq, self.page_size)

    def pool_pages(self) -> int:
        """Actual pool size: configured, or dense-equivalent + null page."""
        return self.num_pages or dense_equivalent_pages(
            self.max_batch, self.max_seq, self.page_size)


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}
