"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attention-free) d_ff=7168 vocab=65536.

Finch: data-dependent decay WKV recurrence.  The paper's attention-fusion
technique is INAPPLICABLE (no QK^T/softmax/PV chain) - see DESIGN.md
S.Arch-applicability; the fusion principle is applied to the WKV kernel
instead.  [arXiv:2404.05892; unverified]
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=7168, vocab_size=65536, head_dim=64,
        norm="layernorm", act="silu", use_rope=False,
        rwkv=True,
        tie_embeddings=False,
    )


def get_smoke_config() -> ModelConfig:
    return get_config().replace(
        name="rwkv6-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256)
