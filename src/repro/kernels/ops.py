"""Public jit'd kernel wrappers: impl dispatch (pallas | ref) + custom VJP.

The forward is the paper's technique on TPU: the fused QK^T -> softmax -> PV
chain stays VMEM/VREG-resident inside one Pallas kernel (ref = chunked jnp
with identical math, used on CPU and in the dry-run).  The backward is a
memory-efficient chunked FlashAttention-2 backward (recompute-from-(q,k,v,
o,lse); no N^2 residuals), so training never materializes attention scores
either - "from buffers to registers" applied to both passes.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref

NEG_INF = ref.NEG_INF
LOG2E = 1.4426950408889634


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


# ===========================================================================
# flash_attention with custom (chunked) VJP
# ===========================================================================

def _fwd_impl(q, k, v, causal, window, softcap, scale, impl):
    if impl == "pallas":
        from . import flash_attention as fa
        return fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                      logit_softcap=softcap, scale=scale)
    o = ref.flash_attention(q, k, v, causal=causal, window=window,
                            logit_softcap=softcap, scale=scale)
    lse = _lse_ref(q, k, causal, window, softcap, scale)
    return o, lse


def _lse_ref(q, k, causal, window, softcap, scale, block_kv: int = 512):
    """Row log-sum-exp (natural log), chunked."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    nblk = -(-Skv // block_kv)
    pad = nblk * block_kv - Skv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    kb = jnp.moveaxis(kp.reshape(B, nblk, block_kv, Hkv, D), 1, 0)
    qf = q.reshape(B, Sq, Hkv, G, D)
    q_pos = jnp.arange(Sq)

    def body(carry, blk):
        m, l = carry
        kblk, j = blk
        k_pos = j * block_kv + jnp.arange(block_kv)
        s = ref.mixed_einsum("bqhgd,bkhd->bqhgk", qf, kblk) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        mask = k_pos[None, :] <= (Skv - 1)
        if causal or window > 0:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window > 0:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_blk = jnp.max(s, -1)
        m_new = jnp.maximum(m, m_blk)
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.where(mask[None, :, None, None, :],
                      jnp.exp2((s - m_safe[..., None]) * LOG2E), 0.0)
        alpha = jnp.where(m <= NEG_INF / 2, 0.0, jnp.exp2((m - m_new) * LOG2E))
        return (m_new, l * alpha + p.sum(-1)), None

    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    (m, l), _ = jax.lax.scan(body, (m0, l0), (kb, jnp.arange(nblk)))
    lse = m + jnp.log(jnp.maximum(l, 1e-20))
    return lse.reshape(B, Sq, Hq)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_attention(q, k, v, causal, window, softcap, scale, impl):
    o, _ = _fwd_impl(q, k, v, causal, window, softcap, scale, impl)
    return o


def _flash_fwd_rule(q, k, v, causal, window, softcap, scale, impl):
    o, lse = _fwd_impl(q, k, v, causal, window, softcap, scale, impl)
    return o, (q, k, v, o, lse)


def _flash_bwd_rule(causal, window, softcap, scale, impl, res, do,
                    block_kv: int = 512):
    """FA-2 backward: Pallas kernels on TPU (kernels/flash_backward.py);
    chunked jnp recompute-from-(q,k,v,o,lse) otherwise."""
    q, k, v, o, lse = res
    if impl == "pallas":
        from . import flash_backward as fb
        return fb.flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                                      window=window, logit_softcap=softcap,
                                      scale=scale)
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    sc = scale if scale is not None else 1.0 / math.sqrt(D)

    nblk = -(-Skv // block_kv)
    pad = nblk * block_kv - Skv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
    kb = jnp.moveaxis(kp.reshape(B, nblk, block_kv, Hkv, D), 1, 0)
    vb = jnp.moveaxis(vp.reshape(B, nblk, block_kv, Hkv, D), 1, 0)

    qb = q.reshape(B, Sq, Hkv, G, D)                       # stay bf16
    dob = do.astype(q.dtype).reshape(B, Sq, Hkv, G, D)
    of = o.reshape(B, Sq, Hkv, G, D)
    lsef = lse.astype(jnp.float32).reshape(B, Sq, Hkv, G)
    delta = jnp.sum(dob.astype(jnp.float32) * of.astype(jnp.float32), -1)
    q_pos = jnp.arange(Sq)

    def body(dq_acc, blk):
        kblk, vblk, j = blk
        k_pos = j * block_kv + jnp.arange(block_kv)
        s_raw = ref.mixed_einsum("bqhgd,bkhd->bqhgk", qb, kblk) * sc
        if softcap > 0.0:
            t = jnp.tanh(s_raw / softcap)
            s = softcap * t
        else:
            s = s_raw
        mask = k_pos[None, :] <= (Skv - 1)
        if causal or window > 0:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window > 0:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        p = jnp.exp2((s - lsef[..., None]) * LOG2E)
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        pb = p.astype(q.dtype)
        dv_j = ref.mixed_einsum("bqhgk,bqhgd->bkhd", pb, dob)
        dp = ref.mixed_einsum("bqhgd,bkhd->bqhgk", dob, vblk)
        ds = p * (dp - delta[..., None])
        if softcap > 0.0:
            ds = ds * (1.0 - t * t)
        dsb = ds.astype(q.dtype)
        dq_acc = dq_acc + ref.mixed_einsum("bqhgk,bkhd->bqhgd", dsb, kblk) * sc
        dk_j = ref.mixed_einsum("bqhgk,bqhgd->bkhd", dsb, qb) * sc
        return dq_acc, (dk_j, dv_j)

    dq0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)
    dq, (dk_blocks, dv_blocks) = jax.lax.scan(
        body, dq0, (kb, vb, jnp.arange(nblk)))
    dk = jnp.moveaxis(dk_blocks, 0, 1).reshape(B, nblk * block_kv, Hkv, D)
    dv = jnp.moveaxis(dv_blocks, 0, 1).reshape(B, nblk * block_kv, Hkv, D)
    del dk_blocks, dv_blocks
    dq = dq.reshape(B, Sq, Hq, D).astype(q.dtype)
    return dq, dk[:, :Skv].astype(k.dtype), dv[:, :Skv].astype(v.dtype)


_flash_attention.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    logit_softcap: float = 0.0,
                    scale: Optional[float] = None,
                    impl: Optional[str] = None) -> jax.Array:
    """Fused attention.  q: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D) (GQA allowed)."""
    impl = impl or default_impl()
    return _flash_attention(q, k, v, causal, window, logit_softcap, scale, impl)


# ===========================================================================
# decode
# ===========================================================================

def flash_decode(q, k_cache, v_cache, cache_len, *, window: int = 0,
                 logit_softcap: float = 0.0,
                 scale: Optional[float] = None,
                 impl: Optional[str] = None) -> jax.Array:
    impl = impl or default_impl()
    if impl == "pallas":
        from . import flash_decode as fd
        return fd.flash_decode(q, k_cache, v_cache, cache_len, window=window,
                               logit_softcap=logit_softcap, scale=scale)
    return ref.flash_decode(q, k_cache, v_cache, cache_len, window=window,
                            logit_softcap=logit_softcap, scale=scale)


def _tp_active(tp_mesh) -> bool:
    """True when a serve mesh actually shards the head ("model") axis."""
    return tp_mesh is not None and dict(tp_mesh.shape).get("model", 1) > 1


def _tp_head_sharded(fn, tp_mesh, n_pools: int, n_scalars: int):
    """shard_map a paged attention kernel on the HEAD axis of a serve mesh.

    The wrapped kernel sees q and n_pools page pools with their head axis
    (axis 2 of (B,S,H,D) / (P,ps,Hkv,D)) split across "model" plus
    n_scalars replicated block-table/length operands, computes its local
    head slice — per-head attention math never mixes heads, so the slice
    is the exact per-head result — and all-gathers outputs back to the
    full head axis.  With tiled=True the gather re-concatenates head
    blocks in device order, so the output is bit-identical to the
    unsharded kernel and everything downstream (output projection, FFN,
    sampling) runs replicated with the tp=1 float summation order.  The
    block table rides in replicated, the kernels' scalar-prefetch state.
    """
    from jax.sharding import PartitionSpec as P

    from .. import compat

    hs = P(None, None, "model", None)

    def local(*args):
        o = fn(*args)
        return jax.lax.all_gather(o, "model", axis=2, tiled=True)

    return compat.shard_map(
        local, tp_mesh,
        in_specs=(hs,) * (1 + n_pools) + (P(),) * n_scalars,
        out_specs=P())


def paged_flash_decode(q, k_pages, v_pages, block_table, cache_len, *,
                       window: int = 0, logit_softcap: float = 0.0,
                       scale: Optional[float] = None,
                       impl: Optional[str] = None,
                       tp_mesh=None) -> jax.Array:
    """Decode against a paged KV cache (vLLM-style block table).

    q: (B,1,Hq,D); k_pages/v_pages: (P, page_size, Hkv, D) global page pool;
    block_table: (B, n_max) int32 page ids; cache_len: (B,) valid lengths.
    The Pallas path walks the block table from SMEM inside the BlockSpec
    index maps, keeping the (m, l, acc) merge VMEM-resident; the ref path
    gathers pages and reuses the chunked dense decode.

    tp_mesh (a launch/mesh.py serve mesh with a "model" axis > 1) runs the
    kernel under shard_map with q and the pools head-sharded and the block
    table replicated; the output comes back replicated (bit-identical to
    tp=1 — see _tp_head_sharded)."""
    impl = impl or default_impl()
    if _tp_active(tp_mesh):
        def run(qc, kp, vp, bt, cl):
            return paged_flash_decode(qc, kp, vp, bt, cl, window=window,
                                      logit_softcap=logit_softcap,
                                      scale=scale, impl=impl)
        return _tp_head_sharded(run, tp_mesh, 2, 2)(
            q, k_pages, v_pages, block_table, cache_len)
    if impl == "pallas":
        from . import flash_decode as fd
        return fd.paged_flash_decode(q, k_pages, v_pages, block_table,
                                     cache_len, window=window,
                                     logit_softcap=logit_softcap,
                                     scale=scale)
    return ref.paged_flash_decode(q, k_pages, v_pages, block_table,
                                  cache_len, window=window,
                                  logit_softcap=logit_softcap, scale=scale)


def batched_paged_prefill_attention(q, k_pages, v_pages, page_tables,
                                    q_offsets, true_lens, q_lens=None, *,
                                    window: int = 0,
                                    logit_softcap: float = 0.0,
                                    scale: Optional[float] = None,
                                    impl: Optional[str] = None,
                                    tp_mesh=None) -> jax.Array:
    """Ragged batched mid-prompt chunk-prefill attention over partially
    filled block tables: K chunks of K different sequences in ONE call.

    q: (K,S,Hq,D) chunk queries; row k sits at absolute positions
    q_offsets[k] + arange(S) (its K/V already written into its pages),
    zero-padded past true_lens[k] - q_offsets[k]; page_tables: (K,n_max)
    per-row block-table rows; true_lens: (K,) per-row prefill cursors
    (dead padding rows carry 0 and an all-null table row, returning
    zero).  Each real row attends causally over every earlier position
    and the chunk itself.  The Pallas path walks every row's table from
    SMEM inside one grid (K, heads, kv-pages) launch with the (m, l,
    acc) merge VMEM-resident (kernels/paged_prefill.py); the ref path
    gathers pages per row and applies the offset causal mask.

    tp_mesh shards q and the pools on heads under shard_map with the
    per-row tables/offsets/cursors replicated (see paged_flash_decode)."""
    impl = impl or default_impl()
    if _tp_active(tp_mesh):
        if q_lens is None:
            def run(qc, kp, vp, pt, qo, tl):
                return batched_paged_prefill_attention(
                    qc, kp, vp, pt, qo, tl, None, window=window,
                    logit_softcap=logit_softcap, scale=scale, impl=impl)
            return _tp_head_sharded(run, tp_mesh, 2, 3)(
                q, k_pages, v_pages, page_tables, q_offsets, true_lens)

        def run(qc, kp, vp, pt, qo, tl, ql):
            return batched_paged_prefill_attention(
                qc, kp, vp, pt, qo, tl, ql, window=window,
                logit_softcap=logit_softcap, scale=scale, impl=impl)
        return _tp_head_sharded(run, tp_mesh, 2, 4)(
            q, k_pages, v_pages, page_tables, q_offsets, true_lens, q_lens)
    if impl == "pallas":
        from . import paged_prefill as pp
        return pp.batched_paged_prefill_attention(
            q, k_pages, v_pages, page_tables, q_offsets, true_lens, q_lens,
            window=window, logit_softcap=logit_softcap, scale=scale)
    return ref.batched_paged_prefill_attention(
        q, k_pages, v_pages, page_tables, q_offsets, true_lens, q_lens,
        window=window, logit_softcap=logit_softcap, scale=scale)


def paged_prefill_attention(q, k_pages, v_pages, page_row, q_offset, *,
                            window: int = 0, logit_softcap: float = 0.0,
                            scale: Optional[float] = None,
                            impl: Optional[str] = None) -> jax.Array:
    """Mid-prompt chunk-prefill attention over a partially filled block
    table: the K=1 special case of batched_paged_prefill_attention.

    q: (1,S,Hq,D) chunk queries at absolute positions q_offset + arange(S)
    (chunk K/V already written into its pages) - the uncached suffix after
    a prefix-cache hit, or any chunk of a token-budget scheduled prefill;
    page_row: (n_max,) the sequence's block-table row.  Each row attends
    causally over every earlier position and the chunk itself."""
    impl = impl or default_impl()
    if impl == "pallas":
        from . import paged_prefill as pp
        return pp.paged_prefill_attention(q, k_pages, v_pages, page_row,
                                          q_offset, window=window,
                                          logit_softcap=logit_softcap,
                                          scale=scale)
    return ref.paged_prefill_attention(q, k_pages, v_pages, page_row,
                                       q_offset, window=window,
                                       logit_softcap=logit_softcap,
                                       scale=scale)


def decode_attention_naive(q, k_cache, v_cache, cache_len, *,
                           logit_softcap: float = 0.0,
                           scale: Optional[float] = None) -> jax.Array:
    """Unchunked decode attention for SPMD sequence-parallel KV caches.

    Deliberately written as plain einsum + reductions over the cache's seq
    axis: when the cache is sharded on seq, XLA's SPMD partitioner turns the
    max / sum reductions into partial reductions + small all-reduces - the
    paper's partial-softmax tier merge, synthesized across chips.  (The
    lax.scan-chunked path cannot be partitioned this way.)
    """
    B, _, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    sc = scale if scale is not None else 1.0 / math.sqrt(D)
    cache_len = jnp.asarray(cache_len)
    if cache_len.ndim == 0:
        cache_len = jnp.full((B,), cache_len)
    qf = (q.astype(jnp.float32) * sc).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32))
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    mask = jnp.arange(S)[None, :] < cache_len[:, None]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, -1, keepdims=True)
    p = jnp.where(mask[:, None, None, :], jnp.exp2((s - m) * LOG2E), 0.0)
    l = jnp.sum(p, -1, keepdims=True)
    o = jnp.einsum("bhgk,bkhd->bhgd", p / jnp.maximum(l, 1e-20),
                   v_cache.astype(jnp.float32))
    return o.reshape(B, 1, Hq, D).astype(q.dtype)


def seq_parallel_decode(q, k_cache_local, v_cache_local, cache_len, *,
                        axis: str = "data",
                        scale: Optional[float] = None) -> jax.Array:
    """Sequence-parallel decode INSIDE shard_map: each device holds a slice
    of the KV cache along seq; compute local partial (m, l, o), all-gather
    the tiny partials, merge with the log-sum-exp combine.

    This is the paper's tier-merge applied across chips: partials flow
    "register-to-register" (ICI) instead of re-materializing the cache.
    q: (B,1,Hq,D) replicated; caches: (B, S_local, Hkv, D) local shard.
    """
    B, _, Hq, D = q.shape
    S_local = k_cache_local.shape[1]
    G = Hq // k_cache_local.shape[2]
    idx = jax.lax.axis_index(axis)
    shard_start = idx * S_local
    local_len = jnp.clip(cache_len - shard_start, 0, S_local)

    m, l, o = _decode_partials(q, k_cache_local, v_cache_local, local_len,
                               scale=scale)
    # gather tiny (m, l, o) partials across the sequence shards
    m_all = jax.lax.all_gather(m, axis)          # (P, B, Hkv, G)
    l_all = jax.lax.all_gather(l, axis)
    o_all = jax.lax.all_gather(o, axis)          # (P, B, Hkv, G, D)
    m_c, l_c, o_c = ref.combine_partial_softmax(m_all, l_all, o_all)
    o_final = o_c / jnp.maximum(l_c, 1e-20)[..., None]
    return o_final.reshape(B, 1, Hq, D).astype(q.dtype)


def _decode_partials(q, kc, vc, valid_len, *, scale=None, block_kv: int = 1024):
    B, _, Hq, D = q.shape
    S, Hkv = kc.shape[1], kc.shape[2]
    G = Hq // Hkv
    sc = scale if scale is not None else 1.0 / math.sqrt(D)
    valid_len = jnp.asarray(valid_len)
    if valid_len.ndim == 0:
        valid_len = jnp.full((B,), valid_len)
    nblk = -(-S // block_kv)
    pad = nblk * block_kv - S
    kp = jnp.pad(kc, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else kc
    vp = jnp.pad(vc, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else vc
    kb = jnp.moveaxis(kp.reshape(B, nblk, block_kv, Hkv, D), 1, 0)
    vb = jnp.moveaxis(vp.reshape(B, nblk, block_kv, Hkv, D), 1, 0)
    qf = (q.astype(jnp.float32) * sc).reshape(B, Hkv, G, D)

    def body(carry, blk):
        m, l, o = carry
        kblk, vblk, j = blk
        pos = j * block_kv + jnp.arange(block_kv)
        s = jnp.einsum("bhgd,bkhd->bhgk", qf, kblk.astype(jnp.float32))
        mask = pos[None, :] < valid_len[:, None]
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, -1))
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.where(mask[:, None, None, :],
                      jnp.exp2((s - m_safe[..., None]) * LOG2E), 0.0)
        alpha = jnp.where(m <= NEG_INF / 2, 0.0, jnp.exp2((m - m_new) * LOG2E))
        l = l * alpha + p.sum(-1)
        o = o * alpha[..., None] + jnp.einsum(
            "bhgk,bkhd->bhgd", p, vblk.astype(jnp.float32))
        return (m_new, l, o), None

    # varying-zero seed: under shard_map the scan carry must carry the same
    # "varying manual axes" type as the body outputs (which depend on the
    # sharded cache); outside shard_map this is +0.0
    vzero = jnp.sum(kc[:, :0].astype(jnp.float32))
    m0 = jnp.full((B, Hkv, G), NEG_INF, jnp.float32) + vzero
    l0 = jnp.zeros((B, Hkv, G), jnp.float32) + vzero
    o0 = jnp.zeros((B, Hkv, G, D), jnp.float32) + vzero
    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), (kb, vb, jnp.arange(nblk)))
    return m, l, o


# ===========================================================================
# SSM / RWKV
# ===========================================================================

def mamba2_scan(x, dt, A, Bm, Cm, *, impl: Optional[str] = None):
    impl = impl or default_impl()
    if impl == "pallas":
        from . import mamba2_scan as mk
        return mk.mamba2_scan(x, dt, A, Bm, Cm)
    if impl == "naive":
        return ref.mamba2_scan(x, dt, A, Bm, Cm)
    return ref.mamba2_scan_chunked(x, dt, A, Bm, Cm)


mamba2_step = ref.mamba2_step


def rwkv6_scan(r, k, v, w, u, *, impl: Optional[str] = None):
    impl = impl or default_impl()
    if impl == "pallas":
        from . import rwkv6_scan as rk
        return rk.rwkv6_scan(r, k, v, w, u)
    if impl == "naive":
        return ref.rwkv6_scan(r, k, v, w, u)
    return ref.rwkv6_scan_chunked(r, k, v, w, u)


rwkv6_step = ref.rwkv6_step
