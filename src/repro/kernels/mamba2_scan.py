"""Pallas TPU chunked Mamba2 (SSD) scan.

The paper's fusion principle applied to an attention-free chain: within a
time chunk the decay / inject / output stages run matrix-form on the MXU
(CB^T masked by the decay kernel), and the inter-chunk state h lives in VMEM
scratch across the sequential grid dimension - no HBM round-trip per chunk.

All decay factors are exp of non-positive numbers (<= 1), so the chunked
form is numerically stable in fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, h_ref, *,
                chunk: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[pl.program_id(1)]                       # scalar decay rate
    x = x_ref[0, :, 0].astype(jnp.float32)            # (T, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)          # (T,) -- wait, see spec
    Bm = b_ref[0].astype(jnp.float32)                 # (T, N)
    Cm = c_ref[0].astype(jnp.float32)                 # (T, N)

    log_a = -dt * A                                   # (T,) <= 0
    csum = jnp.cumsum(log_a)                          # inclusive

    # intra-chunk: y[t] = sum_{s<=t} exp(csum[t]-csum[s]) * dt[s] (C_t.B_s) x[s]
    diff = csum[:, None] - csum[None, :]              # (T, T)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    M = jnp.where(tri, jnp.exp(diff), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (T, T)
    xw = x * dt[:, None]                              # (T, P)
    y = jax.lax.dot_general(CB * M, xw, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (T, P)

    # carry-in: y[t] += exp(csum[t]) * C_t . h_in
    h_in = h_ref[...]                                 # (P, N)
    y_carry = jax.lax.dot_general(Cm, h_in, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (T,P)
    y = y + jnp.exp(csum)[:, None] * y_carry

    # state update: h_out = exp(csum[-1]) h_in + sum_s exp(csum[-1]-csum[s])
    #                                            dt_s x_s (outer) B_s
    w_out = jnp.exp(csum[-1] - csum)[:, None] * xw    # (T, P)
    h_new = jax.lax.dot_general(w_out, Bm, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (P, N)
    h_ref[...] = jnp.exp(csum[-1]) * h_in + h_new

    y_ref[0, :, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk",))
def mamba2_scan(x, dt, A, Bm, Cm, *, chunk: int = 128):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,N) -> y: (B,S,H,P)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                  # A (H,)
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=_interpret(),
    )(A.astype(jnp.float32), x, dt, Bm, Cm)
    return y[:, :S]
