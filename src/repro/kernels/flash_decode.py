"""Pallas TPU flash-decoding: one query token vs a long KV cache.

Grid walks KV blocks sequentially per (batch, kv-head); the running
(m, l, acc) triple lives in VMEM scratch - the same register-resident merge
the paper performs across tiers, here across KV blocks of a 32K-512K cache.
The per-sequence valid length arrives via scalar-memory (SMEM) so masking
is branch-free.

Two cache layouts share the same online-softmax inner step:

  flash_decode        dense (B, S_max, Hkv, D) caches - one contiguous
                      KV strip per sequence.
  paged_flash_decode  a global (P, page, Hkv, D) page pool shared by all
                      sequences; each grid step gathers its page through a
                      scalar-prefetched block table (SMEM), so the BlockSpec
                      index map IS the page-table walk and the (m, l, acc)
                      merge never leaves VMEM scratch.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams

LOG2E = 1.4426950408889634
NEG_INF = -1e30


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _online_merge(s, mask, v, acc_ref, m_ref, l_ref):
    """Fold one masked score block into the running (m, l, acc) triple.

    THE online-softmax merge, shared by every decode/suffix-prefill kernel:
    s: (rows, bk) f32 scores, mask: (rows, bk) bool, v: (bk, D) f32.  Only
    how s/mask were built differs per kernel (scalar valid-length vs 2-D
    offset-causal)."""
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.where(mask, jnp.exp2((s - m_safe) * LOG2E), 0.0)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0,
                      jnp.exp2((m_prev - m_new) * LOG2E))
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
    m_ref[...] = m_new
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv


def _online_softmax_step(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, *,
                         k_first, valid, window: int, scale: float,
                         softcap: float = 0.0):
    """One KV-block update of the running (m, l, acc) triple in VMEM.

    Shared by the dense and the paged decode kernels - only how the KV block
    got into VMEM differs (contiguous BlockSpec walk vs block-table gather).
    """
    q = q_ref[0, 0].astype(jnp.float32) * scale              # (G, D)
    k = k_ref[0].astype(jnp.float32)[:, 0]                   # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bk)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    pos = k_first + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = pos < valid
    if window > 0:
        mask = mask & (pos >= valid - window)
    v = v_ref[0].astype(jnp.float32)[:, 0]                   # (bk, D)
    _online_merge(s, mask, v, acc_ref, m_ref, l_ref)


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, window: int, scale: float, softcap: float,
                   block_kv: int, gq: int):
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    valid = len_ref[pl.program_id(0)]
    k_first = j * block_kv
    run = k_first < valid
    if window > 0:
        run = run & (k_first + block_kv > valid - window)

    @pl.when(run)
    def _compute():
        _online_softmax_step(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
                             k_first=k_first, valid=valid, window=window,
                             scale=scale, softcap=softcap)

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "scale",
                                             "logit_softcap", "block_kv"))
def flash_decode(q, k_cache, v_cache, cache_len, *, window: int = 0,
                 scale: Optional[float] = None,
                 logit_softcap: float = 0.0,
                 block_kv: int = 512) -> jax.Array:
    """q: (B,1,Hq,D); caches: (B,S,Hkv,D); cache_len: (B,) or scalar."""
    B, _, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    cache_len = jnp.asarray(cache_len, jnp.int32)
    if cache_len.ndim == 0:
        cache_len = jnp.full((B,), cache_len, jnp.int32)

    block_kv = min(block_kv, max(S, 128))
    pk = (-S) % block_kv
    kc = jnp.pad(k_cache, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k_cache
    vc = jnp.pad(v_cache, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v_cache
    nk = (S + pk) // block_kv

    qg = q.reshape(B, Hkv, G, D)

    kernel = functools.partial(_decode_kernel, window=window, scale=scale,
                               softcap=logit_softcap, block_kv=block_kv,
                               gq=G)
    grid = (B, Hkv, nk)
    o = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # cache_len, prefetched
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=_interpret(),
    )(cache_len, qg, kc, vc)
    return o.reshape(B, 1, Hq, D)


# ===========================================================================
# paged decode: KV pages gathered through a scalar-prefetched block table
# ===========================================================================

def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, window: int, scale: float,
                         softcap: float, page_size: int):
    """bt_ref: (B, n_max) block table, len_ref: (B,) valid lengths - both
    scalar-prefetched into SMEM; the k/v BlockSpec index maps already walked
    the table, so k_ref/v_ref hold page j of THIS sequence."""
    b = pl.program_id(0)
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    valid = len_ref[b]
    k_first = j * page_size
    run = k_first < valid
    if window > 0:
        run = run & (k_first + page_size > valid - window)

    @pl.when(run)
    def _compute():
        _online_softmax_step(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
                             k_first=k_first, valid=valid, window=window,
                             scale=scale, softcap=softcap)

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "scale",
                                             "logit_softcap"))
def paged_flash_decode(q, k_pages, v_pages, block_table, cache_len, *,
                       window: int = 0,
                       scale: Optional[float] = None,
                       logit_softcap: float = 0.0) -> jax.Array:
    """Decode against a paged KV cache.

    q:           (B, 1, Hq, D)
    k/v_pages:   (P, page_size, Hkv, D) global page pool (all sequences)
    block_table: (B, n_max) int32 - page ids per sequence, position-major;
                 unused entries must point at a valid page (the engine keeps
                 page 0 as a never-allocated null page)
    cache_len:   (B,) or scalar valid lengths
    Returns (B, 1, Hq, D).
    """
    B, _, Hq, D = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    G = Hq // Hkv
    n_max = block_table.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    cache_len = jnp.asarray(cache_len, jnp.int32)
    if cache_len.ndim == 0:
        cache_len = jnp.full((B,), cache_len, jnp.int32)
    block_table = jnp.asarray(block_table, jnp.int32)

    qg = q.reshape(B, Hkv, G, D)
    kernel = functools.partial(_paged_decode_kernel, window=window,
                               scale=scale, softcap=logit_softcap,
                               page_size=ps)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,       # block table + lengths land in SMEM
        grid=(B, Hkv, n_max),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, bt, cl: (b, h, 0, 0)),
            # the index map IS the page-table walk: page j of sequence b
            pl.BlockSpec((1, ps, 1, D),
                         lambda b, h, j, bt, cl: (bt[b, j], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda b, h, j, bt, cl: (bt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, j, bt, cl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    o = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=_interpret(),
    )(block_table, cache_len, qg, k_pages, v_pages)
    return o.reshape(B, 1, Hq, D)
