"""Pallas TPU kernels (+ jnp reference oracles) for the perf-critical layers.

flash_attention / flash_decode implement the paper's register-resident fused
attention chain on TPU (VMEM/VREG instead of hybrid-bonded TSVs);
mamba2_scan / rwkv6_scan apply the same fusion principle to the
attention-free architectures.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
