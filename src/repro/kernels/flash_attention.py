"""Pallas TPU FlashAttention-2 forward - the paper's technique, TPU-native.

The 3D-Flow mapping collapsed onto one kernel: the four "tiers" (QK^T |
rowmax/sub | exp/rowsum | PV/rescale) execute back-to-back on the MXU and
VPU with every intermediate (S, m, N, P, b, l, O-partials) living in
VREGs/VMEM scratch - the TPU analogue of hybrid-bonded register-to-register
TSV links.  Block shapes come from core.tpu_mapping.choose_block_config,
which applies the paper's latency-balanced scheduling criterion to the
MXU-vs-VPU stage split, and the Pallas grid pipeline overlaps the next
block's HBM->VMEM DMA with the current block's compute (the "bubble-free"
property).

Executes on TPU compiled, or anywhere via interpret mode (used for CPU
validation against ref.py).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams

from ..core.tpu_mapping import choose_block_config

LOG2E = 1.4426950408889634
NEG_INF = -1e30


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref, *,
               causal: bool, window: int, softcap: float, scale: float,
               block_q: int, block_kv: int, seq_kv: int):
    i = pl.program_id(2)
    j = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_first = i * block_q
    q_last = q_first + block_q - 1
    k_first = j * block_kv
    k_last = k_first + block_kv - 1

    run = jnp.bool_(True)
    if causal or window > 0:
        run = run & (k_first <= q_last)            # block above the diagonal
    if window > 0:
        run = run & (k_last > q_first - window)    # block left of the window

    @pl.when(run)
    def _compute():
        # ---- tier 0: QK^T (MXU) ------------------------------------------
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)

        q_pos = q_first + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 0)
        k_pos = k_first + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 1)
        mask = k_pos < seq_kv
        if causal or window > 0:
            mask = mask & (k_pos <= q_pos)
        if window > 0:
            mask = mask & (k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        # ---- tier 1: rowmax + subtract (VPU) ------------------------------
        m_prev = m_ref[...]                                 # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)

        # ---- tier 2: exp2 + rowsum + rescale (VPU) ------------------------
        p = jnp.exp2((s - m_safe) * LOG2E)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0,
                          jnp.exp2((m_prev - m_new) * LOG2E))
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        m_ref[...] = m_new

        # ---- tier 3: PV + O rescale (MXU) ---------------------------------
        v = v_ref[0, 0].astype(jnp.float32)                 # (bk, D)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_ref[...] + jnp.log(l))[:, 0]


@functools.partial(jax.jit, static_argnames=("causal", "window",
                                             "logit_softcap", "scale",
                                             "block_q", "block_kv"))
def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        logit_softcap: float = 0.0,
                        scale: Optional[float] = None,
                        block_q: int = 0,
                        block_kv: int = 0) -> Tuple[jax.Array, jax.Array]:
    """q: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D).  Returns (o, lse)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    if not block_q or not block_kv:
        bc = choose_block_config(D, max(Sq, Skv))
        block_q, block_kv = bc.block_q, bc.block_kv
    block_q = min(block_q, max(Sq, 8))
    block_kv = min(block_kv, max(Skv, 128))

    # pad seq dims to block multiples
    pq = (-Sq) % block_q
    pk = (-Skv) % block_kv
    qt = jnp.moveaxis(q, 2, 1)                    # (B,H,Sq,D)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pk), (0, 0)))
    Sqp, Skp = Sq + pq, Skv + pk
    nq, nk = Sqp // block_q, Skp // block_kv

    kernel = functools.partial(
        _fa_kernel, causal=causal, window=window, softcap=logit_softcap,
        scale=scale, block_q=block_q, block_kv=block_kv, seq_kv=Skv)

    grid = (B, Hq, nq, nk)
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, Sqp, D), q.dtype),
            jax.ShapeDtypeStruct((B, Hq, Sqp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=_interpret(),
    )(qt, kt, vt)

    o = jnp.moveaxis(o[:, :, :Sq], 1, 2)          # back to (B,Sq,Hq,D)
    lse = jnp.moveaxis(lse[:, :, :Sq], 1, 2)      # (B,Sq,Hq)
    return o, lse
