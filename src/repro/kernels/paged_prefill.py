"""Pallas TPU paged chunk prefill: ragged batches of mid-prompt chunk runs
against a partially filled paged KV pool.

The primary entry point is BATCHED: one launch executes K chunks of K
DIFFERENT sequences, each at its own prompt position.  Row k carries three
scalar-prefetched per-row facts in SMEM:

  offset[k]     absolute position of the row's first query token
  true_len[k]   the row's prefill cursor AFTER its last real token
                (offset + real chunk length; pages past it are skipped)
  tables[k, :]  the sequence's block-table row (position-major page ids)

so the serve engine can fold every prefill chunk the scheduler planned
this tick - K sequences at K different prompt positions, ragged lengths
zero-padded to one static chunk shape - into ONE kernel launch instead of
K.  This is the software analogue of the paper's bubble-free vertical
dataflow: the win of fine-grained chunking only materializes once the
per-chunk dispatch overhead is folded away (FlatAttention / Zen-Attention
make the same argument for tile-based NPU attention).

Three callers share the kernel, all handing it queries at absolute
positions ``offset + i`` whose K/V for positions < offset is already
resident in the page pool:

  batched chunked prefill  (serve/engine.py) - every chunk of this tick's
                   token-budget plan, packed by scheduler.pack_chunks.
  prefix caching   (serve/prefix_cache.py) - the uncached SUFFIX after
                   the longest cached prefix; offset = cached tokens.
  single chunks    (serve/scheduler.py sequential oracle path) - the K=1
                   special case, kept under the established
                   ``paged_prefill_attention`` name.

Either way the queries must attend causally over EVERYTHING before them -
earlier pages AND the chunk's own K/V, both reached through the row's
block-table row.

Under tensor parallelism (kernels/ops.py _tp_head_sharded,
docs/tensor_parallel.md) this kernel runs unmodified inside shard_map on
each device's contiguous head slice: per-head attention never mixes
heads, the scalar-prefetched tables/offsets/cursors ride in replicated,
and the caller requires n_kv_heads % tp_degree == 0 so every shard holds
whole GQA groups.  Nothing in here is sharding-aware - the kernel sees a
smaller head count and is otherwise bit-identical.

Same construction as paged_flash_decode (kernels/flash_decode.py): the
block tables are scalar-prefetched into SMEM, the BlockSpec index map IS
the page-table walk, and the running (m, l, acc) online-softmax state
stays in VMEM scratch across KV pages.  The only extra ingredient over
the decode kernel is a 2-D causal mask - each chunk row r masks columns
> offset[k] + r - computed branch-free from the prefetched offset.

The grid walks the FULL block-table row (n_max pages, a static shape);
pages at or past the row's true_len are skipped with pl.when, so the cost
scales with the attended prefix, not with max_seq.  Dead (padding) rows
carry true_len == 0 and an all-null table: every page is skipped and the
row's output is exactly zero.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .flash_decode import _online_merge
from .pallas_compat import CompilerParams

NEG_INF = -1e30


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _chunk_kernel(tbl_ref, off_ref, tl_ref, ql_ref, q_ref, k_ref, v_ref,
                  o_ref, acc_ref, m_ref, l_ref, *, page_size: int,
                  window: int, scale: float, softcap: float, gq: int,
                  s_suf: int):
    """tbl_ref: (K, n_max) block-table rows, off_ref/tl_ref/ql_ref: (K,)
    per-row chunk start / prefill cursor / real query count - all
    scalar-prefetched; k_ref/v_ref hold page j of row b's sequence (the
    index map already walked the table).  Query rows at or past ql are
    PAD lanes (a speculative verify row drafts fewer than S - 1 tokens):
    their output is forced to exactly zero in the finalize, so ragged
    verify batches stay bit-deterministic whatever the pad rows hold."""
    b = pl.program_id(0)
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    off = off_ref[b]
    tl = tl_ref[b]
    k_first = j * page_size
    # the row's last real query attends through position true_len - 1;
    # pages fully past that frontier contribute nothing (and may be the
    # null page).  A dead row (true_len == 0) skips every page.
    run = k_first < tl
    if window > 0:
        run = run & (k_first + page_size > off - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32).reshape(s_suf * gq, -1) * scale
        k = k_ref[0].astype(jnp.float32)[:, 0]               # (ps, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        # row r of the flattened (s_suf * G) block is query chunk-index
        # r // gq at absolute position off + r // gq
        row = off + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // gq
        col = k_first + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = col <= row
        if window > 0:
            mask = mask & (col > row - window)
        v = v_ref[0].astype(jnp.float32)[:, 0]               # (ps, D)
        _online_merge(s, mask, v, acc_ref, m_ref, l_ref)

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o = acc_ref[...] / l
        # zero pad query lanes (flattened row r is query index r // gq)
        ql = ql_ref[b]
        qidx = jax.lax.broadcasted_iota(jnp.int32, (s_suf * gq, 1), 0) // gq
        o = jnp.where(qidx < ql, o, 0.0).reshape(s_suf, gq, -1)
        o_ref[0, 0] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "scale",
                                             "logit_softcap"))
def batched_paged_prefill_attention(q, k_pages, v_pages, page_tables,
                                    q_offsets, true_lens, q_lens=None, *,
                                    window: int = 0,
                                    scale: Optional[float] = None,
                                    logit_softcap: float = 0.0) -> jax.Array:
    """Ragged batched mid-prompt chunk-prefill attention through per-row
    block tables: K chunks of K different sequences in ONE launch.

    q:           (K, S, Hq, D) chunk queries; row k sits at absolute
                 positions q_offsets[k] + arange(S).  Each row's K/V must
                 already be written into its pages
                 (attn_prefill_chunks_paged does both), as must all K/V
                 for positions < q_offsets[k] (cached prefix pages and/or
                 earlier chunks - which may be other rows of the SAME
                 launch: the per-layer scatter lands before this kernel
                 reads the pool, so packing two chunks of one sequence is
                 exact as long as their offsets are ordered).
    k/v_pages:   (P, page_size, Hkv, D) global page pool
    page_tables: (K, n_max) int32 - per-row block-table rows,
                 position-major; entries past the reservation point at the
                 null page 0 and are never touched by the causal mask
    q_offsets:   (K,) int32, absolute position of each row's first token
    true_lens:   (K,) int32, each row's prefill cursor after its last
                 REAL token (ragged lengths: rows are zero-padded to S).
                 A dead padding row carries 0 and an all-null table row;
                 its output is exactly zero.
    q_lens:      (K,) int32 per-row REAL query count (the draft-length
                 lane of the speculative verify path: a verify row holds
                 1 + m real queries for an m-token draft chain).  Rows at
                 or past a row's q_len come back as exactly zero, so
                 ragged batches are bit-deterministic whatever their pad
                 lanes contain.  Defaults to true_lens - q_offsets (every
                 non-dead position real), preserving the historical
                 contract of the chunk-prefill callers.
    Returns (K, S, Hq, D).
    """
    K, S, Hq, D = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    G = Hq // Hkv
    n_max = page_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    page_tables = jnp.asarray(page_tables, jnp.int32)
    off = jnp.asarray(q_offsets, jnp.int32).reshape(K)
    tl = jnp.asarray(true_lens, jnp.int32).reshape(K)
    ql = jnp.clip(tl - off, 0, S) if q_lens is None \
        else jnp.asarray(q_lens, jnp.int32).reshape(K)

    # head-major GQA grouping, one grid row per (sequence row, KV head)
    qg = q.reshape(K, S, Hkv, G, D).transpose(0, 2, 1, 3, 4)  # (K,Hkv,S,G,D)
    kernel = functools.partial(_chunk_kernel, page_size=ps, window=window,
                               scale=scale, softcap=logit_softcap, gq=G,
                               s_suf=S)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        # tables + offsets + true_lens + q_lens in SMEM
        num_scalar_prefetch=4,
        grid=(K, Hkv, n_max),
        in_specs=[
            pl.BlockSpec((1, 1, S, G, D),
                         lambda b, h, j, tbl, off, tl, ql: (b, h, 0, 0, 0)),
            # the index map IS the page-table walk: page j of row b
            pl.BlockSpec((1, ps, 1, D),
                         lambda b, h, j, tbl, off, tl, ql:
                         (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda b, h, j, tbl, off, tl, ql:
                         (tbl[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, S, G, D),
                               lambda b, h, j, tbl, off, tl, ql:
                               (b, h, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((S * G, D), jnp.float32),
            pltpu.VMEM((S * G, 1), jnp.float32),
            pltpu.VMEM((S * G, 1), jnp.float32),
        ],
    )
    o = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((K, Hkv, S, G, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=_interpret(),
    )(page_tables, off, tl, ql, qg, k_pages, v_pages)
    return o.transpose(0, 2, 1, 3, 4).reshape(K, S, Hq, D)


def paged_prefill_attention(q, k_pages, v_pages, page_row, q_offset, *,
                            window: int = 0,
                            scale: Optional[float] = None,
                            logit_softcap: float = 0.0) -> jax.Array:
    """Single-sequence mid-prompt chunk prefill: the K=1 special case of
    batched_paged_prefill_attention.

    q: (1, S, Hq, D); page_row: (n_max,) this sequence's block-table row;
    q_offset: scalar int32.  Every position of the chunk is treated as
    real (true_len = q_offset + S), matching the historical single-row
    contract.  Returns (1, S, Hq, D)."""
    off = jnp.asarray(q_offset, jnp.int32).reshape(1)
    return batched_paged_prefill_attention(
        q, k_pages, v_pages, jnp.asarray(page_row, jnp.int32)[None],
        off, off + q.shape[1], window=window, scale=scale,
        logit_softcap=logit_softcap)
