"""Pallas TPU paged chunk prefill: a mid-prompt run of new tokens vs a
partially filled paged KV pool.

Two callers share this kernel, both handing it queries at absolute
positions ``q_offset + i`` whose K/V for positions < q_offset is already
resident in the page pool:

  prefix caching   (serve/prefix_cache.py) - the uncached SUFFIX after
                   the longest cached prefix; q_offset = cached tokens.
  chunked prefill  (serve/scheduler.py) - chunk i of a token-budget
                   scheduled prompt; q_offset = tokens written by earlier
                   chunks (plus any cached prefix).  Composing chunks
                   left to right reproduces the monolithic prefill
                   exactly - this is the request-level analogue of the
                   paper's fine-grained attention chunking: small units
                   that interleave with neighbors instead of stalling
                   them.

Either way the queries must attend causally over EVERYTHING before them -
earlier pages AND the chunk's own K/V, both reached through the
sequence's block-table row.

Same construction as paged_flash_decode (kernels/flash_decode.py): the
block-table row is scalar-prefetched into SMEM, the BlockSpec index map
IS the page-table walk, and the running (m, l, acc) online-softmax state
stays in VMEM scratch across KV pages.  The only new ingredient is a 2-D
causal mask - each chunk row r masks columns > q_offset + r - computed
branch-free from the prefetched offset.

The grid walks the FULL block-table row (n_max pages, a static shape);
pages beyond the causal frontier are skipped with pl.when, so the cost
scales with the attended prefix, not with max_seq.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .flash_decode import _online_merge
from .pallas_compat import CompilerParams

NEG_INF = -1e30


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _chunk_kernel(pr_ref, off_ref, q_ref, k_ref, v_ref, o_ref, acc_ref,
                  m_ref, l_ref, *, page_size: int, window: int,
                  scale: float, softcap: float, gq: int, s_suf: int):
    """pr_ref: (n_max,) block-table row, off_ref: (1,) chunk start - both
    scalar-prefetched; k_ref/v_ref hold page j of this sequence (the index
    map already walked the table)."""
    j = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    off = off_ref[0]
    k_first = j * page_size
    # last chunk row attends through position off + s_suf - 1; pages fully
    # past that frontier contribute nothing (and may be the null page)
    run = k_first < off + s_suf
    if window > 0:
        run = run & (k_first + page_size > off - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32).reshape(s_suf * gq, -1) * scale
        k = k_ref[0].astype(jnp.float32)[:, 0]               # (ps, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        # row r of the flattened (s_suf * G) block is query chunk-index
        # r // gq at absolute position off + r // gq
        row = off + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // gq
        col = k_first + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = col <= row
        if window > 0:
            mask = mask & (col > row - window)
        v = v_ref[0].astype(jnp.float32)[:, 0]               # (ps, D)
        _online_merge(s, mask, v, acc_ref, m_ref, l_ref)

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o = (acc_ref[...] / l).reshape(s_suf, gq, -1)
        o_ref[0] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "scale",
                                             "logit_softcap"))
def paged_prefill_attention(q, k_pages, v_pages, page_row, q_offset, *,
                            window: int = 0,
                            scale: Optional[float] = None,
                            logit_softcap: float = 0.0) -> jax.Array:
    """Mid-prompt chunk-prefill attention through the block table.

    q:           (1, S, Hq, D) chunk queries at absolute positions
                 q_offset + arange(S); the chunk's K/V must already be
                 written into its pages (attn_prefill_chunk_paged does
                 both), as must all K/V for positions < q_offset (cached
                 prefix pages and/or earlier chunks)
    k/v_pages:   (P, page_size, Hkv, D) global page pool
    page_row:    (n_max,) int32 - this sequence's block-table row,
                 position-major; entries past the reservation point at the
                 null page 0 and are never touched by the causal mask
    q_offset:    scalar int32, absolute position of the first chunk token
    Returns (1, S, Hq, D).
    """
    _, S, Hq, D = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    G = Hq // Hkv
    n_max = page_row.shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    page_row = jnp.asarray(page_row, jnp.int32)
    off = jnp.reshape(jnp.asarray(q_offset, jnp.int32), (1,))

    # head-major GQA grouping, one grid row per KV head
    qg = q[0].reshape(S, Hkv, G, D).transpose(1, 0, 2, 3)    # (Hkv,S,G,D)
    kernel = functools.partial(_chunk_kernel, page_size=ps, window=window,
                               scale=scale, softcap=logit_softcap, gq=G,
                               s_suf=S)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,           # block-table row + offset in SMEM
        grid=(Hkv, n_max),
        in_specs=[
            pl.BlockSpec((1, S, G, D), lambda h, j, pr, off: (h, 0, 0, 0)),
            # the index map IS the page-table walk: page j of the sequence
            pl.BlockSpec((1, ps, 1, D),
                         lambda h, j, pr, off: (pr[j], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda h, j, pr, off: (pr[j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, S, G, D),
                               lambda h, j, pr, off: (h, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((S * G, D), jnp.float32),
            pltpu.VMEM((S * G, 1), jnp.float32),
            pltpu.VMEM((S * G, 1), jnp.float32),
        ],
    )
    o = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Hkv, S, G, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=_interpret(),
    )(page_row, off, qg, k_pages, v_pages)
    return o.transpose(1, 0, 2, 3).reshape(1, S, Hq, D)
