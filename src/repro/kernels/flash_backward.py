"""Pallas TPU FlashAttention-2 backward kernels.

Two kernels, mirroring the FA-2 work split:

  dq kernel : grid (B, Hq, nq, nk), KV innermost; dq accumulates in VMEM
              scratch and is written once per q-block.
  dkv kernel: grid (B, Hkv, nk, nq), Q innermost; dk/dv accumulate in VMEM
              scratch (summed over the GQA group in-register) and are
              written once per kv-block.

Like the forward, every intermediate (S, P, dP, dS) lives in VREGs/VMEM -
the paper's "buffers to registers" principle applied to the backward chain.
Softcap and sliding-window masks match ops._flash_bwd_rule (the pure-jnp
oracle used for CPU execution and for validation in interpret mode).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams

LOG2E = 1.4426950408889634
NEG_INF = -1e30


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _masks(q_first, k_first, bq, bk, seq_kv, causal, window):
    q_pos = q_first + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = k_first + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < seq_kv
    if causal or window > 0:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    return mask


def _p_and_ds(q, k, v, do, lse, delta, mask, *, softcap, scale):
    """Shared recompute: returns (p, ds) for one (bq, bk) tile, fp32."""
    s_raw = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        t = jnp.tanh(s_raw / softcap)
        s = softcap * t
    else:
        t = None
        s = s_raw
    p = jnp.exp2((s - lse[:, None]) * LOG2E)
    p = jnp.where(mask, p, 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    if softcap > 0.0:
        ds = ds * (1.0 - t * t)
    return p, ds


# ===========================================================================
# dq kernel
# ===========================================================================

def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_ref, *, causal, window, softcap, scale, block_q,
               block_kv, seq_kv):
    i = pl.program_id(2)
    j = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = jnp.bool_(True)
    q_first, k_first = i * block_q, j * block_kv
    if causal or window > 0:
        run = run & (k_first <= q_first + block_q - 1)
    if window > 0:
        run = run & (k_first + block_kv - 1 > q_first - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        mask = _masks(q_first, k_first, block_q, block_kv, seq_kv,
                      causal, window)
        _, ds = _p_and_ds(q, k, v, do, lse, delta, mask,
                          softcap=softcap, scale=scale)
        acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    @pl.when(j == nk - 1)
    def _finalize():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


# ===========================================================================
# dkv kernel
# ===========================================================================

def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *, causal, window, softcap,
                scale, block_q, block_kv, seq_kv, gqa):
    j = pl.program_id(2)
    i = pl.program_id(3)
    nq = pl.num_programs(3)

    @pl.when(i == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    run = jnp.bool_(True)
    q_first, k_first = i * block_q, j * block_kv
    if causal or window > 0:
        run = run & (k_first <= q_first + block_q - 1)
    if window > 0:
        run = run & (k_first + block_kv - 1 > q_first - window)

    @pl.when(run)
    def _compute():
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        mask = _masks(q_first, k_first, block_q, block_kv, seq_kv,
                      causal, window)
        # sum over the GQA group in-register
        for g in range(gqa):
            q = q_ref[0, 0, g].astype(jnp.float32)
            do = do_ref[0, 0, g].astype(jnp.float32)
            lse = lse_ref[0, 0, g]
            delta = delta_ref[0, 0, g]
            p, ds = _p_and_ds(q, k, v, do, lse, delta, mask,
                              softcap=softcap, scale=scale)
            dv_acc[...] += jax.lax.dot_general(
                p, do, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            dk_acc[...] += jax.lax.dot_general(
                ds, q, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32) * scale

    @pl.when(i == nq - 1)
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


# ===========================================================================
# wrapper
# ===========================================================================

@functools.partial(jax.jit, static_argnames=("causal", "window",
                                             "logit_softcap", "scale",
                                             "block_q", "block_kv"))
def flash_attention_bwd(q, k, v, o, lse, do, *, causal: bool = True,
                        window: int = 0, logit_softcap: float = 0.0,
                        scale: Optional[float] = None, block_q: int = 128,
                        block_kv: int = 128) -> Tuple[jax.Array, jax.Array,
                                                      jax.Array]:
    """q,do: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D); o: (B,Sq,Hq,D);
    lse: (B,Sq,Hq) natural-log row log-sum-exp.  Returns (dq, dk, dv)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    sc = scale if scale is not None else 1.0 / math.sqrt(D)

    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), -1)

    pq = (-Sq) % block_q
    pk = (-Skv) % block_kv
    qt = jnp.moveaxis(q, 2, 1)
    dot = jnp.moveaxis(do, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    lset = jnp.moveaxis(lse, 2, 1)
    deltat = jnp.moveaxis(delta, 2, 1)
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
        dot = jnp.pad(dot, ((0, 0), (0, 0), (0, pq), (0, 0)))
        # padded rows must be inert: lse=+inf makes p = exp2(-inf) = 0
        lset = jnp.pad(lset, ((0, 0), (0, 0), (0, pq)),
                       constant_values=1e30)
        deltat = jnp.pad(deltat, ((0, 0), (0, 0), (0, pq)))
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pk), (0, 0)))
    Sqp, Skp = Sq + pq, Skv + pk
    nq, nk = Sqp // block_q, Skp // block_kv

    # ---- dq ----------------------------------------------------------------
    dq_kernel = functools.partial(
        _dq_kernel, causal=causal, window=window, softcap=logit_softcap,
        scale=sc, block_q=block_q, block_kv=block_kv, seq_kv=Skv)
    dq = pl.pallas_call(
        dq_kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sqp, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=_interpret(),
    )(qt, kt, vt, dot, lset, deltat)

    # ---- dk/dv --------------------------------------------------------------
    # reshape q-side tensors to (B, Hkv, G, Sqp, ...) for the group loop
    qg = qt.reshape(B, Hkv, G, Sqp, D)
    dog = dot.reshape(B, Hkv, G, Sqp, D)
    lseg = lset.reshape(B, Hkv, G, Sqp)
    deltag = deltat.reshape(B, Hkv, G, Sqp)
    dkv_kernel = functools.partial(
        _dkv_kernel, causal=causal, window=window, softcap=logit_softcap,
        scale=sc, block_q=block_q, block_kv=block_kv, seq_kv=Skv, gqa=G)
    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid=(B, Hkv, nk, nq),
        in_specs=[
            pl.BlockSpec((1, 1, G, block_q, D),
                         lambda b, h, j, i: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, j, i: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, j, i: (b, h, j, 0)),
            pl.BlockSpec((1, 1, G, block_q, D),
                         lambda b, h, j, i: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, G, block_q), lambda b, h, j, i: (b, h, 0, i)),
            pl.BlockSpec((1, 1, G, block_q), lambda b, h, j, i: (b, h, 0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, j, i: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, j, i: (b, h, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, Skp, D), k.dtype),
            jax.ShapeDtypeStruct((B, Hkv, Skp, D), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_kv, D), jnp.float32),
                        pltpu.VMEM((block_kv, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=_interpret(),
    )(qg, kt, vt, dog, lseg, deltag)

    dq = jnp.moveaxis(dq[:, :, :Sq], 1, 2)
    dk = jnp.moveaxis(dk[:, :, :Skv], 1, 2)
    dv = jnp.moveaxis(dv[:, :, :Skv], 1, 2)
    return dq, dk, dv
