"""Pallas TPU RWKV6 (Finch) WKV scan with data-dependent per-channel decay.

Chunked matrix form: within a chunk of T steps the pairwise decay products
are expressed through cumulative log-decay sums, turning the recurrence into
two MXU matmuls plus element-wise VPU work; the inter-chunk state S (K x V)
stays in VMEM scratch across the sequential grid dimension (the paper's
fusion principle: no HBM round-trips between chain stages).

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Per-channel decay makes the intra-chunk term
    y_t = sum_{s<t} [sum_c r_tc k_sc exp(cw_{t-1,c} - cw_{s,c})] v_s
        + (r_t u . k_t) v_t  +  (r_t exp(cw_{t-1}) ) S_in
where cw is the inclusive cumulative log decay.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _wkv_kernel(u_ref, r_ref, k_ref, v_ref, w_ref, y_ref, s_ref, *,
                chunk: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, :, 0].astype(jnp.float32)        # (T, K)
    k = k_ref[0, :, 0].astype(jnp.float32)        # (T, K)
    v = v_ref[0, :, 0].astype(jnp.float32)        # (T, V)
    w = w_ref[0, :, 0].astype(jnp.float32)        # (T, K), in (0,1)
    u = u_ref[0].astype(jnp.float32)              # (K,)

    logw = jnp.log(jnp.maximum(w, 1e-30))         # (T, K) <= 0
    cw = jnp.cumsum(logw, axis=0)                 # inclusive

    # r~_t = r_t * exp(cw_{t-1});  k~_s = k_s * exp(-cw_s)
    cw_prev = cw - logw                           # exclusive cumsum
    r_dec = r * jnp.exp(cw_prev)                  # (T, K)
    k_dec = k * jnp.exp(-cw)                      # (T, K)
    # A_ts = sum_c r~_tc k~_sc   for s < t     (strictly lower triangular)
    A = jax.lax.dot_general(r_dec, k_dec, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (T, T)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.where(tri, A, 0.0)
    y = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (T, V)

    # diagonal bonus term: (r_t . (u * k_t)) v_t
    diag = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)     # (T, 1)
    y = y + diag * v

    # carry-in: y_t += (r_t * exp(cw_{t-1})) @ S_in
    S_in = s_ref[...]                             # (K, V)
    y = y + jax.lax.dot_general(r_dec, S_in, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: S_out = diag(exp(cw_T)) S_in + sum_s exp(cw_T - cw_s)
    #                                              k_s^T v_s
    k_out = k_dec * jnp.exp(cw[-1])[None, :]      # (T, K)
    S_new = jax.lax.dot_general(k_out, v, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (K, V)
    s_ref[...] = jnp.exp(cw[-1])[:, None] * S_in + S_new

    y_ref[0, :, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk",))
def rwkv6_scan(r, k, v, w, u, *, chunk: int = 32):
    """r,k,w: (B,S,H,K); v: (B,S,H,V); u: (H,K) -> (B,S,H,V).

    NOTE: the exp(-cw) rescaling bounds usable chunk size: |chunk * log w|
    must stay < ~80 for fp32.  The model clamps its data-dependent decay to
    w >= exp(-2.1) (models/rwkv6.py), so chunk=32 keeps |cw| <= ~68.
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        zpad = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, zpad)
        k = jnp.pad(k, zpad)
        v = jnp.pad(v, zpad)
        w = jnp.pad(w, zpad, constant_values=1.0)   # decay 1 = no-op
    Sp = S + pad
    nc = Sp // chunk

    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, K), lambda b, h, c: (h, 0)),           # u
            pl.BlockSpec((1, chunk, 1, K), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, K), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, V), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, K), lambda b, h, c: (b, c, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, V), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, H, V), r.dtype),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=_interpret(),
    )(u, r, k, v, w)
    return y[:, :S]
