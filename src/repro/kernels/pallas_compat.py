"""Version-compat aliases for the Pallas TPU API surface.

jax >= 0.6 renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``;
kernels import the alias from here so they lower on both API generations.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
