"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth the kernels are validated against, AND the
portable execution path used on backends without Pallas (CPU tests, the
512-device dry-run).  They are written flash-style - chunked over the KV /
time dimension with lax.scan - so they stay memory-efficient at 32K/512K
sequence lengths (no N x N materialization), mirroring the paper's
"no SRAM round-trips" structure at the XLA level.

Conventions:
  q, k, v: (batch, seq, heads, head_dim); GQA when kv heads < q heads.
  Accumulation in fp32 regardless of input dtype.
"""
from __future__ import annotations

import functools
import math
import os
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mixed_einsum(pattern, a, b):
    """bf16 x bf16 einsum with fp32 accumulation.

    On TPU (and in the dry-run, which only compiles - REPRO_MIXED_DOTS=1)
    this is a native mixed-precision MXU dot: no fp32 copies of the operands
    are ever materialized and collectives carrying them stay bf16.  The CPU
    *runtime* cannot execute batched mixed dots (DotThunk), so tests upcast.
    """
    if jax.default_backend() == "cpu" and not os.environ.get("REPRO_MIXED_DOTS"):
        return jnp.einsum(pattern, a.astype(jnp.float32),
                          b.astype(jnp.float32))
    return jnp.einsum(pattern, a, b, preferred_element_type=jnp.float32)


def _gqa_expand(h_q: int, h_kv: int):
    assert h_q % h_kv == 0
    return h_q // h_kv


# ===========================================================================
# FlashAttention-2 forward (chunked, numerically stable)
# ===========================================================================

def _mesh_aligned_block(Skv: int, block_kv: int) -> int:
    """Align the KV block count to the mesh's model-axis size so the scan's
    stacked KV blocks stay sequence-sharded (one block per shard step)
    instead of being gathered wholesale."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and mesh.axis_names and "model" in mesh.axis_names:
            tp = dict(zip(mesh.axis_names, mesh.shape.values()))["model"] \
                if not hasattr(mesh.shape, "get") else mesh.shape.get("model", 1)
            if tp > 1 and Skv % tp == 0 and Skv // tp >= 128:
                return Skv // tp
    except Exception:
        pass
    return block_kv


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    window: int = 0,
                    logit_softcap: float = 0.0,
                    scale: Optional[float] = None,
                    q_offset: int = 0,
                    block_kv: int = 512) -> jax.Array:
    """Chunked attention: scan over KV blocks with running (m, l, o).

    window > 0: sliding-window attention (each query attends to the last
    `window` keys, inclusive of itself).  Implies causal masking.
    q_offset: absolute position of q[0] (for chunked prefill / cross-chunk).
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = _gqa_expand(Hq, Hkv)
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    # exp2-based exponent: exp(x) = exp2(x * log2(e)) - the paper's (and
    # hardware's) preferred form; fold the scale in once.
    LOG2E = 1.4426950408889634

    nblk = -(-Skv // block_kv)
    pad = nblk * block_kv - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qf = q.reshape(B, Sq, Hkv, G, D)        # bf16; fp32 happens in the dot

    kb = jnp.moveaxis(k.reshape(B, nblk, block_kv, Hkv, D), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nblk, block_kv, Hkv, D), 1, 0)

    q_pos = q_offset + jnp.arange(Sq)                      # (Sq,)

    def body(carry, blk):
        m, l, o = carry
        kblk, vblk, j = blk
        k_pos = j * block_kv + jnp.arange(block_kv)        # (bk,)
        s = mixed_einsum("bqhgd,bkhd->bqhgk", qf, kblk) * scale
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        mask = k_pos[None, :] <= (Skv - 1)                 # padding
        if causal or window > 0:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window > 0:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        # guard fully-masked rows (m_new == NEG_INF)
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp2((s - m_safe[..., None]) * LOG2E)
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        alpha = jnp.exp2((m - m_new) * LOG2E)
        alpha = jnp.where(m <= NEG_INF / 2, 0.0, alpha)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = mixed_einsum("bqhgk,bkhd->bqhgd", p.astype(q.dtype), vblk)
        o_new = o * alpha[..., None] + pv
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    o0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)
    (m, l, o), _ = jax.lax.scan(
        body, (m0, l0, o0), (kb, vb, jnp.arange(nblk)))
    o = o / jnp.maximum(l, 1e-20)[..., None]
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)


# ===========================================================================
# Flash-decoding: one query token against a long KV cache
# ===========================================================================

def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 cache_len, *,
                 scale: Optional[float] = None,
                 window: int = 0,
                 logit_softcap: float = 0.0,
                 block_kv: int = 1024) -> jax.Array:
    """q: (B, 1, Hq, D); k_cache/v_cache: (B, S_max, Hkv, D); cache_len: (B,)
    valid prefix length per sequence.  Returns (B, 1, Hq, D)."""
    B, Sq, Hq, D = q.shape
    assert Sq == 1
    _, S, Hkv, _ = k_cache.shape
    G = _gqa_expand(Hq, Hkv)
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    LOG2E = 1.4426950408889634
    cache_len = jnp.asarray(cache_len)
    if cache_len.ndim == 0:
        cache_len = jnp.full((B,), cache_len)

    nblk = -(-S // block_kv)
    pad = nblk * block_kv - S
    kc = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k_cache
    vc = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v_cache
    kb = jnp.moveaxis(kc.reshape(B, nblk, block_kv, Hkv, D), 1, 0)
    vb = jnp.moveaxis(vc.reshape(B, nblk, block_kv, Hkv, D), 1, 0)

    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, G, D)

    def body(carry, blk):
        m, l, o = carry
        kblk, vblk, j = blk
        pos = j * block_kv + jnp.arange(block_kv)          # (bk,)
        s = jnp.einsum("bhgd,bkhd->bhgk", qf, kblk.astype(jnp.float32))
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        mask = pos[None, :] < cache_len[:, None]           # (B, bk)
        if window > 0:
            mask = mask & (pos[None, :] >= cache_len[:, None] - window)
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp2((s - m_safe[..., None]) * LOG2E)
        p = jnp.where(mask[:, None, None, :], p, 0.0)
        alpha = jnp.exp2((m - m_new) * LOG2E)
        alpha = jnp.where(m <= NEG_INF / 2, 0.0, alpha)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgk,bkhd->bhgd", p, vblk.astype(jnp.float32))
        o_new = o * alpha[..., None] + pv
        return (m_new, l_new, o_new), None

    # varying-zero seed: under shard_map (the tensor-parallel serve path
    # wraps this kernel with the head axis sharded) the scan carry must
    # carry the same "varying manual axes" type as the body outputs, which
    # depend on the sharded cache; outside shard_map this is exactly +0.0
    vzero = jnp.sum(k_cache[:, :0].astype(jnp.float32))
    m0 = jnp.full((B, Hkv, G), NEG_INF, jnp.float32) + vzero
    l0 = jnp.zeros((B, Hkv, G), jnp.float32) + vzero
    o0 = jnp.zeros((B, Hkv, G, D), jnp.float32) + vzero
    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0),
                                (kb, vb, jnp.arange(nblk)))
    o = o / jnp.maximum(l, 1e-20)[..., None]
    return o.reshape(B, 1, Hq, D).astype(q.dtype)


def paged_flash_decode(q, k_pages, v_pages, block_table, cache_len, *,
                       scale: Optional[float] = None,
                       window: int = 0,
                       logit_softcap: float = 0.0) -> jax.Array:
    """Decode against a paged KV cache (reference oracle).

    q: (B, 1, Hq, D); k_pages/v_pages: (P, page_size, Hkv, D) global page
    pool; block_table: (B, n_max) int32 page ids (position-major, unused
    entries pointing at any valid page); cache_len: (B,) or scalar.

    Gathers each sequence's pages into a contiguous strip and runs the
    chunked dense decode - the ground truth the Pallas block-table kernel is
    validated against, and the portable paged-serving path off-TPU.
    """
    B = q.shape[0]
    _, page_size, Hkv, D = k_pages.shape
    block_table = jnp.asarray(block_table, jnp.int32)
    k = k_pages[block_table].reshape(B, -1, Hkv, D)
    v = v_pages[block_table].reshape(B, -1, Hkv, D)
    return flash_decode(q, k, v, cache_len, scale=scale, window=window,
                        logit_softcap=logit_softcap)


def batched_paged_prefill_attention(q, k_pages, v_pages, page_tables,
                                    q_offsets, true_lens, q_lens=None, *,
                                    scale: Optional[float] = None,
                                    window: int = 0,
                                    logit_softcap: float = 0.0) -> jax.Array:
    """Ragged batched mid-prompt chunk-prefill attention through per-row
    block tables (reference oracle): K chunks of K different sequences,
    each at its own absolute offset, in one call.

    q: (K, S, Hq, D) chunk queries; row k sits at absolute positions
    q_offsets[k] + arange(S), zero-padded past its real length (ragged
    rows share one static S); k/v_pages: (P, page_size, Hkv, D) global
    pool; page_tables: (K, n_max) per-row block-table rows (each row's
    chunk K/V already written into its pages, as is all K/V for positions
    < q_offsets[k]); true_lens: (K,) each row's prefill cursor after its
    last REAL token - columns at or past it are masked, the gather-level
    analogue of the Pallas kernel's page skip.  A dead padding row
    (true_len == 0, all-null table) returns exactly zero.  Each real
    query row attends causally over positions 0..q_offset+row - earlier
    pages and the chunk itself, so composing chunks left to right matches
    one monolithic causal prefill exactly.

    Gathers each row's pages into a contiguous strip and applies the
    offset causal mask - the ground truth the Pallas chunk kernel
    (kernels/paged_prefill.py) is validated against, and the portable
    chunked / prefix-cached serving path off-TPU.
    """
    K, S, Hq, D = q.shape
    _, ps, Hkv, _ = k_pages.shape
    G = _gqa_expand(Hq, Hkv)
    sc = scale if scale is not None else 1.0 / math.sqrt(D)
    LOG2E = 1.4426950408889634
    page_tables = jnp.asarray(page_tables, jnp.int32)
    k = k_pages[page_tables].reshape(K, -1, Hkv, D)      # (K, n_max*ps, ...)
    v = v_pages[page_tables].reshape(K, -1, Hkv, D)
    Skv = k.shape[1]
    qf = (q.astype(jnp.float32) * sc).reshape(K, S, Hkv, G, D)
    s = jnp.einsum("bshgd,bkhd->bshgk", qf, k.astype(jnp.float32))
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    row = jnp.asarray(q_offsets, jnp.int32)[:, None] + jnp.arange(S)[None, :]
    col = jnp.arange(Skv)
    tl = jnp.asarray(true_lens, jnp.int32)
    mask = (col[None, None, :] <= row[:, :, None]) \
        & (col[None, None, :] < tl[:, None, None])
    if window > 0:
        mask = mask & (col[None, None, :] > row[:, :, None] - window)
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    m = jnp.max(s, -1, keepdims=True)
    m_safe = jnp.where(m <= NEG_INF / 2, 0.0, m)
    p = jnp.where(mask[:, :, None, None, :],
                  jnp.exp2((s - m_safe) * LOG2E), 0.0)
    l = jnp.maximum(jnp.sum(p, -1, keepdims=True), 1e-20)
    o = jnp.einsum("bshgk,bkhd->bshgd", p / l, v.astype(jnp.float32))
    # q_lens: per-row REAL query count (speculative verify rows hold
    # 1 + m real queries).  Rows at or past it are forced to exactly
    # zero, matching the Pallas kernel's draft-length lane; the default
    # (true_lens - q_offsets) keeps the historical chunk contract.
    ql = jnp.clip(tl - jnp.asarray(q_offsets, jnp.int32), 0, S) \
        if q_lens is None else jnp.asarray(q_lens, jnp.int32)
    qpos = jnp.arange(S, dtype=jnp.int32)[None, :]
    o = jnp.where((qpos < ql[:, None])[:, :, None, None, None], o, 0.0)
    return o.reshape(K, S, Hq, D).astype(q.dtype)


def paged_prefill_attention(q, k_pages, v_pages, page_row, q_offset, *,
                            scale: Optional[float] = None,
                            window: int = 0,
                            logit_softcap: float = 0.0) -> jax.Array:
    """Single-sequence mid-prompt chunk prefill (reference oracle): the
    K=1 special case of batched_paged_prefill_attention.  q: (1, S, Hq, D);
    page_row: (n_max,); every chunk position is treated as real
    (true_len = q_offset + S), the historical single-row contract."""
    off = jnp.asarray(q_offset, jnp.int32).reshape(1)
    return batched_paged_prefill_attention(
        q, k_pages, v_pages, jnp.asarray(page_row, jnp.int32)[None],
        off, off + q.shape[1], scale=scale, window=window,
        logit_softcap=logit_softcap)


def combine_partial_softmax(m_parts, l_parts, o_parts):
    """Merge per-shard partial (m, l, o) triples - the distributed analogue
    of the paper's tier merge, used by sequence-parallel decode.

    m_parts: (P, ...), l_parts: (P, ...), o_parts: (P, ..., D)
    """
    LOG2E = 1.4426950408889634
    m = jnp.max(m_parts, axis=0)
    m_safe = jnp.where(m <= NEG_INF / 2, 0.0, m)
    alpha = jnp.exp2((m_parts - m_safe[None]) * LOG2E)
    alpha = jnp.where(m_parts <= NEG_INF / 2, 0.0, alpha)
    l = jnp.sum(l_parts * alpha, axis=0)
    o = jnp.sum(o_parts * alpha[..., None], axis=0)
    return m, l, o


# ===========================================================================
# Mamba2 (SSD) selective state space
# ===========================================================================

def mamba2_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, *, chunk: int = 0) -> jax.Array:
    """Mamba2 SSD recurrence (per-head scalar decay).

      h_t = exp(-dt_t * A) * h_{t-1} + dt_t * (B_t outer x_t)
      y_t = C_t . h_t

    x:  (B, S, H, P)   head channels
    dt: (B, S, H)      positive step sizes (already softplus'ed)
    A:  (H,)           positive per-head decay rate
    Bm: (B, S, N)      input projection (shared across heads, ngroups=1)
    Cm: (B, S, N)      output projection
    returns y: (B, S, H, P)
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    decay = jnp.exp(-dtf * Af[None, None, :])              # (B,S,H)

    def step(h, inp):
        xt, dtt, dct, bt, ct = inp                         # (B,H,P),(B,H),(B,H),(B,N),(B,N)
        # h: (B, H, P, N)
        inject = (dtt[..., None] * xt)[..., None] * bt[:, None, None, :]
        h = h * dct[..., None, None] + inject
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(decay, 1, 0), jnp.moveaxis(Bf, 1, 0),
          jnp.moveaxis(Cf, 1, 0))
    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def mamba2_step(h: jax.Array, x_t: jax.Array, dt_t: jax.Array, A: jax.Array,
                B_t: jax.Array, C_t: jax.Array):
    """Single decode step.  h: (B,H,P,N) fp32 state.  Returns (h', y_t)."""
    decay = jnp.exp(-dt_t.astype(jnp.float32) * A.astype(jnp.float32)[None])
    inject = (dt_t[..., None] * x_t.astype(jnp.float32))[..., None] \
        * B_t.astype(jnp.float32)[:, None, None, :]
    h = h * decay[..., None, None] + inject
    y = jnp.einsum("bhpn,bn->bhp", h, C_t.astype(jnp.float32))
    return h, y.astype(x_t.dtype)


# ===========================================================================
# RWKV6 (Finch) WKV recurrence with data-dependent decay
# ===========================================================================

def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array) -> jax.Array:
    """WKV6:  S_t = diag(w_t) S_{t-1} + k_t^T v_t
              y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

    r, k: (B, S, H, K); v: (B, S, H, V); w: (B, S, H, K) decay in (0,1);
    u: (H, K) bonus.  Returns (B, S, H, V).
    """
    Bsz, S, H, K = r.shape
    V = v.shape[-1]
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(Sstate, inp):
        rt, kt, vt, wt = inp                               # (B,H,K),(B,H,K),(B,H,V),(B,H,K)
        kv = kt[..., :, None] * vt[..., None, :]           # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", rt, Sstate + uf[None, :, :, None] * kv)
        Sstate = Sstate * wt[..., :, None] + kv
        return Sstate, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf))
    S0 = jnp.zeros((Bsz, H, K, V), jnp.float32)
    _, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype)


def rwkv6_scan_chunked_state(r, k, v, w, u, *, chunk: int = 32):
    """Chunked WKV6 returning (y, final_state) - used by true prefill."""
    return _rwkv6_chunked(r, k, v, w, u, chunk=chunk)


def rwkv6_scan_chunked(r, k, v, w, u, *, chunk: int = 32):
    return _rwkv6_chunked(r, k, v, w, u, chunk=chunk)[0]


def _rwkv6_chunked(r, k, v, w, u, *, chunk: int = 32):
    """Chunked matrix-form WKV6 (same math as kernels/rwkv6_scan.py) in pure
    jnp: the backward pass only saves per-CHUNK states instead of per-step
    states, cutting training memory by ~chunk_size (the paper's fusion
    principle applied to the recurrence at the XLA level)."""
    Bsz, S, H, K = r.shape
    V = v.shape[-1]
    pad = (-S) % chunk
    if pad:
        zpad = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, zpad)
        k = jnp.pad(k, zpad)
        v = jnp.pad(v, zpad)
        w = jnp.pad(w, zpad, constant_values=1.0)
    Sp = S + pad
    nc = Sp // chunk
    uf = u.astype(jnp.float32)

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(Bsz, nc, chunk, H, t.shape[-1]), 1, 0)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))   # (nc,B,T,H,·)

    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)

    def chunk_step(Sst, inp):
        rt, kt, vt, wt = (t.astype(jnp.float32) for t in inp)  # (B,T,H,·)
        logw = jnp.log(jnp.maximum(wt, 1e-30))
        cw = jnp.cumsum(logw, axis=1)
        cw_prev = cw - logw
        r_dec = rt * jnp.exp(cw_prev)
        k_dec = kt * jnp.exp(-cw)
        A = jnp.einsum("bthk,bshk->bhts", r_dec, k_dec) * tri[None, None]
        y = jnp.einsum("bhts,bshv->bthv", A, vt)
        diag = jnp.sum(rt * uf[None, None] * kt, -1, keepdims=True)
        y = y + diag * vt
        y = y + jnp.einsum("bthk,bhkv->bthv", r_dec, Sst)
        k_out = k_dec * jnp.exp(cw[:, -1])[:, None]
        S_new = jnp.einsum("bthk,bthv->bhkv", k_out, vt)
        Sst = jnp.exp(cw[:, -1])[..., None] * Sst + S_new
        return Sst, y.astype(r.dtype)

    S0 = jnp.zeros((Bsz, H, K, V), jnp.float32)
    S_fin, ys = jax.lax.scan(chunk_step, S0, (rc, kc, vc, wc))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, Sp, H, V)
    return y[:, :S], S_fin


def mamba2_scan_chunked_state(x, dt, A, Bm, Cm, *, chunk: int = 128):
    """Chunked SSD returning (y, final_state) - used by true prefill."""
    return _mamba2_chunked(x, dt, A, Bm, Cm, chunk=chunk)


def mamba2_scan_chunked(x, dt, A, Bm, Cm, *, chunk: int = 128):
    return _mamba2_chunked(x, dt, A, Bm, Cm, chunk=chunk)[0]


def _mamba2_chunked(x, dt, A, Bm, Cm, *, chunk: int = 128):
    """Chunked matrix-form SSD (same math as kernels/mamba2_scan.py) in pure
    jnp; backward saves per-chunk states only."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    Af = A.astype(jnp.float32)

    xc = jnp.moveaxis(x.reshape(Bsz, nc, chunk, H, P), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(Bsz, nc, chunk, H), 1, 0)
    Bc = jnp.moveaxis(Bm.reshape(Bsz, nc, chunk, N), 1, 0)
    Cc = jnp.moveaxis(Cm.reshape(Bsz, nc, chunk, N), 1, 0)

    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))

    def chunk_step(h, inp):
        xt, dtt, bt, ct = inp
        xf = xt.astype(jnp.float32)
        dtf = dtt.astype(jnp.float32)
        bf = bt.astype(jnp.float32)
        cf = ct.astype(jnp.float32)
        log_a = -dtf * Af[None, None]                    # (B,T,H)
        csum = jnp.cumsum(log_a, axis=1)
        Mdec = jnp.exp(csum[:, :, None] - csum[:, None, :])   # (B,T,T,H)
        M = Mdec * tri[None, :, :, None]
        CB = jnp.einsum("btn,bsn->bts", cf, bf)
        xw = xf * dtf[..., None]                         # (B,T,H,P)
        y = jnp.einsum("bts,btsh,bshp->bthp", CB, M, xw)
        y = y + jnp.exp(csum)[..., None] * jnp.einsum("btn,bhpn->bthp", cf, h)
        wout = jnp.exp(csum[:, -1][:, None] - csum)[..., None] * xw
        h_new = jnp.einsum("bthp,btn->bhpn", wout, bf)
        h = jnp.exp(csum[:, -1])[..., None, None] * h + h_new
        return h, y.astype(x.dtype)

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    h_fin, ys = jax.lax.scan(chunk_step, h0, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, Sp, H, P)
    return y[:, :S], h_fin


def rwkv6_step(Sstate: jax.Array, r_t, k_t, v_t, w_t, u):
    """Single decode step.  Sstate: (B,H,K,V) fp32."""
    kv = k_t.astype(jnp.float32)[..., :, None] * v_t.astype(jnp.float32)[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", r_t.astype(jnp.float32),
                   Sstate + u.astype(jnp.float32)[None, :, :, None] * kv)
    Sstate = Sstate * w_t.astype(jnp.float32)[..., :, None] + kv
    return Sstate, y.astype(r_t.dtype)


# ===========================================================================
# Naive (quadratic) attention - for small-shape cross-checks only
# ===========================================================================

def naive_attention(q, k, v, *, causal=True, window=0, logit_softcap=0.0,
                    scale=None):
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = _gqa_expand(Hq, Hkv)
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qf * scale, k.astype(jnp.float32))
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal or window > 0:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)
