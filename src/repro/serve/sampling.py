"""Device-side sampling stack: top-k / top-p / temperature sampling and
speculative acceptance as PURE jittable functions.

Every serving step that turns logits into tokens routes through here -
the fused decode step, the batched chunk step's final-row sampling, and
the speculative verify step (serve/serve_step.py) - so greedy, top-k,
top-p, and temperature sampling behave identically across every launch
shape, and the host-side `_sample` fallback paths in serve/engine.py run
the very same functions.  The filter knobs (temperature, top_k, top_p)
are Python-level statics closed over by the step factories: a jitted
step compiles the exact filter pipeline its config asked for, with no
device-side branching.

Filter order follows the de-facto standard (HF generate):

    logits -> / temperature -> top-k mask -> top-p mask -> categorical

Greedy is the temperature <= 0 limit and bypasses the PRNG entirely (the
key argument is ignored), so greedy steps stay key-free and bit-stable.

Speculative acceptance (`speculative_accept`) implements sample-and-
compare verification for a DETERMINISTIC draft proposal (self-drafting:
the n-gram drafter proposes one concrete chain, serve/drafting.py).  At
every chain position the TARGET model's token is sampled exactly as
non-speculative decoding would have sampled it; a draft token is
accepted iff it equals that sample.  With a delta-distribution proposal
q = delta(d) this IS the standard speculative rejection-sampling rule
(accept d with probability p(d); on rejection the residual distribution
is p with d zeroed - which is exactly "emit the target sample that
differed"), and because the emitted token at every position is the
target's own sample, the emitted stream is distributed token-for-token
identically to non-speculative decoding: greedy chains are bit-identical
(modulo kernel-rounding near-ties) and sampled chains are exact draws
from the target distribution.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def apply_top_k(logits: jax.Array, k: int) -> jax.Array:
    """Mask all but the k highest logits of the last axis to -inf.
    k <= 0 (or k >= vocab) disables the filter.  Ties at the k-th value
    keep every tied token (the mask is a >= threshold test), so the
    support is well-defined without an arbitrary tie-break."""
    v = logits.shape[-1]
    if k <= 0 or k >= v:
        return logits
    kth = jnp.sort(logits, axis=-1)[..., v - k][..., None]
    return jnp.where(logits >= kth, logits, NEG_INF)


def apply_top_p(logits: jax.Array, p: float) -> jax.Array:
    """Nucleus filter: keep the smallest set of highest-probability
    tokens whose cumulative probability reaches p; mask the rest to
    -inf.  p >= 1 disables the filter; the argmax token is always kept
    (even when its probability alone exceeds p)."""
    if p >= 1.0:
        return logits
    sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # token i (sorted desc) survives while the mass BEFORE it is < p;
    # the first token has zero mass before it, so it always survives
    keep_sorted = (cum - probs) < p
    # threshold back in logit space: the smallest surviving sorted logit
    n_keep = jnp.sum(keep_sorted, axis=-1, keepdims=True)
    thresh = jnp.take_along_axis(sorted_logits, n_keep - 1, axis=-1)
    return jnp.where(logits >= thresh, logits, NEG_INF)


def sample(logits: jax.Array, key: Optional[jax.Array] = None, *,
           temperature: float = 0.0, top_k: int = 0,
           top_p: float = 1.0) -> jax.Array:
    """logits (..., V) -> tokens (...) int32 through the standard filter
    pipeline.  temperature <= 0 is greedy argmax (key ignored - may be
    None); otherwise `key` is required and the draw is a categorical over
    the filtered, temperature-scaled logits."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / temperature
    scaled = apply_top_k(scaled, top_k)
    scaled = apply_top_p(scaled, top_p)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


def sample_chain(logits: jax.Array, key: Optional[jax.Array] = None, *,
                 temperature: float = 0.0, top_k: int = 0,
                 top_p: float = 1.0) -> jax.Array:
    """Per-position sampling for speculative verification: logits
    (K, S, V) -> tokens (K, S) int32, every (row, position) drawn with an
    INDEPENDENT key derived from `key` (greedy needs none).  Conditional
    on its prefix each position's token is distributed exactly as one
    non-speculative decode step would have drawn it."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    K, S, _ = logits.shape
    keys = jax.random.split(key, K * S).reshape(K, S, -1)
    scaled = logits / temperature
    scaled = apply_top_k(scaled, top_k)
    scaled = apply_top_p(scaled, top_p)
    return jax.vmap(jax.vmap(
        lambda k_, l_: jax.random.categorical(k_, l_)))(
            keys, scaled).astype(jnp.int32)


def speculative_accept(target_tokens: jax.Array, draft_tokens: jax.Array,
                       draft_lens: jax.Array
                       ) -> Tuple[jax.Array, jax.Array]:
    """Sample-and-compare acceptance for deterministic draft chains.

    target_tokens (K, S): the target model's sampled token at every
        chain position (position j conditions on the pending token and
        drafts 1..j, so target_tokens[:, j] is the token decoding would
        emit after accepting j drafts);
    draft_tokens  (K, S): row = [pending, d_1 .. d_m, pad...];
    draft_lens    (K,):   m per row (0 <= m <= S - 1).

    Returns (n_acc (K,), bonus (K,)): n_acc = length of the longest
    prefix of the draft chain matching the target's samples (capped at
    draft_lens); bonus = target_tokens[:, n_acc] - the correction token
    on first mismatch, or the free extra token when the whole chain
    matched.  Every verify launch therefore emits n_acc + 1 >= 1 tokens.
    """
    S = draft_tokens.shape[1]
    pos = jnp.arange(S - 1, dtype=jnp.int32)[None, :]
    m = jnp.asarray(draft_lens, jnp.int32)[:, None]
    match = (target_tokens[:, :-1] == draft_tokens[:, 1:]) & (pos < m)
    n_acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
    bonus = jnp.take_along_axis(target_tokens, n_acc[:, None],
                                axis=1)[:, 0]
    return n_acc, bonus
