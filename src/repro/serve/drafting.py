"""Self-speculative drafting: prompt-lookup (n-gram) draft proposal.

No second model: the drafter proposes a continuation by finding an
earlier occurrence of the sequence's own trailing n-gram and continuing
the pattern that followed it.  That is the prompt-lookup decoding trick
(and the self-drafting half of lookahead decoding): generation that
copies or paraphrases its context - retrieval answers, code completion,
structured output, or simply a model that has settled into a repeating
pattern - is predicted perfectly, while history with no repetition
simply yields no draft (and the request decodes normally that tick).

Pure host-side policy: tiny integer scans over token lists the host
already owns, no device work.  The engine verifies whatever is proposed
through the batched chunk kernel (serve/serve_step.py
make_spec_verify_step); a bad draft costs only its share of the tick's
token budget, never correctness - acceptance compares every draft token
against the token the target model itself samples at that position
(serve/sampling.py speculative_accept).
"""
from __future__ import annotations

from typing import List, Sequence


def ngram_draft(history: Sequence[int], max_draft: int,
                max_ngram: int) -> List[int]:
    """Propose up to `max_draft` tokens continuing `history` by suffix-
    shift prediction: for n = max_ngram down to 1, find the MOST RECENT
    earlier occurrence of the trailing n-gram; its distance p from the
    suffix is the local period, and the draft continues the pattern
    cyclically - token[t] = token[t - p] - for the full max_draft.
    Longer n-grams are preferred (a longer match is stronger evidence the
    pattern will continue) and the most recent occurrence wins (smallest
    shift = the freshest local pattern), so a sequence that has settled
    into a constant run or a period-p cycle is predicted perfectly for
    the whole draft, not just to the end of recorded history.  The match
    window may overlap the suffix itself (p < n is fine - that IS a
    short-period cycle).  Returns [] when history never repeats (the
    caller decodes that request normally this tick)."""
    h = list(history)
    L = len(h)
    if max_draft <= 0 or L < 2:
        return []
    for n in range(min(max_ngram, L - 1), 0, -1):
        suffix = h[L - n:]
        for i in range(L - n - 1, -1, -1):
            if h[i:i + n] == suffix:
                p = L - n - i
                out: List[int] = []
                for j in range(max_draft):
                    t = L + j - p
                    out.append(h[t] if t < L else out[t - L])
                return out
    return []
