"""Engine-wide telemetry: metrics registry, span tracer, per-launch
data-movement attribution, and a Perfetto-compatible trace exporter.

The paper's central claim is that DATA MOVEMENT, not FLOPs, dominates the
cost of attention (>60% of energy is on-chip SRAM access at long sequence
lengths).  Until this module the serving stack could only report coarse
aggregates - ad-hoc ``launch_log`` tuples and three different hand-rolled
``stats()`` dict conventions - so bytes-moved, KV pages touched, and tick
time could not be attributed to a specific request, phase, or kernel
launch.  This module is the one typed source of truth those surfaces now
sit on:

  MetricsRegistry   counters / gauges / histograms, each registered
                    EXACTLY ONCE with a help string (duplicate or
                    help-less registration raises).  Snapshots export as
                    JSON or Prometheus text exposition format.  The
                    engine, scheduler, page allocator, and prefix cache
                    all register into one shared registry per engine.

  SpanTracer        a bounded ring buffer of lifecycle spans and instant
                    events.  Every record is stamped in BOTH wall time
                    (seconds since the tracer's epoch) and the engine's
                    deterministic work clock (total prefill + decode
                    tokens executed), plus the tick index - so the
                    work-clock view of a replayed trace is bit-
                    reproducible and testable, while the wall-clock view
                    stays human-meaningful in Perfetto.

  LaunchRecord      per kernel launch: rows launched, true vs padded
                    tokens, and KV pages read / written - counted from
                    the PageAllocator's block-table accounting, so the
                    movement numbers are the allocator's, not a second
                    bookkeeping convention that can drift.

  movement_breakdown  a cost adapter over core/energy.py: converts launch
                    records into estimated HBM / SRAM bytes and energy
                    per launch kind (the serving analogue of the paper's
                    Fig. 6 data-movement breakdown).

  export_chrome_trace  Chrome trace-event JSON (the format Perfetto and
                    chrome://tracing load directly): request lifecycle
                    spans on per-slot tracks, engine phases and kernel
                    launches on engine tracks, preempt/resume/speculation
                    instants as arrows-free instant events.

Everything here is host-side Python over counts the engine already
computes: enabling telemetry adds ZERO jitted calls and ZERO device->host
syncs (asserted in tests/test_telemetry.py via the dispatch accounting),
and the spans themselves never read a device array.
"""
from __future__ import annotations

import json
import time
from collections import deque
from dataclasses import dataclass, field
from typing import (Any, Dict, Iterable, Iterator, List, Optional, Sequence,
                    Tuple)

__all__ = [
    "Counter", "Gauge", "Histogram", "LaunchRecord", "MetricError",
    "MetricsRegistry", "Span", "SpanTracer", "Telemetry", "TickRecord",
    "TraceEvent", "export_chrome_trace", "movement_breakdown",
]


# ===========================================================================
# metrics registry
# ===========================================================================

class MetricError(ValueError):
    """Raised on duplicate registration, a missing help string, or a
    label-shape mismatch - the registration-drift hazards the registry
    exists to make impossible."""


class _Metric:
    """Base: a named instrument with a mandatory help string.  Metrics
    with `labelnames` hold one value per observed label tuple (accessed
    through .labels(...)); unlabeled metrics hold a single value."""

    kind = "untyped"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = ()):
        if not name or not name.replace("_", "").isalnum():
            raise MetricError(f"invalid metric name {name!r}")
        if not help or not help.strip():
            raise MetricError(f"metric {name!r} registered without a help "
                              f"string")
        self.name = name
        self.help = help.strip()
        self.labelnames = tuple(labelnames)
        self._children: Dict[Tuple[str, ...], "_Metric"] = {}

    def labels(self, *values) -> "_Metric":
        """Child instrument for one label-value tuple (created lazily)."""
        if len(values) != len(self.labelnames):
            raise MetricError(
                f"{self.name}: got {len(values)} label values for "
                f"labels {self.labelnames}")
        key = tuple(str(v) for v in values)
        child = self._children.get(key)
        if child is None:
            child = type(self)(self.name, self.help)
            self._children[key] = child
        return child

    def label_items(self) -> List[Tuple[Tuple[str, ...], "_Metric"]]:
        return sorted(self._children.items())


class Counter(_Metric):
    """Monotone event count.  `set_total` exists ONLY so legacy attribute
    views (``engine.jit_calls += 1`` style) can write through the
    registry; it still refuses to run the counter backwards."""

    kind = "counter"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = ()):
        super().__init__(name, help, labelnames)
        self.value: float = 0

    def inc(self, n: float = 1):
        if n < 0:
            raise MetricError(f"{self.name}: counter increment {n} < 0")
        self.value += n

    def set_total(self, v: float):
        if v < self.value:
            raise MetricError(f"{self.name}: counter cannot decrease "
                              f"({self.value} -> {v})")
        self.value = v


class Gauge(_Metric):
    """Point-in-time value (queue depth, free pages, peak watermark)."""

    kind = "gauge"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = ()):
        super().__init__(name, help, labelnames)
        self.value: float = 0

    def set(self, v: float):
        self.value = v

    def max_update(self, v: float):
        """Watermark update: keep the high-water mark."""
        if v > self.value:
            self.value = v


class Histogram(_Metric):
    """Cumulative-bucket histogram (Prometheus semantics: each bucket
    counts observations <= its upper bound, plus the implicit +Inf)."""

    kind = "histogram"
    DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(buckets))
        if not self.buckets:
            raise MetricError(f"{name}: histogram needs >= 1 bucket")
        self.bucket_counts = [0] * (len(self.buckets) + 1)   # + Inf
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float):
        self.count += 1
        self.sum += v
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                self.bucket_counts[i] += 1
                return
        self.bucket_counts[-1] += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class MetricsRegistry:
    """One typed home for every metric an engine emits.  Registration is
    exactly-once (a second register of the same name raises MetricError),
    every metric carries a help string, and the whole registry exports as
    a JSON snapshot or Prometheus text - the drift-proofing the old three
    dict conventions lacked."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}

    # -- registration -----------------------------------------------------
    def _register(self, metric: _Metric) -> _Metric:
        if metric.name in self._metrics:
            raise MetricError(f"metric {metric.name!r} registered twice")
        self._metrics[metric.name] = metric
        return metric

    def counter(self, name: str, help: str,
                labelnames: Sequence[str] = ()) -> Counter:
        return self._register(Counter(name, help, labelnames))

    def gauge(self, name: str, help: str,
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._register(Gauge(name, help, labelnames))

    def histogram(self, name: str, help: str,
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = Histogram.DEFAULT_BUCKETS
                  ) -> Histogram:
        return self._register(Histogram(name, help, labelnames, buckets))

    # -- access -----------------------------------------------------------
    def get(self, name: str) -> _Metric:
        return self._metrics[name]

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def __iter__(self) -> Iterator[_Metric]:
        return iter(self._metrics[n] for n in self.names())

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def catalog(self) -> Dict[str, str]:
        """{name: help} for every registered metric (the doc-coverage
        check in tests/test_telemetry.py walks this)."""
        return {m.name: m.help for m in self}

    # -- export -----------------------------------------------------------
    @staticmethod
    def _scalar(v: float):
        return int(v) if float(v).is_integer() else float(v)

    def _metric_value(self, m: _Metric):
        if isinstance(m, Histogram):
            return {"buckets": list(m.buckets),
                    "bucket_counts": list(m.bucket_counts),
                    "count": m.count, "sum": m.sum, "mean": m.mean}
        if m.labelnames:
            return {",".join(k): self._scalar(c.value)
                    for k, c in m.label_items()}
        return self._scalar(m.value)

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """JSON-ready snapshot: {name: {kind, help, value}}."""
        return {m.name: {"kind": m.kind, "help": m.help,
                         "value": self._metric_value(m)}
                for m in self}

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (one # HELP / # TYPE pair
        per metric; labeled metrics render one sample per label tuple)."""
        out: List[str] = []
        for m in self:
            out.append(f"# HELP {m.name} {m.help}")
            out.append(f"# TYPE {m.name} {m.kind}")
            if isinstance(m, Histogram):
                cum = 0
                for ub, c in zip(m.buckets, m.bucket_counts):
                    cum += c
                    out.append(f'{m.name}_bucket{{le="{ub}"}} {cum}')
                out.append(f'{m.name}_bucket{{le="+Inf"}} {m.count}')
                out.append(f"{m.name}_sum {m.sum}")
                out.append(f"{m.name}_count {m.count}")
            elif m.labelnames:
                for key, child in m.label_items():
                    lbl = ",".join(f'{n}="{v}"'
                                   for n, v in zip(m.labelnames, key))
                    out.append(f"{m.name}{{{lbl}}} "
                               f"{self._scalar(child.value)}")
            else:
                out.append(f"{m.name} {self._scalar(m.value)}")
        return "\n".join(out) + "\n"


# ===========================================================================
# spans and events
# ===========================================================================

# track ids for the Chrome-trace export: requests live on per-slot tracks
# (track = slot index); these engine-level tracks sit alongside them
TRACK_ENGINE = -1      # per-tick engine phases (plan / launches / fetch)
TRACK_QUEUE = -2       # requests waiting for admission (QUEUED / RESUMING)


@dataclass(frozen=True)
class Span:
    """One closed interval: a request lifecycle phase or an engine tick
    phase.  `work0`/`work1` are deterministic work-clock stamps; `wall0`/
    `wall1` are seconds since the tracer's epoch."""
    name: str
    cat: str                     # "request" | "tick" | "launch"
    track: int                   # slot index, TRACK_ENGINE, or TRACK_QUEUE
    tick: int                    # engine tick index at span START
    work0: int
    work1: int
    wall0: float
    wall1: float
    args: Tuple[Tuple[str, Any], ...] = ()

    def deterministic_key(self) -> tuple:
        """Everything but the wall stamps - the bit-reproducible view."""
        return ("span", self.name, self.cat, self.track, self.tick,
                self.work0, self.work1, self.args)


@dataclass(frozen=True)
class TraceEvent:
    """One instant: PREEMPT, RESUME, FINISH, SPEC_VERIFY, prefix-cache
    hit/evict - anything with a moment but no duration."""
    name: str
    cat: str
    track: int
    tick: int
    work: int
    wall: float
    args: Tuple[Tuple[str, Any], ...] = ()

    def deterministic_key(self) -> tuple:
        return ("event", self.name, self.cat, self.track, self.tick,
                self.work, self.args)


def _freeze_args(kw: Dict[str, Any]) -> Tuple[Tuple[str, Any], ...]:
    return tuple(sorted(kw.items()))


class SpanTracer:
    """Bounded ring buffer of spans and instant events, in record order.
    When full the OLDEST records drop (and are counted in `dropped`), so
    a long-running engine's tracer is a flight recorder, not a leak."""

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"tracer capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._buf: deque = deque(maxlen=capacity)
        self.dropped = 0
        self.epoch = time.perf_counter()

    def now(self) -> float:
        """Wall seconds since this tracer's epoch."""
        return time.perf_counter() - self.epoch

    def _append(self, rec):
        if len(self._buf) == self.capacity:
            self.dropped += 1
        self._buf.append(rec)

    def add_span(self, name: str, cat: str, track: int, tick: int,
                 work0: int, work1: int, wall0: float, wall1: float,
                 **args):
        self._append(Span(name, cat, track, tick, int(work0), int(work1),
                          wall0, wall1, _freeze_args(args)))

    def add_event(self, name: str, cat: str, track: int, tick: int,
                  work: int, wall: float, **args):
        self._append(TraceEvent(name, cat, track, tick, int(work), wall,
                                _freeze_args(args)))

    def records(self) -> List[Any]:
        return list(self._buf)

    def spans(self) -> List[Span]:
        return [r for r in self._buf if isinstance(r, Span)]

    def events(self) -> List[TraceEvent]:
        return [r for r in self._buf if isinstance(r, TraceEvent)]

    def __len__(self) -> int:
        return len(self._buf)

    def deterministic_trace(self) -> List[tuple]:
        """The wall-clock-free view of every record, in order: two replays
        of the same seeded traffic trace must produce EXACTLY this list
        (asserted in tests/test_telemetry.py)."""
        return [r.deterministic_key() for r in self._buf]


# ===========================================================================
# per-launch data-movement records
# ===========================================================================

@dataclass(frozen=True)
class LaunchRecord:
    """Data-movement attribution for one kernel launch.  Page counts come
    from the PageAllocator's block-table accounting (the engine counts
    mapped pages over each row's true span), so they can be cross-checked
    exactly against ceil(true_len / page_size) math - one source of
    truth, not a parallel convention."""
    tick: int
    kind: str                # prefill | prefill_paged | chunk | chunk_batch
    #                          | decode | spec_verify | stepwise
    rows: int                # kernel rows launched (after pow2 bucketing)
    live_rows: int           # rows carrying real work
    true_tokens: int         # real query tokens computed
    padded_tokens: int       # rows * row width (incl. bucket/pad waste)
    kv_pages_read: int       # pages the launch's attention reads
    kv_pages_written: int    # pages its K/V writes touch
    new_kv_tokens: int       # KV positions written (true)
    work_clock: int          # scheduler work clock AFTER the launch


@dataclass(frozen=True)
class TickRecord:
    """One tick's dispatch accounting - the typed record behind the
    legacy ``launch_log`` 5-tuple compatibility view."""
    jit_calls: int
    host_syncs: int
    host_wall_s: float
    n_chunk_tasks: int
    n_decode: int

    def as_tuple(self) -> tuple:
        return (self.jit_calls, self.host_syncs, self.host_wall_s,
                self.n_chunk_tasks, self.n_decode)


# ===========================================================================
# telemetry facade (what the engine holds)
# ===========================================================================

class Telemetry:
    """One engine's telemetry surface: the shared metrics registry
    (always on - it IS the stats() backing store), the span tracer
    (optional, ServeConfig.telemetry), per-launch movement records, and
    the per-tick dispatch records behind the launch_log view."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[SpanTracer] = None,
                 launch_capacity: int = 65536):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer
        self.launches: deque = deque(maxlen=launch_capacity)
        self.ticks: List[TickRecord] = []
        # open request-phase spans: uid -> (phase, track, tick0, work0, wall0)
        self._open: Dict[int, tuple] = {}

    @property
    def enabled(self) -> bool:
        return self.tracer is not None

    # -- request lifecycle -------------------------------------------------
    def request_phase(self, uid: int, phase: str, track: int, tick: int,
                      work: int, **args):
        """Close the request's open phase span (if any) and open `phase`.
        Terminal phases (DONE) close without opening.  No-op with the
        tracer off."""
        tr = self.tracer
        if tr is None:
            return
        wall = tr.now()
        open_ = self._open.pop(uid, None)
        if open_ is not None:
            old_phase, old_track, tick0, work0, wall0 = open_
            tr.add_span(f"r{uid}:{old_phase}", "request", old_track, tick0,
                        work0, work, wall0, wall, uid=uid, phase=old_phase)
        if phase == "DONE":
            tr.add_event(f"r{uid}:DONE", "request",
                         open_[1] if open_ else track, tick, work, wall,
                         uid=uid, **args)
        else:
            self._open[uid] = (phase, track, tick, work, wall)

    def request_event(self, uid: int, name: str, track: int, tick: int,
                      work: int, **args):
        tr = self.tracer
        if tr is not None:
            tr.add_event(f"r{uid}:{name}", "request", track, tick, work,
                         tr.now(), uid=uid, **args)

    def open_phases(self) -> Dict[int, str]:
        """uid -> open phase name (diagnostics; drained traces are empty)."""
        return {uid: rec[0] for uid, rec in self._open.items()}

    # -- launches ----------------------------------------------------------
    def launch(self, rec: LaunchRecord, wall0: float, wall1: float):
        """Record one kernel launch: a movement record plus a span on the
        engine track."""
        self.launches.append(rec)
        tr = self.tracer
        if tr is not None:
            tr.add_span(rec.kind, "launch", TRACK_ENGINE, rec.tick,
                        rec.work_clock, rec.work_clock, wall0, wall1,
                        rows=rec.rows, live_rows=rec.live_rows,
                        true_tokens=rec.true_tokens,
                        padded_tokens=rec.padded_tokens,
                        kv_pages_read=rec.kv_pages_read,
                        kv_pages_written=rec.kv_pages_written)


# ===========================================================================
# movement attribution: launch records -> HBM / SRAM bytes and energy
# ===========================================================================

def _kv_token_bytes(cfg) -> int:
    """Bytes of K+V one token holds across every layer."""
    import jax.numpy as jnp
    itemsize = jnp.dtype(cfg.dtype).itemsize
    return 2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim * itemsize


def movement_breakdown(launches: Iterable[LaunchRecord], cfg, scfg,
                       energy_table=None,
                       tp_degree: int = 1) -> Dict[str, Dict[str, float]]:
    """Fold per-launch movement records into a paper-style (Fig. 6)
    data-movement breakdown per launch kind, in estimated HBM and SRAM
    bytes and energy.

    tp_degree > 1 adds a "per_device" section attributing the totals to
    ONE device of a head-sharded tensor-parallel engine: KV bytes divide
    by tp_degree (each shard streams only its Hkv/tp head slice of every
    page), while weights, activations, and the block table are replicated
    - every device streams them in full, which is exactly the replication
    overhead the serve_bench --tp inequality charges against the split.

    The byte model is a first-order serving roofline, not a device
    counter (benchmarks/roofline.py makes the same tradeoff):

      KV read    pages_read * page_size tokens of K+V stream from HBM
      KV write   every newly written KV position streams back once
      weights    each launch streams the active parameters once
      acts       every padded query token moves one d_model activation
                 vector in and out per layer
      SRAM       every HBM byte is staged through on-chip SRAM once in
                 and once out (the flash kernels are single-pass by
                 construction, so 2x is the floor, not a guess)

    Energy folds the byte totals through core/energy.py's per-action
    table (e_dram_byte / e_sram_byte), the same constants the paper-
    reproduction figures use.  `padding_overhead` is the fraction of
    moved query tokens that were bucket/row padding - the cost of the
    power-of-two compile-shape bucketing, made visible per kind.
    """
    import jax.numpy as jnp

    from ..core.energy import Activity, EnergyTable, energy_of

    tbl = energy_table if energy_table is not None \
        else EnergyTable.default16nm()
    itemsize = jnp.dtype(cfg.dtype).itemsize
    kv_tok = _kv_token_bytes(cfg)
    weight_bytes_per_launch = cfg.active_param_count() * itemsize
    act_tok = 2 * cfg.n_layers * cfg.d_model * itemsize

    kinds: Dict[str, Dict[str, float]] = {}
    for rec in launches:
        row = kinds.setdefault(rec.kind, {
            "launches": 0, "rows": 0, "live_rows": 0, "true_tokens": 0,
            "padded_tokens": 0, "kv_pages_read": 0, "kv_pages_written": 0,
            "new_kv_tokens": 0, "kv_read_bytes": 0.0, "kv_write_bytes": 0.0,
            "weight_bytes": 0.0, "act_bytes": 0.0, "hbm_bytes": 0.0,
            "sram_bytes": 0.0, "energy_j": 0.0, "padding_overhead": 0.0})
        row["launches"] += 1
        row["rows"] += rec.rows
        row["live_rows"] += rec.live_rows
        row["true_tokens"] += rec.true_tokens
        row["padded_tokens"] += rec.padded_tokens
        row["kv_pages_read"] += rec.kv_pages_read
        row["kv_pages_written"] += rec.kv_pages_written
        row["new_kv_tokens"] += rec.new_kv_tokens
        row["kv_read_bytes"] += rec.kv_pages_read * scfg.page_size * kv_tok
        row["kv_write_bytes"] += rec.new_kv_tokens * kv_tok
        row["weight_bytes"] += weight_bytes_per_launch
        row["act_bytes"] += rec.padded_tokens * act_tok

    total = {k: 0.0 for k in ("launches", "rows", "live_rows",
                              "true_tokens", "padded_tokens",
                              "kv_pages_read", "kv_pages_written",
                              "new_kv_tokens", "kv_read_bytes",
                              "kv_write_bytes", "weight_bytes", "act_bytes",
                              "hbm_bytes", "sram_bytes", "energy_j")}
    for row in kinds.values():
        row["hbm_bytes"] = (row["kv_read_bytes"] + row["kv_write_bytes"]
                            + row["weight_bytes"] + row["act_bytes"])
        row["sram_bytes"] = 2.0 * row["hbm_bytes"]
        row["energy_j"] = energy_of(
            Activity(dram_bytes=row["hbm_bytes"],
                     sram_bytes=row["sram_bytes"]), tbl).total
        row["padding_overhead"] = (
            1.0 - row["true_tokens"] / row["padded_tokens"]
            if row["padded_tokens"] else 0.0)
        for k in total:
            total[k] += row[k]
    total["padding_overhead"] = (
        1.0 - total["true_tokens"] / total["padded_tokens"]
        if total["padded_tokens"] else 0.0)
    if total["hbm_bytes"]:
        for row in kinds.values():
            row["hbm_share"] = row["hbm_bytes"] / total["hbm_bytes"]
    kinds["total"] = total
    if tp_degree > 1:
        per_dev_hbm = ((total["kv_read_bytes"] + total["kv_write_bytes"])
                       / tp_degree
                       + total["weight_bytes"] + total["act_bytes"])
        kinds["per_device"] = {
            "tp_degree": float(tp_degree),
            "kv_read_bytes": total["kv_read_bytes"] / tp_degree,
            "kv_write_bytes": total["kv_write_bytes"] / tp_degree,
            "weight_bytes": total["weight_bytes"],     # replicated
            "act_bytes": total["act_bytes"],           # replicated
            "hbm_bytes": per_dev_hbm,
            "sram_bytes": 2.0 * per_dev_hbm,
            "energy_j": energy_of(
                Activity(dram_bytes=per_dev_hbm,
                         sram_bytes=2.0 * per_dev_hbm), tbl).total,
        }
    return kinds


# ===========================================================================
# Chrome trace-event export (Perfetto / chrome://tracing)
# ===========================================================================

_PID_ENGINE = 0
_PID_REQUESTS = 1
# engine-track tids inside the engine process
_TID_TICKS = 0
_TID_LAUNCHES = 1


def _track_ids(track: int, n_slots: int) -> Tuple[int, int]:
    """Map a telemetry track to a (pid, tid) pair: engine phases and
    launches live in the engine process; request phases live in the
    requests process, one thread per slot, with the admission queue as
    the thread after the last slot."""
    if track == TRACK_ENGINE:
        return _PID_ENGINE, _TID_LAUNCHES
    if track == TRACK_QUEUE:
        return _PID_REQUESTS, n_slots
    return _PID_REQUESTS, track


def export_chrome_trace(path, tracer: SpanTracer, n_slots: int,
                        clock: str = "wall") -> Dict[str, Any]:
    """Write the tracer's records as Chrome trace-event JSON - the format
    Perfetto (ui.perfetto.dev) and chrome://tracing open directly.

    `clock` selects the timestamp domain: "wall" (microseconds of wall
    time since the tracer epoch - the human view) or "work" (the
    deterministic work clock, one microsecond per work token - the view
    that is bit-identical across replays of the same trace).  Returns
    the trace dict it wrote; pass path=None to skip writing.
    """
    if clock not in ("wall", "work"):
        raise ValueError(f"clock must be 'wall' or 'work', got {clock!r}")

    def ts_span(s: Span) -> Tuple[float, float]:
        if clock == "wall":
            return s.wall0 * 1e6, max((s.wall1 - s.wall0) * 1e6, 0.0)
        return float(s.work0), float(max(s.work1 - s.work0, 0))

    def ts_event(e: TraceEvent) -> float:
        return e.wall * 1e6 if clock == "wall" else float(e.work)

    events: List[Dict[str, Any]] = [
        {"ph": "M", "pid": _PID_ENGINE, "tid": 0, "name": "process_name",
         "args": {"name": "engine"}},
        {"ph": "M", "pid": _PID_ENGINE, "tid": _TID_TICKS,
         "name": "thread_name", "args": {"name": "ticks"}},
        {"ph": "M", "pid": _PID_ENGINE, "tid": _TID_LAUNCHES,
         "name": "thread_name", "args": {"name": "launches"}},
        {"ph": "M", "pid": _PID_REQUESTS, "tid": 0, "name": "process_name",
         "args": {"name": "requests"}},
        {"ph": "M", "pid": _PID_REQUESTS, "tid": n_slots,
         "name": "thread_name", "args": {"name": "queue"}},
    ]
    for slot in range(n_slots):
        events.append({"ph": "M", "pid": _PID_REQUESTS, "tid": slot,
                       "name": "thread_name",
                       "args": {"name": f"slot{slot}"}})
    for rec in tracer.records():
        if isinstance(rec, Span):
            pid, tid = _track_ids(rec.track, n_slots)
            if rec.cat == "tick":
                pid, tid = _PID_ENGINE, _TID_TICKS
            ts, dur = ts_span(rec)
            events.append({"ph": "X", "name": rec.name, "cat": rec.cat,
                           "pid": pid, "tid": tid, "ts": ts, "dur": dur,
                           "args": dict(rec.args)})
        else:
            pid, tid = _track_ids(rec.track, n_slots)
            events.append({"ph": "i", "s": "t", "name": rec.name,
                           "cat": rec.cat, "pid": pid, "tid": tid,
                           "ts": ts_event(rec), "args": dict(rec.args)})
    trace = {"traceEvents": events, "displayTimeUnit": "ms",
             "otherData": {"clock": clock,
                           "dropped_records": tracer.dropped}}
    if path is not None:
        with open(path, "w") as f:
            json.dump(trace, f, indent=None, separators=(",", ":"))
    return trace
