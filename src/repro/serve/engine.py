"""Continuous-batching serve engine (dense or paged KV cache).

Slot-based scheduler: up to `max_batch` concurrent sequences share one
batched KV cache; new requests are prefilled into free slots; every tick
runs one batched decode step for all active slots; finished sequences free
their slot immediately (no head-of-line blocking).

Two cache modes (ServeConfig.paged):

  dense  one (L, max_batch, max_seq, Hkv, D) strip per K and V - every slot
         reserves max_seq worth of KV whether it needs it or not.
  paged  a global page pool + block table (serve/paged_cache.py): a request
         holds ceil((prompt + max_new) / page_size) pages from admission to
         completion and returns them the tick it finishes, so mixed-length
         traffic fits far more concurrent sequences in the same KV bytes.
         Admission reserves the worst case up front; when the free list
         cannot cover it the request simply stays queued (backpressure) -
         nothing mid-flight can run out of pages.

Prefill: attention families run one batched prefill over the (padded)
prompt - real length travels in batch["true_lens"] so logits come from the
last REAL token; recurrent families (ssm / hybrid / audio) keep the exact
token-by-token path.

Prefix caching (ServeConfig.prefix_cache, paged mode only): finished
requests publish their prompt pages into a radix tree
(serve/prefix_cache.py) instead of freeing them; admission matches the
longest cached prefix, attaches those pages to the slot (refcounted), and
prefills ONLY the uncached suffix - suffix queries attend over the cached
pages through the block table.  A fully cached prompt recomputes just its
last token for logits, copy-on-writing the final shared page first.  When
the free list runs low, unreferenced cached pages are LRU-evicted back to
the pool, so caching never blocks an admission plain paged serving could
have made.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ServeConfig
from ..models import Model, build_model
from .paged_cache import PageAllocator, pages_needed
from .prefix_cache import RadixPrefixCache
from .serve_step import (make_paged_prefill_step, make_prefill_step,
                         make_serve_step, make_suffix_prefill_step,
                         sample_token)

# attention-family prompts are padded to a multiple of this before the
# batched prefill, bounding jit recompiles to one per bucket
PREFILL_BUCKET = 16


@dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, scfg: ServeConfig):
        self.model = model
        self.params = params
        self.scfg = scfg
        cfg = model.cfg
        B = scfg.max_batch
        self.paged = scfg.paged
        self._attention_family = cfg.family in ("dense", "moe", "vlm")
        self.prefix: Optional[RadixPrefixCache] = None
        if scfg.prefix_cache and not scfg.paged:
            raise ValueError("prefix_cache requires paged=True")
        if self.paged:
            if model.prefill_paged is None:
                raise ValueError(f"paged serving needs an attention family, "
                                 f"got {cfg.family}")
            if scfg.max_seq % scfg.page_size:
                # the page-multiple invariant (attn_prefill_paged reshapes
                # prompts into whole pages) must hold at the max_seq cap too
                raise ValueError(
                    f"max_seq ({scfg.max_seq}) must be a multiple of "
                    f"page_size ({scfg.page_size})")
            num_pages = scfg.pool_pages()
            self.allocator = PageAllocator(num_pages, scfg.page_size, B,
                                           scfg.max_seq)
            self.cache = model.init_cache(B, scfg.max_seq,
                                          page_size=scfg.page_size,
                                          num_pages=num_pages)
            if scfg.prefix_cache:
                self.prefix = RadixPrefixCache(self.allocator,
                                               scfg.page_size)
        else:
            self.allocator = None
            self.cache = model.init_cache(B, scfg.max_seq,
                                          enc_len=scfg.max_seq)
        # metrics (all modes; prefix_* stay 0 without the prefix cache)
        self.peak_pages = 0          # pool pages in use, incl. cached
        self.peak_live_pages = 0     # distinct pages referenced by slots
        self.prefill_tokens = 0      # prompt tokens actually computed
        self.prefix_hit_tokens = 0   # prompt tokens served from the cache
        self.cow_copies = 0          # copy-on-write page copies
        self.lens = jnp.zeros((B,), jnp.int32)
        self.slots: List[Optional[Request]] = [None] * B
        self.tokens = jnp.zeros((B, 1), jnp.int32)
        self.queue: List[Request] = []
        self._uid = 0

        # donate the cache through the jit boundary so a tick updates the
        # KV pool in place instead of transiently doubling it (donation is
        # unimplemented on CPU - skip there to avoid per-call warnings)
        def _jit_donating_cache(fn, cache_argnum):
            if jax.default_backend() == "cpu":
                return jax.jit(fn)
            return jax.jit(fn, donate_argnums=(cache_argnum,))

        self._decode = _jit_donating_cache(make_serve_step(model), 1)
        self._prefill = _jit_donating_cache(make_prefill_step(model), 2)
        if self.paged:
            self._prefill_paged = _jit_donating_cache(
                make_paged_prefill_step(model), 2)
            self._prefill_suffix = _jit_donating_cache(
                make_suffix_prefill_step(model), 2)

    # ------------------------------------------------------------------
    def submit(self, prompt: List[int],
               max_new_tokens: Optional[int] = None) -> int:
        """Enqueue a request.  Everything that can never be served -
        empty prompt, zero generation budget, overflowing max_seq, a page
        reservation larger than the engine can ever grant - fails HERE
        with a clear error instead of deep inside prefill or the
        allocator."""
        n_new = self.scfg.max_new_tokens if max_new_tokens is None \
            else max_new_tokens
        if not prompt:
            raise ValueError("empty prompt")
        if n_new < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {n_new}")
        if len(prompt) + n_new > self.scfg.max_seq:
            raise ValueError(
                f"request does not fit: {len(prompt)} prompt + {n_new} new "
                f"tokens > max_seq {self.scfg.max_seq}")
        if self.paged:
            need = pages_needed(len(prompt) + n_new, self.scfg.page_size)
            usable = min(self.allocator.max_pages_per_seq,
                         self.allocator.num_pages - 1)
            if need > usable:
                # backpressure cannot help a reservation larger than the
                # whole pool - fail fast instead of queueing forever
                raise ValueError(
                    f"request needs {need} pages; the engine can grant at "
                    f"most {usable} (pool {self.allocator.num_pages}, "
                    f"max_seq {self.scfg.max_seq}, page "
                    f"{self.scfg.page_size})")
        self._uid += 1
        self.queue.append(Request(self._uid, list(prompt), n_new))
        return self._uid

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def prefix_stats(self) -> Dict[str, int]:
        """Prefill / prefix-cache counters (zeros when caching is off)."""
        total = self.prefill_tokens + self.prefix_hit_tokens
        return {"prefill_tokens": self.prefill_tokens,
                "prefix_hit_tokens": self.prefix_hit_tokens,
                "prompt_tokens": total,
                "cow_copies": self.cow_copies,
                "cached_pages": self.prefix.cached_pages
                if self.prefix is not None else 0,
                "peak_pages": self.peak_pages,
                "peak_live_pages": self.peak_live_pages}

    def kv_cache_bytes(self) -> int:
        """Allocated cache bytes, every leaf: KV strips or pages, block
        table, and recurrent state for ssm/hybrid/audio families.  Caches
        are preallocated, so allocated == peak."""
        return sum(int(np.prod(leaf.shape))
                   * jnp.dtype(leaf.dtype).itemsize
                   for leaf in jax.tree_util.tree_leaves(self.cache))

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _admit(self):
        """Prefill queued requests into free slots.  FIFO; stops at the
        first request that cannot be placed (no slot, or - paged - not
        enough free pages: backpressure, it stays queued)."""
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            if self.paged:
                if not self._admit_paged(slot):
                    return
            elif self._attention_family:
                self._admit_prefill(slot)
            else:
                self._admit_stepwise(slot)

    def _padded_prompt(self, prompt: List[int], bucket: int):
        s_real = len(prompt)
        s_pad = min(-(-s_real // bucket) * bucket, self.scfg.max_seq)
        toks = np.zeros((1, s_pad), np.int32)
        toks[0, :s_real] = prompt
        return jnp.asarray(toks), s_real

    def _place(self, slot: int, req: Request, logits, s_real: int):
        """Common tail of every admission path: record the slot state and
        sample the first generated token from the prompt's last logits."""
        self.lens = self.lens.at[slot].set(s_real)
        nxt = int(sample_token(logits)[0, 0])
        req.out_tokens.append(nxt)
        self.tokens = self.tokens.at[slot, 0].set(nxt)
        self.slots[slot] = req

    def _admit_prefill(self, slot: int):
        """Dense cache, attention family: one batched prefill into a
        sub-cache sized to the padded prompt, scattered into the slot row."""
        req = self.queue.pop(0)
        toks, s_real = self._padded_prompt(req.prompt, PREFILL_BUCKET)
        s_pad = toks.shape[1]
        sub = self.model.init_cache(1, s_pad)
        batch = {"tokens": toks, "true_lens": jnp.asarray([s_real])}
        logits, sub, _ = self._prefill(self.params, batch, sub)
        self.cache["k"] = self.cache["k"].at[:, slot, :s_pad].set(
            sub["k"][:, 0])
        self.cache["v"] = self.cache["v"].at[:, slot, :s_pad].set(
            sub["v"][:, 0])
        self.prefill_tokens += s_real
        self._place(slot, req, logits, s_real)

    def _note_alloc(self):
        self.peak_pages = max(self.peak_pages, self.allocator.used_pages)
        self.peak_live_pages = max(self.peak_live_pages,
                                   self.allocator.live_pages())

    def _ensure_free(self, n: int, protect=frozenset()) -> bool:
        """True if n pages are (or can be made) free.  With the prefix
        cache, LRU-evicts unreferenced cached pages - never `protect`
        (pages about to be attached) or anything a slot references."""
        if self.allocator.can_alloc(n):
            return True
        if self.prefix is not None:
            self.prefix.evict(n - self.allocator.free_pages,
                              protect=frozenset(protect))
        return self.allocator.can_alloc(n)

    def _copy_page(self, src: int, dst: int):
        """Device-side copy of one page across every layer's K and V slab
        (the data half of copy-on-write; the allocator did the
        bookkeeping)."""
        for key in ("k_pages", "v_pages"):
            slab = self.cache[key]
            self.cache[key] = slab.at[:, dst].set(slab[:, src])

    def _admit_paged(self, slot: int) -> bool:
        """Paged cache: reserve the request's worst case up front; prefill
        the prompt straight into its pages.  False = out of pages.
        (Reservations that can never fit were rejected at submit time.)"""
        if self.prefix is not None:
            return self._admit_prefix(slot)
        req = self.queue[0]
        scfg = self.scfg
        need = pages_needed(len(req.prompt) + req.max_new_tokens,
                            scfg.page_size)
        if not self.allocator.can_alloc(need):
            return False
        self.queue.pop(0)
        pages = self.allocator.alloc(slot, need)
        self._note_alloc()
        toks, s_real = self._padded_prompt(req.prompt, scfg.page_size)
        page_ids = jnp.asarray(pages[:toks.shape[1] // scfg.page_size],
                               jnp.int32)
        self.cache["block_table"] = self.allocator.table_device()
        batch = {"tokens": toks, "true_lens": jnp.asarray([s_real])}
        logits, self.cache, _ = self._prefill_paged(
            self.params, batch, self.cache, page_ids)
        self.prefill_tokens += s_real
        self._place(slot, req, logits, s_real)
        return True

    def _admit_prefix(self, slot: int) -> bool:
        """Prefix-cached admission: attach the longest cached prefix,
        allocate pages for the rest of the reservation, prefill only the
        uncached suffix.  False = out of pages even after eviction."""
        req = self.queue[0]
        scfg = self.scfg
        ps = scfg.page_size
        P = len(req.prompt)
        matched = self.prefix.match(req.prompt)
        # a fully cached prompt still recomputes its LAST token (we need
        # its logits to start decoding); that token's K/V write lands in
        # the final cached page, which therefore gets a private
        # copy-on-write copy instead of being attached
        full_cover = bool(matched) and len(matched) * ps >= P
        shared = matched[:-1] if full_cover else matched
        need_total = pages_needed(P + req.max_new_tokens, ps)
        n_fresh = need_total - len(shared)
        if not self._ensure_free(n_fresh, protect=matched):
            return False
        self.queue.pop(0)
        if shared:
            self.allocator.attach(slot, shared)
        owned = self.allocator.alloc(slot, n_fresh)
        if full_cover:
            self._copy_page(matched[-1], owned[len(shared)])
            self.cow_copies += 1
        self._note_alloc()
        suffix_start = P - 1 if full_cover else len(shared) * ps
        suffix = req.prompt[suffix_start:]
        s_pad = -(-len(suffix) // ps) * ps
        toks = np.zeros((1, s_pad), np.int32)
        toks[0, :len(suffix)] = suffix
        self.cache["block_table"] = self.allocator.table_device()
        page_row = jnp.asarray(self.allocator.table[slot], jnp.int32)
        batch = {"tokens": jnp.asarray(toks),
                 "offset": jnp.asarray([suffix_start], jnp.int32),
                 "true_lens": jnp.asarray([P], jnp.int32)}
        logits, self.cache, _ = self._prefill_suffix(
            self.params, batch, self.cache, page_row)
        self.prefill_tokens += len(suffix)
        self.prefix_hit_tokens += P - len(suffix)
        self._place(slot, req, logits, P)
        return True

    def _admit_stepwise(self, slot: int):
        """Token-by-token prefill through decode_step (exact for every
        architecture family, including recurrent state caches)."""
        req = self.queue.pop(0)
        lens = self.lens
        cache = self.cache
        last_logits = None
        for t in req.prompt:
            tok = self.tokens.at[slot, 0].set(t)
            pos = lens
            logits, cache = self._decode(self.params, cache, tok, pos)
            lens = lens.at[slot].add(1)
            last_logits = logits
        self.cache, self.lens = cache, lens
        self.prefill_tokens += len(req.prompt)
        nxt = int(sample_token(last_logits)[slot, 0]) \
            if last_logits is not None else 0
        req.out_tokens.append(nxt)
        self.tokens = self.tokens.at[slot, 0].set(nxt)
        self.slots[slot] = req

    # ------------------------------------------------------------------
    def _cow_guard(self):
        """Give any slot about to WRITE into a shared page a private copy
        first.  By construction generation pages are private (the one
        structural COW happens at admission), so this is a cheap defensive
        sweep - but it makes 'decode never corrupts a cached page' an
        invariant of the tick loop rather than of the admission math."""
        ps = self.scfg.page_size
        lens = np.asarray(self.lens)
        dirty = False
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            idx = int(lens[i]) // ps
            page = int(self.allocator.table[i, idx])
            if self.allocator.refcount(page) > 1:
                src, dst = self.allocator.cow(i, idx)
                self._copy_page(src, dst)
                self.cow_copies += 1
                dirty = True
        if dirty:
            self.cache["block_table"] = self.allocator.table_device()

    def tick(self) -> List[Request]:
        """One engine iteration: admit + one batched decode step.
        Returns requests that finished this tick."""
        self._admit()
        if not any(s is not None for s in self.slots):
            return []
        if self.prefix is not None:
            self._cow_guard()
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.tokens, self.lens)
        next_tokens = sample_token(logits)
        finished = []
        new_tokens = self.tokens
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.lens = self.lens.at[i].add(1)
            tok = int(next_tokens[i, 0])
            req.out_tokens.append(tok)
            new_tokens = new_tokens.at[i, 0].set(tok)
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                finished.append(req)
                self.slots[i] = None
                self.lens = self.lens.at[i].set(0)
                if self.prefix is not None:
                    # prompt pages go into the radix tree; the partial
                    # tail page and generation pages return to the pool
                    self.prefix.release(i, req.prompt)
                elif self.paged:
                    # pages go back to the pool the tick the request ends
                    self.allocator.free_slot(i)
        if finished and self.paged:
            if self.prefix is not None \
                    and self.scfg.prefix_evict_watermark > 0:
                usable = self.allocator.num_pages - 1
                target = math.ceil(self.scfg.prefix_evict_watermark * usable)
                short = target - self.allocator.free_pages
                if short > 0:
                    self.prefix.evict(short)
            self.cache["block_table"] = self.allocator.table_device()
        self.tokens = new_tokens
        return finished

    def run_until_done(self, max_ticks: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_ticks):
            done.extend(self.tick())
            if not self.queue and all(s is None for s in self.slots):
                break
        return done
