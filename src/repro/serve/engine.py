"""Continuous-batching serve engine.

Slot-based scheduler: up to `max_batch` concurrent sequences share one
batched KV cache; new requests are prefilled into free slots; every tick
runs one batched decode step for all active slots; finished sequences free
their slot immediately (no head-of-line blocking).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ServeConfig
from ..models import Model, build_model
from .serve_step import sample_token


@dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, scfg: ServeConfig):
        self.model = model
        self.params = params
        self.scfg = scfg
        cfg = model.cfg
        B = scfg.max_batch
        self.cache = model.init_cache(B, scfg.max_seq, enc_len=scfg.max_seq)
        self.lens = jnp.zeros((B,), jnp.int32)
        self.slots: List[Optional[Request]] = [None] * B
        self.tokens = jnp.zeros((B, 1), jnp.int32)
        self.queue: List[Request] = []
        self._uid = 0

        self._decode = jax.jit(
            lambda p, c, t, l: model.decode_step(p, t, l, c))

    # ------------------------------------------------------------------
    def submit(self, prompt: List[int],
               max_new_tokens: Optional[int] = None) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, list(prompt),
                                  max_new_tokens or self.scfg.max_new_tokens))
        return self._uid

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _admit(self):
        """Prefill queued requests into free slots, token by token (exact for
        every architecture family, including recurrent state caches)."""
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            req = self.queue.pop(0)
            lens = self.lens
            cache = self.cache
            last_logits = None
            for t in req.prompt:
                tok = self.tokens.at[slot, 0].set(t)
                pos = lens
                logits, cache = self._decode(self.params, cache, tok, pos)
                lens = lens.at[slot].add(1)
                last_logits = logits
            self.cache, self.lens = cache, lens
            nxt = int(sample_token(last_logits)[slot, 0]) \
                if last_logits is not None else 0
            req.out_tokens.append(nxt)
            self.tokens = self.tokens.at[slot, 0].set(nxt)
            self.slots[slot] = req

    # ------------------------------------------------------------------
    def tick(self) -> List[Request]:
        """One engine iteration: admit + one batched decode step.
        Returns requests that finished this tick."""
        self._admit()
        if not any(s is not None for s in self.slots):
            return []
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.tokens, self.lens)
        next_tokens = sample_token(logits)
        finished = []
        new_tokens = self.tokens
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.lens = self.lens.at[i].add(1)
            tok = int(next_tokens[i, 0])
            req.out_tokens.append(tok)
            new_tokens = new_tokens.at[i, 0].set(tok)
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                finished.append(req)
                self.slots[i] = None
                self.lens = self.lens.at[i].set(0)
        self.tokens = new_tokens
        return finished

    def run_until_done(self, max_ticks: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_ticks):
            done.extend(self.tick())
            if not self.queue and all(s is None for s in self.slots):
                break
        return done
