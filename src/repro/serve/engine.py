"""Continuous-batching serve engine (dense or paged KV cache).

Slot-based scheduler: up to `max_batch` concurrent sequences share one
batched KV cache; every tick runs one batched decode step for all decoding
slots; finished sequences free their slot immediately (no head-of-line
blocking).  Queueing, admission policy (FIFO / shortest-prompt-first),
chunk planning, and latency accounting live in the token-budget scheduler
(serve/scheduler.py); this module owns all device state and page
bookkeeping.

Two cache modes (ServeConfig.paged):

  dense  one (L, max_batch, max_seq, Hkv, D) strip per K and V - every slot
         reserves max_seq worth of KV whether it needs it or not.
  paged  a global page pool + block table (serve/paged_cache.py): a request
         holds ceil((prompt + max_new) / page_size) pages from admission to
         completion and returns them the tick it finishes, so mixed-length
         traffic fits far more concurrent sequences in the same KV bytes.
         Admission reserves the worst case up front; when the free list
         cannot cover it the request simply stays queued (backpressure) -
         nothing mid-flight can run out of pages.

Two prefill schedules (ServeConfig.chunked):

  monolithic  (default) the whole prompt prefills in ONE batched pass at
         admission - simple, but a 4k-token admission stalls every active
         decode slot for the full prefill (a request-level pipeline
         bubble, the serving analogue of the tier stalls the paper's
         3D-FlashAttention chunking removes).
  chunked  each tick gets ServeConfig.tick_token_budget tokens of work:
         decoding slots consume 1 each, and the remainder is filled with
         prompt chunks (multiples of ServeConfig.prefill_chunk) for
         PREFILLING slots through the offset-causal block-table kernel
         (kernels/paged_prefill.py) - decode latency stays flat while
         long prompts stream in.  Paged mode only.  A slot that is still
         prefilling keeps lens == 0 and a zeroed row in the DEVICE block
         table, so the batched decode step's write lane for it lands in
         the reserved null page, never in its half-filled pages.
         With ServeConfig.batched (default) a chunked tick costs ONE
         ragged batched prefill launch + ONE fused decode launch + ONE
         device->host transfer regardless of how many requests are in
         flight: the scheduler packs every planned chunk into a K-row
         batch (serve/scheduler.py pack_chunks, power-of-two bucketed),
         final-chunk tokens are sampled device-side, and per-slot
         bookkeeping collapses into vectorized masked updates.
         batched=False keeps one launch per chunk (the parity oracle).

Decode-priority shaping + preemption (chunked mode):

  ServeConfig.decode_priority caps the prefill share of every tick at
  max_prefill_fraction * tick_token_budget after decode slots take their
  token each, so a burst of queued prefills can never inflate per-tick
  work - and with it every in-flight decode's work-clock TBT - up to the
  full budget.
  ServeConfig.preemption lets admission SHED lower-priority running
  requests (submit(priority=...), higher wins) when the page pool or the
  slot table cannot place a higher-priority candidate: the victim's
  non-shared pages return to the pool (prefix-cache pages survive via
  refcounts), it parks QUEUED->RESUMING, and on re-admission the prefix
  cache re-matches whatever pages survived while only the lost remainder
  re-prefills through the chunk path.  A mid-decode victim resumes from
  prompt + generated-so-far (Request.target), so greedy outputs are
  bit-identical to an uninterrupted run.  Equal priorities never preempt.

Prefix caching (ServeConfig.prefix_cache, paged mode only): finished
requests publish their prompt pages into a radix tree
(serve/prefix_cache.py); admission matches the longest cached prefix,
attaches those pages refcounted, and prefills ONLY the uncached remainder
- monolithically as a suffix, or as budgeted chunks when chunked (the
request's prefill cursor simply starts at the cached-prefix boundary).

Self-speculative decoding (ServeConfig.speculative, chunked+batched
only): each tick, every DECODING slot may propose a draft chain by
n-gram lookup over its own token history (serve/drafting.py - no second
model), capped by spec_k, the remaining generation budget, and the
tick's token budget (drafted tokens consume budget exactly like prefill
chunks).  All chains verify in ONE extra ragged launch through the same
batched chunk kernel decode already uses (serve/serve_step.py
make_spec_verify_step): row r scores [pending, d_1..d_m] at
offset = lens, the target's token is sampled at every position, and a
draft token is accepted iff it matches - so the emitted stream is
distributed exactly as non-speculative decoding (bit-identical under
greedy), and every chain nets n_acc + 1 >= 1 tokens for one launch.
Rejection rollback is free: the device sets lens = offset + n_acc + 1
and everything past it is dead - masked by the offset-causal kernel,
overwritten by the slot's next write - while the pages stay reserved
(admission sized them for max_new_tokens up front).  The work clock
advances only for ACCEPTED tokens, so work-clock latency and the final
work_tokens total are directly comparable spec-on vs spec-off.

Requests finish on length (max_new_tokens) or on a stop token
(submit(..., stop_tokens=...) / ServeConfig.eos_id), freeing or
publishing their pages the same tick.  Sampling runs the device-side
stack in serve/sampling.py: greedy at temperature 0; otherwise
temperature -> top-k -> top-p -> categorical through a PRNG key seeded
from ServeConfig.seed and threaded on the engine, so runs are
reproducible.
"""
from __future__ import annotations

import math
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ServeConfig
from ..models import Model, build_model
from .paged_cache import (PageAllocator, pages_needed, page_kv_bytes,
                          shard_page_kv_bytes)
from .prefix_cache import RadixPrefixCache
from .scheduler import (ChunkTask, DraftTask, Request, RequestState,
                        SpecBatch, TokenBudgetScheduler)
from .serve_step import (make_chunk_batch_step, make_chunk_prefill_step,
                         make_fused_decode_step, make_paged_prefill_step,
                         make_prefill_step, make_serve_step,
                         make_spec_verify_step, sample_token)
from .telemetry import (TRACK_ENGINE, TRACK_QUEUE, LaunchRecord,
                        MetricsRegistry, SpanTracer, Telemetry, TickRecord,
                        export_chrome_trace, movement_breakdown)

# attention-family prompts are padded to a multiple of this before the
# batched prefill, bounding jit recompiles to one per bucket
PREFILL_BUCKET = 16

# Jitted serve steps are SHARED across every engine built on the same model
# (and sampling knobs): the steps close over nothing but the model and the
# static sampling configuration (temperature, top_k, top_p), so two engines
# can execute the very same compiled executables.  That eliminates per-engine recompiles (constructing an
# engine is free once the first one warmed up) and - just as important -
# keeps greedy outputs bit-identical ACROSS engine instances: near-tie
# argmaxes are sensitive to last-ulp rounding differences between separate
# compilations of the same program, so parity comparisons between two
# engines (monolithic vs chunked, preempted vs uninterrupted oracle) are
# only exact when both run the same executables.
#
# The cache lives for the PROCESS: the step closures capture the model, so
# an entry pins its model (and compiled variants) for as long as the
# process runs.  That is the point - deliberate, bounded by the number of
# distinct models built, and cheap next to the recompiles it saves.  (A
# weak-keyed mapping would be a lie here: value -> model -> key is a
# strong cycle, so nothing would ever actually be evicted.)
_STEP_CACHE: Dict[int, Any] = {}


def _shared_steps(model: Model, temperature: float, top_k: int = 0,
                  top_p: float = 1.0, tp_mesh=None) -> Dict[str, Any]:
    # keyed by object identity WITH the model pinned in the entry, so an
    # id can never be recycled for a different model
    entry = _STEP_CACHE.get(id(model))
    if entry is None or entry[0] is not model:
        entry = (model, {})
        _STEP_CACHE[id(model)] = entry
    per_model = entry[1]
    # the tp mesh keys structurally (jax.sharding.Mesh equality is devices
    # + axis names), so two TP replicas at the same degree share the SAME
    # jitted steps - the cross-replica bit-identity the fleet differential
    # tests rely on extends to TP fleets unchanged
    knobs = (float(temperature), int(top_k), float(top_p), tp_mesh)
    steps = per_model.get(knobs)
    if steps is None:
        # donate the cache through the jit boundary so a tick updates the
        # KV pool in place instead of transiently doubling it (donation is
        # unimplemented on CPU - skip there to avoid per-call warnings)
        def _jit_donating_cache(fn, cache_argnum):
            if jax.default_backend() == "cpu":
                return jax.jit(fn)
            return jax.jit(fn, donate_argnums=(cache_argnum,))

        steps = {
            "decode": _jit_donating_cache(make_serve_step(model), 1),
            # sampling + masked token/length updates fused into the decode
            # launch: the whole decode phase of a tick is one jitted call
            # and the sampled tokens come back in ONE device_get at tick end
            "decode_fused": _jit_donating_cache(
                make_fused_decode_step(model, temperature=temperature,
                                       top_k=top_k, top_p=top_p,
                                       tp_mesh=tp_mesh), 1),
            "prefill": _jit_donating_cache(make_prefill_step(model), 2),
        }
        if model.prefill_paged is not None:
            steps["prefill_paged"] = _jit_donating_cache(
                make_paged_prefill_step(model), 2)
            # one jitted step serves the prefix-suffix AND chunked paths:
            # a suffix is a final chunk (same batch contract, same HLO)
            steps["prefill_chunk"] = _jit_donating_cache(
                make_chunk_prefill_step(model), 2)
            # the one-launch tick: every chunk planned this tick runs as
            # one ragged batch, final-chunk tokens sampled device-side
            steps["prefill_chunks"] = _jit_donating_cache(
                make_chunk_batch_step(model, temperature=temperature,
                                      top_k=top_k, top_p=top_p,
                                      tp_mesh=tp_mesh), 2)
        if model.verify_chunks is not None:
            # the speculative verify launch: one ragged batch scores every
            # draft chain and folds acceptance into tokens/lens device-side
            steps["spec_verify"] = _jit_donating_cache(
                make_spec_verify_step(model, temperature=temperature,
                                      top_k=top_k, top_p=top_p,
                                      tp_mesh=tp_mesh), 2)
        per_model[knobs] = steps
    return steps


def _registry_counter(name: str):
    """Class-level compatibility view over a registry counter (the engine
    analogue of the scheduler's): reads and `self.x += n` writes on the
    old attribute names go through the MetricsRegistry, so the registry is
    the one source of truth while every call site keeps its spelling."""
    def fget(self):
        return int(self.tm.registry.get(name).value)

    def fset(self, v):
        self.tm.registry.get(name).set_total(v)

    return property(fget, fset)


def _registry_gauge(name: str):
    def fget(self):
        return int(self.tm.registry.get(name).value)

    def fset(self, v):
        self.tm.registry.get(name).set(v)

    return property(fget, fset)


class ServeEngine:
    def __init__(self, model: Model, params, scfg: ServeConfig):
        self.model = model
        self.params = params
        self.scfg = scfg.validate()
        cfg = model.cfg
        B = scfg.max_batch
        self.paged = scfg.paged
        self.chunked = scfg.chunked
        self.speculative = scfg.speculative
        self._attention_family = cfg.family in ("dense", "moe", "vlm")
        if self.speculative and model.verify_chunks is None:
            raise ValueError(f"speculative serving needs an attention "
                             f"family, got {cfg.family}")
        # telemetry FIRST: one metrics registry per engine (the typed
        # backing store of every counter below - the scheduler, allocator,
        # and prefix cache all register into it), plus the optional span
        # tracer (ServeConfig.telemetry; host-side only - zero jitted
        # calls, zero device->host syncs, bit-identical outputs on or off)
        tracer = SpanTracer(scfg.telemetry_spans) if scfg.telemetry else None
        self.tm = Telemetry(registry=MetricsRegistry(), tracer=tracer)
        m = self.tm.registry
        m.counter("serve_jit_calls_total",
                  "Jitted model-step launches dispatched")
        m.counter("serve_host_syncs_total",
                  "Device->host transfers (token fetches and admission "
                  "samples)")
        m.counter("serve_prefill_tokens_total",
                  "Prompt tokens actually computed by prefill")
        m.counter("serve_prefix_hit_tokens_total",
                  "Prompt tokens served from the prefix cache instead of "
                  "being recomputed")
        m.counter("serve_cow_copies_total",
                  "Device-side copy-on-write page copies")
        m.counter("serve_gen_tokens_total", "Generation tokens emitted")
        m.counter("serve_decode_launches_total",
                  "Token-emitting launches (fused decode + spec verify)")
        m.counter("serve_kv_pages_read_total",
                  "KV pages read by token-emitting launches (analytic "
                  "host-side count, not a device counter)")
        m.counter("serve_requests_submitted_total",
                  "Requests accepted by submit()")
        m.counter("serve_requests_finished_total",
                  "Requests finished (length or stop token)")
        m.gauge("serve_peak_pages",
                "High-water mark of pool pages in use (cached included)")
        m.gauge("serve_peak_live_pages",
                "High-water mark of distinct pages referenced by slots")
        m.gauge("serve_outstanding_work_tokens",
                "Queued + in-flight work tokens (prompt remaining plus "
                "unspent generation budget) - the load signal load_stats() "
                "publishes for the fleet router")
        m.gauge("serve_tp_degree",
                "Tensor-parallel degree of this engine (devices the "
                "head-sharded KV page pool spans; 1 = single-device)")
        m.counter("serve_tp_shard_kv_bytes_read_total",
                  "KV bytes read PER DEVICE by token-emitting launches "
                  "(kv_pages_read converted through the head-sharded "
                  "per-shard page bytes; equals the full page bytes at "
                  "tp_degree 1)")
        m.counter("serve_tp_table_bytes_replicated_total",
                  "Block-table bytes uploaded times tp_degree - the "
                  "replication overhead of keeping the table as scalar-"
                  "prefetch state on every shard")
        self.prefix: Optional[RadixPrefixCache] = None
        # tensor parallelism: the mesh is built (and the pools committed
        # head-sharded) inside the paged branch below; tp_degree > 1
        # without paged mode is rejected by ServeConfig.validate()
        self.tp_mesh = None
        m.get("serve_tp_degree").set(scfg.tp_degree)
        # per-device bytes of one page (full page bytes at tp_degree 1);
        # indivisible head/tp combos fail with the clear error below, so
        # fall back to tp=1 math here rather than raising twice
        self._shard_page_bytes = shard_page_kv_bytes(
            cfg, scfg.page_size,
            scfg.tp_degree if cfg.n_kv_heads % scfg.tp_degree == 0 else 1)
        if scfg.prefix_cache and not scfg.paged:
            raise ValueError("prefix_cache requires paged=True")
        if self.paged:
            if model.prefill_paged is None:
                raise ValueError(f"paged serving needs an attention family, "
                                 f"got {cfg.family}")
            if scfg.max_seq % scfg.page_size:
                # the page-multiple invariant (attn_prefill_paged reshapes
                # prompts into whole pages) must hold at the max_seq cap too
                raise ValueError(
                    f"max_seq ({scfg.max_seq}) must be a multiple of "
                    f"page_size ({scfg.page_size})")
            num_pages = scfg.pool_pages()
            self.allocator = PageAllocator(num_pages, scfg.page_size, B,
                                           scfg.max_seq,
                                           usable_pages=scfg.usable_pages,
                                           metrics=m)
            self.cache = model.init_cache(B, scfg.max_seq,
                                          page_size=scfg.page_size,
                                          num_pages=num_pages)
            if scfg.tp_degree > 1:
                if cfg.n_kv_heads % scfg.tp_degree:
                    raise ValueError(
                        f"ServeConfig.tp_degree ({scfg.tp_degree}) must "
                        f"divide n_kv_heads ({cfg.n_kv_heads}): the KV "
                        f"page pool shards on the head axis, so every "
                        f"device needs a whole number of KV heads (GQA "
                        f"query heads follow their KV head's shard)")
                from jax.sharding import NamedSharding, PartitionSpec
                from ..launch.mesh import make_serve_mesh
                self.tp_mesh = make_serve_mesh(scfg.tp_degree)
                # commit placement ONCE at construction: the (L, P, ps,
                # Hkv, D) pools head-sharded, params fully replicated, so
                # every jitted step compiles against stable shardings and
                # per-tick uploads (block table, chunk packs, tokens) stay
                # small uncommitted host arrays jit re-shards for free
                hs = NamedSharding(self.tp_mesh,
                                   PartitionSpec(None, None, None, "model",
                                                 None))
                rep = NamedSharding(self.tp_mesh, PartitionSpec())
                self.cache = {
                    "k_pages": jax.device_put(self.cache["k_pages"], hs),
                    "v_pages": jax.device_put(self.cache["v_pages"], hs),
                    "block_table": jax.device_put(
                        self.cache["block_table"], rep),
                }
                self.params = jax.device_put(self.params, rep)
            if scfg.prefix_cache:
                self.prefix = RadixPrefixCache(self.allocator,
                                               scfg.page_size, metrics=m)
                self.prefix.event_cb = self._prefix_event
        else:
            self.allocator = None
            self.cache = model.init_cache(B, scfg.max_seq,
                                          enc_len=scfg.max_seq)
        self.lens = jnp.zeros((B,), jnp.int32)
        self.slots: List[Optional[Request]] = [None] * B
        self.tokens = jnp.zeros((B, 1), jnp.int32)
        self.sched = TokenBudgetScheduler(scfg, metrics=m)
        self._uid = 0
        self._admit_seq = 0          # monotone admission stamp (victim order)
        self._key = jax.random.PRNGKey(scfg.seed)
        self._dummy_key = jax.random.PRNGKey(0)   # greedy: key arg unused
        self._finished_this_tick: List[Request] = []
        # set the first time a submit carries a deadline: the per-tick
        # expiry sweep is a no-op until then (deadline-free traffic pays
        # one boolean check per tick)
        self._deadlines_active = False
        self._table_dirty = False    # device block table behind the host's
        # host mirror of `lens`: every host-side decision that needs
        # lengths (COW guard, bookkeeping) reads this instead of syncing
        # the device array - lengths are fully determined by scheduling
        self._lens_np = np.zeros((B,), np.int64)
        # dispatch / throughput counters (jit_calls, host_syncs,
        # gen_tokens, decode_launches, kv_pages_read, ...) live in the
        # telemetry registry; the attribute names below the class body are
        # registry-backed properties, and launch_log is a view over the
        # typed per-tick records in self.tm.ticks
        # n_acc array of the tick's verify launch, fetched WITH tokens
        self._spec_nacc: Optional[jax.Array] = None

        # jitted steps come from the model-level shared cache: every engine
        # on this model (at these sampling knobs) runs the SAME executables
        # - no per-engine recompiles, and bit-identical numerics across
        # engine instances (see _shared_steps)
        steps = _shared_steps(model, scfg.temperature, scfg.top_k,
                              scfg.top_p, tp_mesh=self.tp_mesh)
        self._decode = steps["decode"]
        self._decode_fused = steps["decode_fused"]
        self._prefill = steps["prefill"]
        if self.paged:
            self._prefill_paged = steps["prefill_paged"]
            self._prefill_chunk = steps["prefill_chunk"]
            self._prefill_chunks = steps["prefill_chunks"]
        if self.speculative:
            self._spec_verify = steps["spec_verify"]

    # registry-backed compatibility views (one source of truth: the
    # telemetry registry; `eng.jit_calls += 1` et al. keep working)
    jit_calls = _registry_counter("serve_jit_calls_total")
    host_syncs = _registry_counter("serve_host_syncs_total")
    prefill_tokens = _registry_counter("serve_prefill_tokens_total")
    prefix_hit_tokens = _registry_counter("serve_prefix_hit_tokens_total")
    cow_copies = _registry_counter("serve_cow_copies_total")
    gen_tokens = _registry_counter("serve_gen_tokens_total")
    decode_launches = _registry_counter("serve_decode_launches_total")
    kv_pages_read = _registry_counter("serve_kv_pages_read_total")
    peak_pages = _registry_gauge("serve_peak_pages")
    peak_live_pages = _registry_gauge("serve_peak_live_pages")

    @property
    def launch_log(self) -> List[tuple]:
        """Per-tick dispatch accounting as the legacy 5-tuple rows
        (jit_calls, host_syncs, host_wall_s, n_chunk_tasks, n_decode) -
        a compatibility view over the typed TickRecords in self.tm.ticks."""
        return [t.as_tuple() for t in self.tm.ticks]

    # ------------------------------------------------------------------
    # telemetry surface
    # ------------------------------------------------------------------
    def export_trace(self, path, clock: str = "wall"):
        """Write the span tracer's records as Chrome trace-event JSON
        (open in Perfetto / chrome://tracing): request lifecycle spans on
        per-slot tracks, engine phases and kernel launches on engine
        tracks.  clock="wall" for the human view, "work" for the
        deterministic work-clock view.  Returns the trace dict."""
        if self.tm.tracer is None:
            raise ValueError(
                "span tracing is off: build the engine with "
                "ServeConfig(telemetry=True) to record spans")
        return export_chrome_trace(path, self.tm.tracer,
                                   self.scfg.max_batch, clock=clock)

    def metrics_snapshot(self) -> Dict[str, Any]:
        """JSON-ready snapshot of every registered metric."""
        return self.tm.registry.snapshot()

    def prometheus_metrics(self) -> str:
        """Every registered metric in Prometheus text exposition format."""
        return self.tm.registry.prometheus_text()

    def launch_records(self) -> List[LaunchRecord]:
        """Per-launch data-movement attribution records, launch order."""
        return list(self.tm.launches)

    def movement_stats(self) -> Dict[str, Dict[str, float]]:
        """Paper-style (Fig. 6) data-movement breakdown per launch kind:
        estimated HBM / SRAM bytes and energy folded from the per-launch
        records through core/energy.py (see telemetry.movement_breakdown)."""
        return movement_breakdown(self.tm.launches, self.model.cfg,
                                  self.scfg, tp_degree=self.scfg.tp_degree)

    def tp_stats(self) -> Dict[str, int]:
        """Tensor-parallel accounting snapshot: the per-device KV bytes
        the token-emitting launches read, the block-table bytes paid to
        replication, and the per-shard page-byte unit - everything the
        conformance cross-check (shard_bytes * tp == pages_read *
        page_bytes) and the serve_bench --tp inequality consume."""
        g = self.tm.registry.get
        return {
            "tp_degree": int(self.scfg.tp_degree),
            "shard_kv_bytes_read":
                int(g("serve_tp_shard_kv_bytes_read_total").value),
            "table_bytes_replicated":
                int(g("serve_tp_table_bytes_replicated_total").value),
            "shard_page_bytes": int(self._shard_page_bytes),
            "page_bytes": int(page_kv_bytes(self.model.cfg,
                                            self.scfg.page_size)),
            "kv_pages_read": int(self.kv_pages_read),
        }

    def _prefix_event(self, name: str, **args):
        """Prefix-cache hit/publish/evict instants onto the engine track
        (wired as RadixPrefixCache.event_cb; no-op with the tracer off)."""
        tr = self.tm.tracer
        if tr is not None:
            tr.add_event(name, "prefix", TRACK_ENGINE, self.sched.ticks,
                         self.sched.work_clock, tr.now(), **args)

    def _note_launch(self, kind: str, rows: int, live_rows: int,
                     true_tokens: int, padded_tokens: int,
                     kv_pages_read: int, kv_pages_written: int,
                     new_kv_tokens: int, wall0: float = 0.0,
                     wall1: float = 0.0):
        """Record one kernel launch's data-movement attribution (and, with
        the tracer on, its span on the engine track)."""
        self.tm.launch(LaunchRecord(
            tick=self.sched.ticks, kind=kind, rows=rows,
            live_rows=live_rows, true_tokens=true_tokens,
            padded_tokens=padded_tokens, kv_pages_read=kv_pages_read,
            kv_pages_written=kv_pages_written,
            new_kv_tokens=new_kv_tokens,
            work_clock=self.sched.work_clock), wall0, wall1)

    def _note_kv_pages_read(self, n_pages: int):
        """Count pages a token-emitting launch read, in BOTH units: pool
        pages (the historical serve_kv_pages_read_total) and per-device
        bytes (pages x the head-sharded per-shard page bytes) - every
        shard walks the same replicated block table over the same page
        ids, so per-shard reads are exactly total reads / tp_degree."""
        n = int(n_pages)
        self.kv_pages_read += n
        self.tm.registry.get("serve_tp_shard_kv_bytes_read_total").inc(
            n * self._shard_page_bytes)

    def _note_table_upload(self, nbytes: int):
        """Count one block-table upload's replication cost: the table is
        scalar-prefetch state on every shard, so the bytes multiply by
        tp_degree instead of dividing."""
        self.tm.registry.get("serve_tp_table_bytes_replicated_total").inc(
            int(nbytes) * int(self.scfg.tp_degree))

    def _row_pages(self, slot: int, true_len: int) -> int:
        """KV pages slot's attention READS at KV length `true_len`:
        counted from the allocator's block-table row (the PageAllocator's
        accounting IS the source of truth - tests cross-check this count
        against the analytic ceil(true_len / page_size))."""
        n = -(-int(true_len) // self.scfg.page_size)
        return int(np.count_nonzero(self.allocator.table[slot, :n]))

    def _span_pages(self, start: int, end: int) -> int:
        """Pages the K/V writes of token positions [start, end) touch."""
        if end <= start:
            return 0
        ps = self.scfg.page_size
        return end // ps - start // ps + (1 if end % ps else 0)

    def _wall(self) -> float:
        """Tracer wall stamp; 0.0 (never read) with the tracer off."""
        tr = self.tm.tracer
        return tr.now() if tr is not None else 0.0

    def _phase(self, req: Request, phase: str, track: int, **args):
        """Request-lifecycle phase transition onto the tracer (no-op off)."""
        self.tm.request_phase(req.uid, phase, track, self.sched.ticks,
                              self.sched.work_clock, **args)

    def _event(self, req: Request, name: str, track: int, **args):
        self.tm.request_event(req.uid, name, track, self.sched.ticks,
                              self.sched.work_clock, **args)

    # ------------------------------------------------------------------
    @property
    def queue(self) -> List[Request]:
        """Requests waiting for admission (owned by the scheduler)."""
        return self.sched.queue

    @property
    def tick_log(self):
        """Per-tick (decode_tokens, prefill_tokens) budget accounting."""
        return self.sched.tick_log

    def submit(self, prompt: List[int],
               max_new_tokens: Optional[int] = None,
               stop_tokens: Optional[Sequence[int]] = None,
               priority: int = 0,
               deadline: Optional[int] = None,
               max_retries: Optional[int] = None) -> int:
        """Enqueue a request.  Everything that can never be served -
        empty prompt, zero generation budget, overflowing max_seq, a page
        reservation larger than the engine can ever grant, a deadline the
        prompt's own prefill would already blow - fails HERE with a clear
        error instead of deep inside prefill or the allocator.
        `stop_tokens` (merged with ServeConfig.eos_id) end generation
        early the tick one is produced.  Higher `priority` admits first
        and - with ServeConfig.preemption - may preempt running
        lower-priority requests when the page pool runs dry.  `deadline`
        is a per-request work-clock deadline in tokens (default:
        ServeConfig.default_deadline_tokens; 0/None = none): once the
        engine has executed that much work since the submit the request
        expires with a TIMEOUT status, pages freed the same tick.
        `max_retries` caps how many times a fleet router may redispatch
        the request off a failed replica (None = unbounded)."""
        n_new = self.scfg.max_new_tokens if max_new_tokens is None \
            else max_new_tokens
        if not prompt:
            raise ValueError("empty prompt")
        if n_new < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {n_new}")
        if deadline is None:
            deadline = self.scfg.default_deadline_tokens or None
        if deadline is not None:
            if deadline <= 0:
                raise ValueError(f"deadline must be >= 1 work token, "
                                 f"got {deadline}")
            if deadline <= len(prompt):
                # the prompt alone costs len(prompt) work tokens of
                # prefill before the first token can exist: a smaller
                # deadline is a guaranteed timeout - reject it at submit
                raise ValueError(
                    f"deadline ({deadline}) is not above the prompt's "
                    f"minimum prefill work ({len(prompt)} tokens): the "
                    f"request could never produce a token in time")
        if max_retries is not None and max_retries < 0:
            raise ValueError(f"max_retries must be >= 0 (None = "
                             f"unbounded), got {max_retries}")
        if len(prompt) + n_new > self.scfg.max_seq:
            raise ValueError(
                f"request does not fit: {len(prompt)} prompt + {n_new} new "
                f"tokens > max_seq {self.scfg.max_seq}")
        if self.paged:
            need = pages_needed(len(prompt) + n_new, self.scfg.page_size)
            usable = min(self.allocator.max_pages_per_seq,
                         self.allocator.usable_pages)
            if need > usable:
                # backpressure cannot help a reservation larger than the
                # whole pool - fail fast instead of queueing forever
                raise ValueError(
                    f"request needs {need} pages; the engine can grant at "
                    f"most {usable} (pool {self.allocator.num_pages}, "
                    f"max_seq {self.scfg.max_seq}, page "
                    f"{self.scfg.page_size})")
        stops = frozenset(stop_tokens or ())
        if self.scfg.eos_id is not None:
            stops = stops | {self.scfg.eos_id}
        self._uid += 1
        req = Request(self._uid, list(prompt), n_new, stop_tokens=stops,
                      priority=int(priority), deadline_tokens=deadline,
                      max_retries=max_retries)
        if deadline is not None:
            self._deadlines_active = True
        self.sched.submit(req)
        self.tm.registry.get("serve_requests_submitted_total").inc()
        self._phase(req, "QUEUED", TRACK_QUEUE,
                    prompt_tokens=len(prompt), priority=int(priority))
        return self._uid

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def prefix_stats(self) -> Dict[str, int]:
        """Prefill / prefix-cache counters (zeros when caching is off)."""
        total = self.prefill_tokens + self.prefix_hit_tokens
        return {"prefill_tokens": self.prefill_tokens,
                "prefix_hit_tokens": self.prefix_hit_tokens,
                "prompt_tokens": total,
                "cow_copies": self.cow_copies,
                "cached_pages": self.prefix.cached_pages
                if self.prefix is not None else 0,
                "peak_pages": self.peak_pages,
                "peak_live_pages": self.peak_live_pages}

    def load_stats(self) -> Dict[str, int]:
        """Cheap occupancy view for dispatch decisions (the fleet router
        reads this once per replica per submit): queue depth, in-flight
        requests, outstanding work tokens, and page headroom.  Pure
        host-side bookkeeping reads - no device sync, no LRU or refcount
        effects - and the work-token total is published to the
        registry gauge `serve_outstanding_work_tokens`, so the load
        signal the router acted on is visible in metrics snapshots."""
        inflight = [r for r in self.slots if r is not None]
        work = sum(r.prompt_remaining + r.remaining_new
                   for r in inflight)
        work += sum(r.prompt_remaining + r.remaining_new
                    for r in self.queue)
        self.tm.registry.get("serve_outstanding_work_tokens").set(work)
        free = int(self.allocator.free_pages) if self.paged \
            else 1 << 30                # dense KV never backpressures
        evictable = self.prefix.evictable_pages() \
            if self.prefix is not None else 0
        return {"queue_depth": len(self.queue),
                "inflight": len(inflight),
                "free_slots": sum(s is None for s in self.slots),
                "outstanding_work_tokens": work,
                "free_pages": free,
                "evictable_pages": evictable}

    def stats(self) -> Dict[str, float]:
        """Engine stats API: scheduler latency aggregates (p50/p95 TTFT
        and time-between-tokens, wall-clock and work-clock), per-tick
        budget accounting, the prefill / prefix-cache counters, and
        dispatch accounting (jitted launches, device->host transfers, and
        host-loop wall time per tick)."""
        out: Dict[str, float] = dict(self.sched.stats())
        out.update(self.prefix_stats())
        out["tick_token_budget"] = self.scfg.tick_token_budget
        out["chunked"] = self.chunked
        out["batched"] = self.scfg.batched
        out["jit_calls"] = self.jit_calls
        out["host_syncs"] = self.host_syncs
        out["compile_count"] = self.compile_cache_size()
        out["speculative"] = self.speculative
        out["telemetry"] = self.tm.enabled
        out["gen_tokens"] = self.gen_tokens
        out["decode_launches"] = self.decode_launches
        out["kv_pages_read"] = self.kv_pages_read
        out["tp_degree"] = self.scfg.tp_degree
        out["tokens_per_launch"] = (self.gen_tokens / self.decode_launches
                                    if self.decode_launches else 0.0)
        out["tokens_per_kv_page"] = (self.gen_tokens / self.kv_pages_read
                                     if self.kv_pages_read else 0.0)
        if self.launch_log:
            calls = [r[0] for r in self.launch_log]
            syncs = [r[1] for r in self.launch_log]
            walls = [r[2] for r in self.launch_log]
            # "busy" = the steady-state shape of the acceptance criterion:
            # prefill chunks AND decodes in the same tick
            busy = [r[0] for r in self.launch_log if r[3] and r[4]]
            out["jit_calls_per_tick_max"] = max(calls)
            out["jit_calls_per_tick_mean"] = float(np.mean(calls))
            out["jit_calls_per_busy_tick_max"] = max(busy) if busy else 0
            out["host_syncs_per_tick_max"] = max(syncs)
            out["tick_host_wall_p50"] = float(np.percentile(walls, 50))
            out["tick_host_wall_p95"] = float(np.percentile(walls, 95))
        return out

    def check_invariants(self):
        """Debug hook for serve-path test fixtures (tests/traffic.py calls
        it after every tick): allocator refcount conservation + block-table
        mirroring (PageAllocator.check_invariants), prefix-tree consistency
        when caching is on, and the engine's own host-side bookkeeping -
        slot back-references, queue states, and the lens mirror.  Pure
        host-side: never touches a device array, so calling it cannot
        perturb the launch/sync accounting under test."""
        if self.paged:
            if self.prefix is not None:
                self.prefix.check_invariants()
            else:
                self.allocator.check_invariants()
            # per-shard byte accounting tracks the page counter exactly
            # (every read is noted through _note_kv_pages_read, in pages
            # AND per-device bytes, off one shard_page_kv_bytes unit)
            shard_bytes = int(self.tm.registry.get(
                "serve_tp_shard_kv_bytes_read_total").value)
            assert shard_bytes == self.kv_pages_read \
                * self._shard_page_bytes, \
                (f"per-shard KV byte accounting drifted: {shard_bytes} != "
                 f"{self.kv_pages_read} pages x {self._shard_page_bytes} "
                 f"bytes/shard-page")
        for i, r in enumerate(self.slots):
            if r is None:
                if self.paged:
                    assert not self.allocator.table[i].any(), \
                        f"slot {i} empty but its table row is live"
                assert self._lens_np[i] == 0, \
                    f"slot {i} empty but lens mirror {self._lens_np[i]}"
            else:
                assert r.slot == i, f"slot {i} back-reference broken"
                assert r.state in (RequestState.PREFILLING,
                                   RequestState.DECODING), \
                    f"slot {i} holds a {r.state} request"
        for r in self.queue:
            assert r.state in (RequestState.QUEUED, RequestState.RESUMING)
            assert r.slot is None, \
                f"queued request {r.uid} still holds slot {r.slot}"
            assert r.remaining_new >= 1

    def compile_cache_size(self) -> int:
        """Total compiled-variant count across the engine's jitted steps
        (jax pjit cache sizes) - the recompile-count metric benchmarks
        record and the steady-state guard tests pin down.  Steps are
        shared across engines of the same model (_shared_steps), so the
        absolute count spans every sibling engine in the process; deltas
        within one engine's run still measure that run's recompiles."""
        fns = [self._decode, self._decode_fused, self._prefill,
               getattr(self, "_prefill_paged", None),
               getattr(self, "_prefill_chunk", None),
               getattr(self, "_prefill_chunks", None),
               getattr(self, "_spec_verify", None)]
        return sum(fn._cache_size() for fn in fns
                   if fn is not None and hasattr(fn, "_cache_size"))

    def kv_cache_bytes(self) -> int:
        """Allocated cache bytes, every leaf: KV strips or pages, block
        table, and recurrent state for ssm/hybrid/audio families.  Caches
        are preallocated, so allocated == peak."""
        return sum(int(np.prod(leaf.shape))
                   * jnp.dtype(leaf.dtype).itemsize
                   for leaf in jax.tree_util.tree_leaves(self.cache))

    # ------------------------------------------------------------------
    # sampling / emission / completion (shared by all schedules)
    # ------------------------------------------------------------------
    def _sample(self, logits) -> jax.Array:
        """(B, 1, V) logits -> (B, 1) tokens through the device-side
        sampling stack (serve/sampling.py).  Greedy at temperature 0;
        otherwise temperature -> top-k -> top-p -> categorical through
        the engine's threaded PRNG key (one split per call, so a fixed
        ServeConfig.seed reproduces the whole trace)."""
        if self.scfg.temperature <= 0.0:
            return sample_token(logits)
        self._key, sub = jax.random.split(self._key)
        return sample_token(logits, temperature=self.scfg.temperature,
                            top_k=self.scfg.top_k, top_p=self.scfg.top_p,
                            key=sub)

    def _next_key(self) -> jax.Array:
        """PRNG key for a fused (device-side sampling) step: a fixed dummy
        at temperature 0 (the step ignores it - no per-tick split work),
        one split per launch otherwise."""
        if self.scfg.temperature <= 0.0:
            return self._dummy_key
        self._key, sub = jax.random.split(self._key)
        return sub

    def _fetch_tokens(self) -> np.ndarray:
        """THE tick's device->host transfer: the (B, 1) token array after
        the fused steps wrote every lane's sampled token into it."""
        self.host_syncs += 1
        return np.asarray(jax.device_get(self.tokens))

    def _emit(self, req: Request, tok: int,
              work: Optional[int] = None) -> bool:
        """Record one generated token; True when the request is finished
        (stop token or length budget).  `work` back-stamps the token's
        work clock (one-launch tick: emission is deferred until after the
        decode launch, but the stamp must match the sequential path)."""
        req.out_tokens.append(tok)
        self.gen_tokens += 1
        self.sched.note_token(req, time.time(), work=work)
        if tok in req.stop_tokens:
            req.finish_reason = "stop"
            return True
        if len(req.out_tokens) >= req.max_new_tokens:
            req.finish_reason = "length"
            return True
        return False

    def _finish(self, req: Request):
        """Free the request's slot; pages go back to the pool (or publish
        into the prefix cache) the same tick."""
        i = req.slot
        req.state = RequestState.DONE
        req.done = True
        self.slots[i] = None
        self.lens = self.lens.at[i].set(0)
        self._lens_np[i] = 0
        if self.prefix is not None:
            # prompt pages go into the radix tree; the partial tail page
            # and generation pages return to the pool
            self.prefix.release(i, req.prompt)
        elif self.paged:
            self.allocator.free_slot(i)
        if self.paged:
            self._table_dirty = True     # zero the slot's device row
        self.sched.note_finished(req)
        self.tm.registry.get("serve_requests_finished_total").inc()
        self._phase(req, "DONE", i, reason=req.finish_reason,
                    out_tokens=len(req.out_tokens))
        self._finished_this_tick.append(req)

    def _expire(self, req: Request):
        """Deadline timeout: take the request out of the engine - queued,
        prefilling, or decoding - and free everything it held THE SAME
        TICK.  A slot-holding request frees exactly like a preemption
        victim (only fully-valid positions publish into the prefix tree:
        prefill_pos while prefilling, the lens mirror while decoding;
        without a prefix cache the slot's pages simply return to the
        pool), so an expired request can never strand capacity or corrupt
        page accounting.  Finishes with state TIMEOUT / finish_reason
        "timeout" and surfaces through the tick's finished list like any
        completion - a deadline bounds latency, it never hangs."""
        i = req.slot
        if i is not None:
            if self.prefix is not None:
                if req.state is RequestState.PREFILLING:
                    n_valid = req.prefill_pos
                    seq = list(req.target)
                else:
                    seq = req.prompt + list(req.out_tokens)
                    n_valid = int(self._lens_np[i])
                self.prefix.release(i, seq[:n_valid])
            elif self.paged:
                self.allocator.free_slot(i)
            self.slots[i] = None
            self.lens = self.lens.at[i].set(0)
            self._lens_np[i] = 0
            req.slot = None
            if self.paged:
                self._table_dirty = True
        else:
            self.sched.queue.remove(req)
        req.state = RequestState.TIMEOUT
        req.done = True
        req.finish_reason = "timeout"
        self.sched.timeouts += 1
        self.sched.note_finished(req)
        self._phase(req, "TIMEOUT", i if i is not None else TRACK_QUEUE,
                    out_tokens=len(req.out_tokens))
        self._finished_this_tick.append(req)

    def _expire_deadlines(self):
        """Top-of-tick deadline sweep (both tick flavors): expire every
        request - queued or in flight - whose work-clock age reached its
        deadline.  The scheduler owns the predicate (sched.expired); the
        engine owns the page/slot consequences.  Sweeping BEFORE admission
        and planning means a request never does work in the tick it
        expires, and the pages it frees are immediately admissible."""
        if not self._deadlines_active:
            return
        expired = [r for r in self.sched.queue if self.sched.expired(r)]
        expired += [r for r in self.slots
                    if r is not None and self.sched.expired(r)]
        for r in expired:
            self._expire(r)

    def request_statuses(self) -> Dict[int, str]:
        """{uid: state} for every request this engine has ever accepted:
        terminal ("done" / "timeout" / "failed") or still-live ("queued" /
        "prefilling" / "decoding" / "resuming").  Built from the three
        places a request can be - finished list, admission queue, slots -
        so nothing is ever silently dropped (the exhaustion-reporting and
        chaos suites assert on exactly this view)."""
        out: Dict[int, str] = {}
        for r in self.sched.finished:
            out[r.uid] = r.state.value
        for r in self.queue:
            out[r.uid] = r.state.value
        for r in self.slots:
            if r is not None:
                out[r.uid] = r.state.value
        return out

    def _sync_table(self):
        """Upload the block table, MASKING rows of slots that are not yet
        decoding: a PREFILLING slot keeps lens == 0, so the batched decode
        step's write lane for it must land in the reserved null page - not
        in the pages its chunks are filling.  The host table is ALWAYS
        copied before upload: jnp.asarray of an aligned numpy array can be
        zero-copy on CPU, and the allocator mutates this table in place on
        every alloc/free/preempt - an aliased upload would let those host
        writes retarget the device table under an in-flight tick."""
        tbl = self.allocator.table.copy()
        masked = [i for i, r in enumerate(self.slots)
                  if r is not None and r.state is not RequestState.DECODING]
        if masked:
            tbl[masked] = 0
        self.cache["block_table"] = jnp.asarray(tbl)
        self._note_table_upload(tbl.nbytes)
        self._table_dirty = False

    # ------------------------------------------------------------------
    # admission (monolithic prefill)
    # ------------------------------------------------------------------
    def _admit(self):
        """Prefill queued requests into free slots, whole prompts at once.
        Admission order follows ServeConfig.admission_policy; stops at the
        first candidate that cannot be placed (no slot, or - paged - not
        enough free pages: backpressure, it stays queued)."""
        while True:
            req = self.sched.peek()
            if req is None:
                return
            slot = self._free_slot()
            if slot is None:
                return
            if self.paged:
                if not self._admit_paged(slot, req):
                    return
            elif self._attention_family:
                self._admit_prefill(slot, req)
            else:
                self._admit_stepwise(slot, req)

    def _padded_prompt(self, prompt: List[int], bucket: int):
        s_real = len(prompt)
        s_pad = min(-(-s_real // bucket) * bucket, self.scfg.max_seq)
        toks = np.zeros((1, s_pad), np.int32)
        toks[0, :s_real] = prompt
        return jnp.asarray(toks), s_real

    def _place(self, slot: int, req: Request, logits, s_real: int):
        """Common tail of every monolithic admission path: record the slot
        state and sample the first generated token from the prompt's last
        logits (a stop token here finishes the request immediately)."""
        self.lens = self.lens.at[slot].set(s_real)
        self._lens_np[slot] = s_real
        self.host_syncs += 1
        nxt = int(self._sample(logits)[0, 0])
        self.tokens = self.tokens.at[slot, 0].set(nxt)
        self.slots[slot] = req
        req.slot = slot
        req.prefill_pos = len(req.prompt)
        req.state = RequestState.DECODING
        self._stamp_admit(req)
        self._phase(req, "DECODING", slot)
        if self._emit(req, nxt):
            self._finish(req)

    def _admit_prefill(self, slot: int, req: Request):
        """Dense cache, attention family: one batched prefill into a
        sub-cache sized to the padded prompt, scattered into the slot row."""
        self.sched.pop(req)
        self._phase(req, "PREFILLING", slot)
        toks, s_real = self._padded_prompt(req.prompt, PREFILL_BUCKET)
        s_pad = toks.shape[1]
        sub = self.model.init_cache(1, s_pad)
        batch = {"tokens": toks, "true_lens": jnp.asarray([s_real])}
        self.jit_calls += 1
        w0 = self._wall()
        logits, sub, _ = self._prefill(self.params, batch, sub)
        self._note_launch("prefill", rows=1, live_rows=1,
                          true_tokens=s_real, padded_tokens=s_pad,
                          kv_pages_read=0, kv_pages_written=0,
                          new_kv_tokens=s_real, wall0=w0,
                          wall1=self._wall())
        self.cache["k"] = self.cache["k"].at[:, slot, :s_pad].set(
            sub["k"][:, 0])
        self.cache["v"] = self.cache["v"].at[:, slot, :s_pad].set(
            sub["v"][:, 0])
        self.prefill_tokens += s_real
        self.sched.note_work(s_real)
        self._place(slot, req, logits, s_real)

    def _note_alloc(self):
        self.peak_pages = max(self.peak_pages, self.allocator.used_pages)
        self.peak_live_pages = max(self.peak_live_pages,
                                   self.allocator.live_pages())

    def _ensure_free(self, n: int, protect=frozenset()) -> bool:
        """True if n pages are (or can be made) free.  With the prefix
        cache, LRU-evicts unreferenced cached pages - never `protect`
        (pages about to be attached) or anything a slot references."""
        if self.allocator.can_alloc(n):
            return True
        if self.prefix is not None:
            self.prefix.evict(n - self.allocator.free_pages,
                              protect=frozenset(protect))
        return self.allocator.can_alloc(n)

    def _copy_page(self, src: int, dst: int):
        """Device-side copy of one page across every layer's K and V slab
        (the data half of copy-on-write; the allocator did the
        bookkeeping)."""
        for key in ("k_pages", "v_pages"):
            slab = self.cache[key]
            self.cache[key] = slab.at[:, dst].set(slab[:, src])

    def _admit_paged(self, slot: int, req: Request) -> bool:
        """Paged cache: reserve the request's worst case up front; prefill
        the prompt straight into its pages.  False = out of pages.
        (Reservations that can never fit were rejected at submit time.)"""
        if self.prefix is not None:
            return self._admit_prefix(slot, req)
        scfg = self.scfg
        need = pages_needed(len(req.prompt) + req.max_new_tokens,
                            scfg.page_size)
        if not self.allocator.can_alloc(need):
            return False
        self.sched.pop(req)
        self._phase(req, "PREFILLING", slot)
        pages = self.allocator.alloc(slot, need)
        self._note_alloc()
        toks, s_real = self._padded_prompt(req.prompt, scfg.page_size)
        page_ids = jnp.asarray(pages[:toks.shape[1] // scfg.page_size],
                               jnp.int32)
        self.cache["block_table"] = self.allocator.table_device()
        self._note_table_upload(self.allocator.table.nbytes)
        batch = {"tokens": toks, "true_lens": jnp.asarray([s_real])}
        self.jit_calls += 1
        w0 = self._wall()
        logits, self.cache, _ = self._prefill_paged(
            self.params, batch, self.cache, page_ids)
        self._note_launch("prefill_paged", rows=1, live_rows=1,
                          true_tokens=s_real,
                          padded_tokens=toks.shape[1],
                          kv_pages_read=self._row_pages(slot, s_real),
                          kv_pages_written=self._span_pages(0, s_real),
                          new_kv_tokens=s_real, wall0=w0,
                          wall1=self._wall())
        self.prefill_tokens += s_real
        self.sched.note_work(s_real)
        self._place(slot, req, logits, s_real)
        return True

    def _reserve_prefix(self, slot: int, req: Request) -> Optional[int]:
        """Shared prefix-cached reservation: attach the longest cached
        prefix, allocate the rest of the worst case, COW the final cached
        page when the whole prompt is covered.  Returns the prompt
        position computation must start from (the prefill cursor), or
        None when out of pages even after eviction.  A RESUMING request's
        target is prompt + pre-preemption output: the match re-finds
        whatever pages survived the preemption (the tree's references kept
        them alive) and only the lost remainder re-prefills."""
        scfg = self.scfg
        ps = scfg.page_size
        target = req.target
        P = len(target)
        matched = self.prefix.match(target)
        # a fully cached prompt still recomputes its LAST token (we need
        # its logits to start decoding); that token's K/V write lands in
        # the final cached page, which therefore gets a private
        # copy-on-write copy instead of being attached
        full_cover = bool(matched) and len(matched) * ps >= P
        shared = matched[:-1] if full_cover else matched
        need_total = pages_needed(P + req.remaining_new, ps)
        n_fresh = need_total - len(shared)
        if not self._ensure_free(n_fresh, protect=matched):
            return None
        if shared:
            self.allocator.attach(slot, shared)
        owned = self.allocator.alloc(slot, n_fresh)
        if full_cover:
            self._copy_page(matched[-1], owned[len(shared)])
            self.cow_copies += 1
        self._note_alloc()
        start = P - 1 if full_cover else len(shared) * ps
        self.prefix_hit_tokens += start
        return start

    def _stamp_admit(self, req: Request):
        """Monotone admission stamp: the preemption policy sheds the most
        recently admitted PREFILLING victim first (it has the least sunk
        prefill work and the longest road ahead)."""
        req.admit_seq = self._admit_seq
        self._admit_seq += 1

    def _admit_prefix(self, slot: int, req: Request) -> bool:
        """Prefix-cached monolithic admission: the whole uncached suffix
        prefills in one pass - literally the request's FINAL chunk, so
        this delegates to _run_chunk (which samples the first token and
        flips the request to DECODING).  False = out of pages even after
        eviction."""
        start = self._reserve_prefix(slot, req)
        if start is None:
            return False
        self.sched.pop(req)
        self.slots[slot] = req
        req.slot = slot
        req.prefill_pos = start
        req.state = RequestState.PREFILLING
        self._stamp_admit(req)
        self._phase(req, "PREFILLING", slot, cached_tokens=start)
        # the decode step later this tick walks the slot's row on device
        self.cache["block_table"] = self.allocator.table_device()
        self._note_table_upload(self.allocator.table.nbytes)
        self._run_chunk(ChunkTask(req, slot, start,
                                  len(req.prompt) - start))
        return True

    def _admit_stepwise(self, slot: int, req: Request):
        """Token-by-token prefill through decode_step (exact for every
        architecture family, including recurrent state caches)."""
        self.sched.pop(req)
        self._phase(req, "PREFILLING", slot)
        lens = self.lens
        cache = self.cache
        last_logits = None
        w0 = self._wall()
        for t in req.prompt:
            tok = self.tokens.at[slot, 0].set(t)
            pos = lens
            self.jit_calls += 1
            logits, cache = self._decode(self.params, cache, tok, pos)
            lens = lens.at[slot].add(1)
            last_logits = logits
        # one aggregated record for the whole token-by-token sweep
        self._note_launch("stepwise", rows=1, live_rows=1,
                          true_tokens=len(req.prompt),
                          padded_tokens=len(req.prompt),
                          kv_pages_read=0, kv_pages_written=0,
                          new_kv_tokens=len(req.prompt), wall0=w0,
                          wall1=self._wall())
        self.cache, self.lens = cache, lens
        self._lens_np[slot] = len(req.prompt)
        self.prefill_tokens += len(req.prompt)
        self.sched.note_work(len(req.prompt))
        self.host_syncs += 1
        nxt = int(self._sample(last_logits)[slot, 0]) \
            if last_logits is not None else 0
        self.tokens = self.tokens.at[slot, 0].set(nxt)
        self.slots[slot] = req
        req.slot = slot
        req.prefill_pos = len(req.prompt)
        req.state = RequestState.DECODING
        self._phase(req, "DECODING", slot)
        if self._emit(req, nxt):
            self._finish(req)

    # ------------------------------------------------------------------
    # chunked prefill (token-budget schedule)
    # ------------------------------------------------------------------
    def _reserve_chunked(self, slot: int, req: Request) -> bool:
        """Chunked admission: reserve pages (through the prefix cache when
        enabled) and mark the request PREFILLING with its cursor at the
        cached-prefix boundary - no prompt computation happens here; the
        scheduler streams chunks in over the coming ticks."""
        if self.prefix is not None:
            start = self._reserve_prefix(slot, req)
            if start is None:
                return False
        else:
            need = pages_needed(len(req.target) + req.remaining_new,
                                self.scfg.page_size)
            if not self.allocator.can_alloc(need):
                return False
            self.allocator.alloc(slot, need)
            self._note_alloc()
            start = 0
        self.slots[slot] = req
        req.slot = slot
        req.prefill_pos = start
        req.state = RequestState.PREFILLING
        self._stamp_admit(req)
        self._phase(req, "PREFILLING", slot, cached_tokens=start)
        return True

    def _run_chunk(self, task: ChunkTask):
        """Execute one planned prefill chunk through the offset-causal
        block-table kernel; the chunk's K/V lands in the slot's pages and
        its queries attend over everything already written (cached prefix
        + earlier chunks).  The final chunk samples the request's first
        token from the prompt's last logits and flips it to DECODING.
        (The sequential oracle path - ServeConfig.batched=False - and the
        monolithic prefix-suffix admission; the batched tick replaces the
        per-chunk launches and per-token syncs with _run_chunk_batch.)"""
        req, slot = task.req, task.slot
        ps = self.scfg.page_size
        start, n = task.start, task.length
        s_pad = -(-n // ps) * ps
        toks = np.zeros((1, s_pad), np.int32)
        toks[0, :n] = req.target[start:start + n]
        # copy: the row is a view into the live allocator table (see
        # _sync_table for the zero-copy aliasing hazard)
        page_row = jnp.asarray(self.allocator.table[slot].copy(), jnp.int32)
        batch = {"tokens": jnp.asarray(toks),
                 "offset": jnp.asarray([start], jnp.int32),
                 "true_lens": jnp.asarray([start + n], jnp.int32)}
        self.jit_calls += 1
        w0 = self._wall()
        logits, self.cache, _ = self._prefill_chunk(
            self.params, batch, self.cache, page_row)
        req.prefill_pos = start + n
        self.prefill_tokens += n
        self.sched.note_work(n)
        self.sched.chunks_run += 1
        self._note_launch("chunk", rows=1, live_rows=1, true_tokens=n,
                          padded_tokens=s_pad,
                          kv_pages_read=self._row_pages(slot, start + n),
                          kv_pages_written=self._span_pages(start,
                                                            start + n),
                          new_kv_tokens=n, wall0=w0, wall1=self._wall())
        if req.prefill_pos >= len(req.target):
            self.lens = self.lens.at[slot].set(len(req.target))
            self._lens_np[slot] = len(req.target)
            self.host_syncs += 1
            nxt = int(self._sample(logits)[0, 0])
            self.tokens = self.tokens.at[slot, 0].set(nxt)
            req.state = RequestState.DECODING
            self._phase(req, "DECODING", slot)
            self._table_dirty = True     # unmask the slot's device row
            if self._emit(req, nxt):
                self._finish(req)

    def _run_chunk_batch(self, tasks: List[ChunkTask]):
        """Execute EVERY prefill chunk planned this tick in ONE jitted
        launch: the scheduler packs the tasks into a ragged K-row batch
        (power-of-two bucketed, dead rows padded to the null page like
        the masked decode table), each row carrying its own offset /
        cursor / block-table row; final-chunk first tokens are sampled
        device-side inside the launch and land in the engine's tokens /
        lens via masked scatters.  Returns the final rows' deferred
        emissions [(req, slot, work-clock stamp)] - their token VALUES
        surface in the tick's single device_get after the decode launch.

        Host accounting walks the tasks in plan order so work-clock
        TTFT/TBT match the sequential per-chunk path bit for bit."""
        pack = self.sched.pack_chunks(tasks)
        finals = []
        for t in tasks:
            t.req.prefill_pos = t.start + t.length
            self.prefill_tokens += t.length
            self.sched.note_work(t.length)
            self.sched.chunks_run += 1
            if t.req.prefill_pos >= len(t.req.target):
                t.req.state = RequestState.DECODING
                self._phase(t.req, "DECODING", t.slot)
                self._table_dirty = True     # unmask the slot's device row
                self._lens_np[t.slot] = len(t.req.target)
                finals.append((t.req, t.slot, self.sched.work_clock))
        # per-row block-table rows from the host allocator (dead rows keep
        # the all-null table so every page walk lands on the null page)
        tables = np.zeros((pack.tokens.shape[0],
                           self.allocator.table.shape[1]), np.int32)
        live = pack.row_slots >= 0
        tables[live] = self.allocator.table[pack.row_slots[live]]
        batch = {"tokens": jnp.asarray(pack.tokens),
                 "offset": jnp.asarray(pack.offsets),
                 "true_lens": jnp.asarray(pack.true_lens),
                 "final_slot": jnp.asarray(pack.final_slots)}
        self.jit_calls += 1
        self.sched.packs_run += 1
        w0 = self._wall()
        self.cache, self.tokens, self.lens = self._prefill_chunks(
            self.params, batch, self.cache, jnp.asarray(tables),
            self.tokens, self.lens, self._next_key())
        n_true = sum(t.length for t in tasks)
        self._note_launch(
            "chunk_batch", rows=int(pack.tokens.shape[0]),
            live_rows=len(tasks), true_tokens=n_true,
            padded_tokens=int(pack.tokens.shape[0] * pack.tokens.shape[1]),
            kv_pages_read=sum(self._row_pages(t.slot, t.start + t.length)
                              for t in tasks),
            kv_pages_written=sum(self._span_pages(t.start, t.start
                                                  + t.length)
                                 for t in tasks),
            new_kv_tokens=n_true, wall0=w0, wall1=self._wall())
        return finals

    def _run_spec_verify(self, tasks: List[DraftTask]) -> SpecBatch:
        """Execute EVERY draft chain planned this tick in ONE jitted
        launch through the batched chunk kernel: the scheduler packs the
        chains into a ragged verify batch (pack_drafts) with per-row
        block-table rows from the host allocator, the device samples the
        target's token at every chain position, accepts the matching
        draft prefix, writes the bonus token into the engine's tokens and
        the new KV frontier into lens (rejected positions past it are
        dead - rollback is free), and leaves the per-row acceptance
        counts in _spec_nacc for the host to fetch WITH the tick's
        tokens - no extra device->host sync."""
        pack = self.sched.pack_drafts(tasks, self._lens_np)
        # per-row block-table rows from the host allocator (dead rows
        # keep the all-null table, copied - never aliased - like
        # _run_chunk_batch)
        tables = np.zeros((pack.tokens.shape[0],
                           self.allocator.table.shape[1]), np.int32)
        live = pack.row_slots < self.scfg.max_batch
        tables[live] = self.allocator.table[pack.row_slots[live]]
        batch = {"tokens": jnp.asarray(pack.tokens),
                 "offset": jnp.asarray(pack.offsets),
                 "true_lens": jnp.asarray(pack.true_lens),
                 "q_lens": jnp.asarray(pack.q_lens),
                 "draft_lens": jnp.asarray(pack.draft_lens),
                 "row_slot": jnp.asarray(pack.row_slots)}
        self.jit_calls += 1
        self.decode_launches += 1
        ps = self.scfg.page_size
        self._note_kv_pages_read(sum(-(-int(t) // ps)
                                     for t in pack.true_lens[live]))
        w0 = self._wall()
        self.cache, self.tokens, self.lens, self._spec_nacc = \
            self._spec_verify(self.params, batch, self.cache,
                              jnp.asarray(tables), self.tokens, self.lens,
                              self._next_key())
        n_q = sum(1 + len(t.draft) for t in pack.tasks)
        self._note_launch(
            "spec_verify", rows=int(pack.tokens.shape[0]),
            live_rows=len(pack.tasks), true_tokens=n_q,
            padded_tokens=int(pack.tokens.shape[0] * pack.tokens.shape[1]),
            kv_pages_read=sum(self._row_pages(t.slot,
                                              t.offset + 1 + len(t.draft))
                              for t in pack.tasks),
            kv_pages_written=sum(
                self._span_pages(t.offset, t.offset + 1 + len(t.draft))
                for t in pack.tasks),
            new_kv_tokens=n_q, wall0=w0, wall1=self._wall())
        return pack

    # ------------------------------------------------------------------
    # preemption (ServeConfig.preemption): shed low-priority load when the
    # page pool - or the slot table - cannot place a higher-priority
    # admission candidate
    # ------------------------------------------------------------------
    def _next_victim(self, cand: Request) -> Optional[Request]:
        """Victim policy: only requests CAND strictly outranks are
        eligible (equal priority never preempts - the priority-inversion
        guard, and what keeps all-default-priority traffic preemption
        free).  PREFILLING victims go first - lowest priority, most
        recently admitted first (least sunk prefill work) - then DECODING
        victims, lowest priority, longest remaining generation first
        (shedding the one that would hold its pages longest)."""
        best, best_key = None, None
        for r in self.slots:
            if r is None or r.priority >= cand.priority:
                continue
            if r.state is RequestState.PREFILLING:
                key = (0, r.priority, -r.admit_seq)
            elif r.state is RequestState.DECODING:
                key = (1, r.priority, -r.remaining_new, -r.admit_seq)
            else:
                continue
            if best_key is None or key < best_key:
                best, best_key = r, key
        return best

    def _preempt(self, victim: Request):
        """Shed one running request: drop the slot's reference on every
        page it holds - private pages return to the pool, prefix-cache
        pages survive through the tree's refcount - zero its lane, and
        park it back in the queue as RESUMING.  A mid-decode victim
        snapshots prompt + generated-so-far as its resume target
        (Request.target): the chunk path rebuilds that KV on resume and
        the final resume chunk's logits sample the NEXT token exactly as
        the uninterrupted decode would have.

        Publish-on-preempt (prefix cache on): instead of freeing, the
        victim's fully-written pages PARK in the radix tree keyed by the
        tokens whose KV they hold - on resume the prefix match re-attaches
        them and only the lost tail re-prefills; under continued pressure
        they are ordinary evictable cache.  Only fully-VALID positions
        publish: prefill_pos for a PREFILLING victim, the lens mirror for
        a DECODING one (the pending token's KV is unwritten, and any
        speculative garbage past lens must never enter the tree)."""
        slot = victim.slot
        free0 = self.allocator.free_pages
        if self.prefix is not None:
            if victim.state is RequestState.PREFILLING:
                n_valid = victim.prefill_pos
                seq = list(victim.target)
            else:
                seq = victim.prompt + list(victim.out_tokens)
                n_valid = int(self._lens_np[slot])
            cached0 = self.prefix.cached_pages
            self.prefix.release(slot, seq[:n_valid])
            self.sched.pages_parked += self.prefix.cached_pages - cached0
        else:
            self.allocator.free_slot(slot)
        self.sched.pages_reclaimed += self.allocator.free_pages - free0
        self.sched.preemptions += 1
        victim.n_preemptions += 1
        self._event(victim, "PREEMPT", slot,
                    pages_reclaimed=self.allocator.free_pages - free0)
        self._phase(victim, "RESUMING", TRACK_QUEUE)
        self.slots[slot] = None
        self.lens = self.lens.at[slot].set(0)
        self._lens_np[slot] = 0
        victim.slot = None
        victim.prefill_pos = 0
        if victim.out_tokens:
            victim.resume_tokens = victim.prompt + list(victim.out_tokens)
        victim.state = RequestState.RESUMING
        self.sched.requeue(victim)
        self._table_dirty = True     # lane must mask to the null page

    def _try_preempt(self, cand: Request) -> bool:
        """Shed ONE victim toward placing CAND (the admission loop retries
        the reservation after each).  False when preemption is off, no
        eligible victim exists, or shedding every eligible victim plus
        evicting the whole prefix cache could still not cover CAND's
        worst-case reservation - then backpressure is the right answer
        and shedding would only waste the victims' work."""
        if not self.scfg.preemption:
            return False
        victim = self._next_victim(cand)
        if victim is None:
            return False
        need = pages_needed(len(cand.target) + cand.remaining_new,
                            self.scfg.page_size)
        headroom = self.allocator.free_pages
        if self.prefix is not None:
            headroom += self.prefix.evictable_pages()
        # DISTINCT pages across eligible victims: a page shared by two
        # victim slots (or with the prefix tree) frees - or becomes
        # evictable - only once, so counting per slot would overstate the
        # reclaim and shed victims for nothing
        victim_pages = {p for r in self.slots
                        if r is not None and r.priority < cand.priority
                        for p in self.allocator.slot_pages(r.slot)}
        headroom += len(victim_pages)
        if need > headroom:
            return False
        self._preempt(victim)
        return True

    def _tick_chunked(self) -> List[Request]:
        """One budgeted iteration: admit, fill the budget with prefill
        chunks, run one batched decode step for the slots that were
        already decoding.  Total work never exceeds tick_token_budget.

        With ServeConfig.batched (default) the tick is ONE batched ragged
        prefill launch + ONE fused decode launch + ONE device->host
        transfer, whatever the traffic: all sampling happens device-side
        and token values surface in a single fetch at the end, so the
        host loop carries no per-chunk or per-slot round-trips (the
        serving analogue of the paper's bubble-free vertical dataflow -
        fine-grained chunking only wins once per-step dispatch overhead
        is gone).  batched=False keeps one launch per chunk and per-slot
        emission: the sequential parity oracle."""
        w0 = self.sched.work_clock
        wp0 = self._wall()
        # admission FIRST (it can preempt: a decoding victim shed here
        # must not join this tick's decode batch): reserve slots + pages
        # for as many queued requests as the policy head allows (no
        # prompt computation yet).  When the head cannot be placed and
        # outranks a running request, shed victims one at a time and
        # retry; otherwise head-of-line backpressure as before.
        while True:
            req = self.sched.peek()
            if req is None:
                break
            resuming = req.state is RequestState.RESUMING
            placed = False
            while True:
                slot = self._free_slot()
                if slot is not None and self._reserve_chunked(slot, req):
                    placed = True
                    break
                if not self._try_preempt(req):
                    break
            if not placed:
                break
            self.sched.pop(req)
            if resuming:
                self.sched.resumes += 1
                req.n_resumes += 1
                self._event(req, "RESUME", req.slot)
        if self._table_dirty:
            # a preemption zeroed a lane (or freed pages that admission
            # just re-allocated): the device table must mask it to the
            # null page BEFORE this tick's launches touch the pool
            self._sync_table()
        decode_slots = [i for i, r in enumerate(self.slots)
                        if r is not None
                        and r.state is RequestState.DECODING]
        prefilling = [(i, r) for i, r in enumerate(self.slots)
                      if r is not None
                      and r.state is RequestState.PREFILLING]
        # speculative drafting: DECODING slots may propose chains out of
        # the budget left after every decode slot took its guaranteed
        # token; prefill planning gets what remains after drafts, so the
        # tick's total work stays bounded by tick_token_budget
        spec_tasks: List[DraftTask] = []
        spec_tokens = 0
        if self.speculative and decode_slots:
            room = self.scfg.tick_token_budget - len(decode_slots)
            spec_tasks = self.sched.plan_drafts(
                [(i, self.slots[i]) for i in decode_slots], room)
            spec_tokens = sum(len(t.draft) for t in spec_tasks)
        budget = self.sched.prefill_budget(len(decode_slots) + spec_tokens)
        chunks = self.sched.plan_chunks(prefilling, budget)
        self._tick_profile = (len(chunks), len(decode_slots))
        tr = self.tm.tracer
        if tr is not None:
            # the tick's host-side planning phase: admission (incl. any
            # preemption), draft planning, and chunk planning
            tr.add_span("plan", "tick", TRACK_ENGINE, self.sched.ticks,
                        w0, self.sched.work_clock, wp0, tr.now(),
                        n_chunks=len(chunks), n_decode=len(decode_slots),
                        n_drafts=len(spec_tasks))
        finals = []
        if chunks:
            if self.scfg.batched:
                finals = self._run_chunk_batch(chunks)
            else:
                for task in chunks:
                    self._run_chunk(task)
        # drafted slots verify their whole chain in the spec launch; the
        # rest take their one token through the fused decode as before
        spec_slots = {t.slot for t in spec_tasks}
        plain_slots = [i for i in decode_slots if i not in spec_slots]
        if decode_slots and self.prefix is not None:
            self._cow_guard({t.slot: len(t.draft) for t in spec_tasks})
        spec_pack = None
        if spec_tasks:
            spec_pack = self._run_spec_verify(spec_tasks)
        if plain_slots:
            live = np.zeros((len(self.slots),), bool)
            live[plain_slots] = True
            self.jit_calls += 1
            self.decode_launches += 1
            self._note_kv_pages_read(sum(
                -(-(int(self._lens_np[i]) + 1) // self.scfg.page_size)
                for i in plain_slots))
            pages_read = sum(self._row_pages(i, int(self._lens_np[i]) + 1)
                             for i in plain_slots)
            lw0 = self._wall()
            self.cache, self.tokens, self.lens = self._decode_fused(
                self.params, self.cache, self.tokens, self.lens,
                jnp.asarray(live), self._next_key())
            self._note_launch("decode", rows=len(self.slots),
                              live_rows=len(plain_slots),
                              true_tokens=len(plain_slots),
                              padded_tokens=len(self.slots),
                              kv_pages_read=pages_read,
                              kv_pages_written=len(plain_slots),
                              new_kv_tokens=len(plain_slots), wall0=lw0,
                              wall1=self._wall())
            self.sched.note_work(len(plain_slots))
            self._lens_np[plain_slots] += 1
        gen_work = len(plain_slots)
        if finals or plain_slots or spec_pack is not None:
            # THE device->host transfer: every sampled token of the tick
            # (plus, speculating, every chain's acceptance count)
            wf0 = self._wall()
            if spec_pack is not None:
                self.host_syncs += 1
                toks, naccs = (np.asarray(x) for x in jax.device_get(
                    (self.tokens, self._spec_nacc)))
            else:
                toks = self._fetch_tokens()
            tr = self.tm.tracer
            if tr is not None:
                tr.add_span("device_get", "tick", TRACK_ENGINE,
                            self.sched.ticks, self.sched.work_clock,
                            self.sched.work_clock, wf0, tr.now())
            for req, slot, work in finals:
                if self._emit(req, int(toks[slot, 0]), work=work):
                    self._finish(req)
            if spec_pack is not None:
                for r, t in enumerate(spec_pack.tasks):
                    n = int(naccs[r])
                    self.sched.note_spec(len(t.draft), n)
                    self._event(t.req, "SPEC_VERIFY", t.slot,
                                drafted=len(t.draft), accepted=n)
                    self._lens_np[t.slot] = t.offset + n + 1
                    # accepted draft prefix + the target's bonus token;
                    # work-clock advances per ACCEPTED token only, so
                    # work_tokens match a non-speculative run exactly
                    chain = list(t.draft[:n]) + [int(toks[t.slot, 0])]
                    for tok in chain:
                        self.sched.note_work(1)
                        gen_work += 1
                        if self._emit(t.req, tok):
                            self._finish(t.req)
                            break
            for i in plain_slots:
                req = self.slots[i]
                if self._emit(req, int(toks[i, 0])):
                    self._finish(req)
        self.sched.note_tick(gen_work,
                             self.sched.work_clock - w0 - gen_work)
        if self._finished_this_tick:
            self._maybe_evict_watermark()
        if self._table_dirty:
            self._sync_table()
        return self._finished_this_tick

    # ------------------------------------------------------------------
    def _cow_guard(self, spec_spans: Optional[Dict[int, int]] = None):
        """Give any decoding slot about to WRITE into a shared page a
        private copy first.  By construction generation pages are private
        (the one structural COW happens at admission), so this is a cheap
        defensive sweep - but it makes 'decode never corrupts a cached
        page' an invariant of the tick loop rather than of the admission
        math.  Slots still prefilling are skipped: their decode write lane
        is masked to the null page, not to table[lens // page_size].
        `spec_spans` maps slots with a planned draft chain to its length
        m: the verify launch writes positions lens .. lens + m, so every
        page that range touches gets the same guard."""
        ps = self.scfg.page_size
        lens = self._lens_np          # host mirror: no device->host sync
        spans = spec_spans or {}
        dirty = False
        for i, req in enumerate(self.slots):
            if req is None or req.state is not RequestState.DECODING:
                continue
            lo = int(lens[i]) // ps
            hi = (int(lens[i]) + spans.get(i, 0)) // ps
            for idx in range(lo, hi + 1):
                page = int(self.allocator.table[i, idx])
                if self.allocator.refcount(page) > 1:
                    src, dst = self.allocator.cow(i, idx)
                    self._copy_page(src, dst)
                    self.cow_copies += 1
                    dirty = True
        if dirty:
            self._sync_table()

    def _maybe_evict_watermark(self):
        if self.prefix is not None and self.scfg.prefix_evict_watermark > 0:
            usable = self.allocator.usable_pages
            target = math.ceil(self.scfg.prefix_evict_watermark * usable)
            short = target - self.allocator.free_pages
            if short > 0:
                self.prefix.evict(short)

    def tick(self) -> List[Request]:
        """One engine iteration.  Monolithic: admit (full prefills) + one
        batched decode step.  Chunked: one token-budgeted round of decode
        + prefill chunks.  Returns requests that finished this tick.
        Every tick appends a dispatch-accounting row to launch_log:
        (jit_calls, host_syncs, host_wall_s, n_chunk_tasks, n_decode)."""
        self._finished_this_tick = []
        self._tick_profile = (0, 0)
        self._expire_deadlines()
        j0, s0 = self.jit_calls, self.host_syncs
        tick0 = self.sched.ticks
        work0 = self.sched.work_clock
        wt0 = self._wall()
        t0 = time.perf_counter()
        out = self._tick_chunked() if self.chunked \
            else self._tick_monolithic()
        self.tm.ticks.append(TickRecord(
            self.jit_calls - j0, self.host_syncs - s0,
            time.perf_counter() - t0, *self._tick_profile))
        tr = self.tm.tracer
        if tr is not None:
            tr.add_span("tick", "tick", TRACK_ENGINE, tick0, work0,
                        self.sched.work_clock, wt0, tr.now(),
                        jit_calls=self.jit_calls - j0,
                        host_syncs=self.host_syncs - s0,
                        n_chunks=self._tick_profile[0],
                        n_decode=self._tick_profile[1])
        return out

    def _tick_monolithic(self) -> List[Request]:
        w0 = self.sched.work_clock
        self._admit()
        if self._finished_this_tick and self.paged:
            # a request can finish AT admission (stop token / length 1 on
            # its first sampled token); its pages just went back to the
            # pool or into the prefix cache, but the device table still
            # maps its lane to them - re-upload BEFORE the decode step or
            # the lane's masked write (lens == 0) corrupts position 0 of a
            # freed or published page
            self._sync_table()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            if self.sched.work_clock > w0:      # admissions that finished
                self.sched.note_tick(0, self.sched.work_clock - w0)
            return self._finished_this_tick
        self._tick_profile = (0, len(active))
        if self.prefix is not None:
            self._cow_guard()
        # one fused launch: decode + device-side sampling + vectorized
        # masked token/length updates, then ONE device->host transfer for
        # every lane's sampled token (was: one int() sync + two .at[i]
        # dispatches PER SLOT)
        live = np.zeros((len(self.slots),), bool)
        live[active] = True
        self.jit_calls += 1
        self.decode_launches += 1
        pages_read = 0
        if self.paged:
            self._note_kv_pages_read(sum(
                -(-(int(self._lens_np[i]) + 1) // self.scfg.page_size)
                for i in active))
            pages_read = sum(self._row_pages(i, int(self._lens_np[i]) + 1)
                             for i in active)
        lw0 = self._wall()
        self.cache, self.tokens, self.lens = self._decode_fused(
            self.params, self.cache, self.tokens, self.lens,
            jnp.asarray(live), self._next_key())
        self._note_launch("decode", rows=len(self.slots),
                          live_rows=len(active), true_tokens=len(active),
                          padded_tokens=len(self.slots),
                          kv_pages_read=pages_read,
                          kv_pages_written=len(active) if self.paged else 0,
                          new_kv_tokens=len(active), wall0=lw0,
                          wall1=self._wall())
        self.sched.note_work(len(active))
        self._lens_np[active] += 1
        toks = self._fetch_tokens()
        for i in active:
            req = self.slots[i]
            if self._emit(req, int(toks[i, 0])):
                self._finish(req)
        self.sched.note_tick(len(active),
                             self.sched.work_clock - w0 - len(active))
        if self._finished_this_tick and self.paged:
            self._maybe_evict_watermark()
            self._sync_table()
        return self._finished_this_tick

    def run_until_done(self, max_ticks: int = 10_000,
                       on_exhaust: str = "raise") -> List[Request]:
        """Tick until queue and slots drain.  If `max_ticks` runs out with
        work still pending the engine RAISES (on_exhaust="raise", default)
        so a hung scheduler cannot masquerade as a completed trace; pass
        on_exhaust="return" to get the partial results back instead."""
        done: List[Request] = []
        for _ in range(max_ticks):
            done.extend(self.tick())
            if not self.queue and all(s is None for s in self.slots):
                return done
        n_flight = sum(s is not None for s in self.slots)
        if not self.queue and n_flight == 0:
            return done
        msg = (f"run_until_done: {max_ticks} ticks exhausted with "
               f"{len(self.queue)} queued and {n_flight} in-flight "
               f"requests still pending ({len(done)} finished)")
        if on_exhaust == "raise":
            raise RuntimeError(msg)
        import warnings
        warnings.warn(msg)
        return done
