"""Radix-tree prefix cache: prompt-page reuse with copy-on-write paging.

Real serving traffic is full of repeated prompt prefixes - shared system
prompts, few-shot templates, multi-turn chat where every turn resends the
conversation so far.  The paged KV pool (serve/paged_cache.py) already
stores K/V in position-independent pages; this module adds the host-side
index that lets a NEW request reuse pages an earlier request computed:

  radix tree   keyed by page-sized token blocks, with path compression
               (one node can label a run of many blocks).  `match` walks
               the longest cached prefix of a prompt, whole pages only -
               a page is shared either completely or not at all, so the
               K/V inside shared pages is immutable by construction.
  refcounts    live in the PageAllocator: the tree holds one reference on
               every cached page, each slot using the page holds another.
               Pages return to the free list only when the last reference
               drops - a page is never both free and referenced.
  copy-on-write  a slot that must WRITE into a shared page (refcount > 1)
               first gets a private copy (allocator.cow + a device-side
               page copy by the engine).  The one structural writer is a
               fully cached prompt: its last token is recomputed for
               logits, and that token's K/V lands in the final cached
               page - so admission COWs exactly that page.
  LRU eviction tail-first from the least-recently-used leaves: only pages
               whose sole reference is the tree's are evictable, so an
               in-flight request can never lose a page it is attending
               over.  Trimming from the tail keeps every surviving node a
               valid prefix.

Capacity math (docs/prefix_caching.md): with H requests sharing a P-token
prefix, the pool holds the prefix ONCE (ceil(P / page_size) pages) instead
of H times, and admission prefills only each request's suffix - prefill
compute and peak working-set pages both drop by roughly the hit rate.
"""
from __future__ import annotations

from typing import (Callable, Dict, FrozenSet, List, Optional, Sequence, Set,
                    Tuple)

from .paged_cache import PageAllocator
from .telemetry import MetricsRegistry

Block = Tuple[int, ...]


class _Node:
    """One radix-tree edge: a run of page-sized token blocks and the
    physical page holding each block's K/V."""
    __slots__ = ("blocks", "pages", "children", "parent", "last_used")

    def __init__(self, blocks: List[Block], pages: List[int],
                 parent: Optional["_Node"]):
        self.blocks = blocks
        self.pages = pages
        self.children: Dict[Block, "_Node"] = {}
        self.parent = parent
        self.last_used = 0


class RadixPrefixCache:
    """Host-side prefix index over a PageAllocator's page pool."""

    def __init__(self, allocator: PageAllocator, page_size: int,
                 metrics: Optional[MetricsRegistry] = None):
        self.alloc = allocator
        self.page_size = page_size
        self.root = _Node([], [], None)
        self._clock = 0
        self._pages: Set[int] = set()       # pages the tree holds a ref on
        # cache-traffic counters (serve/telemetry.py registry; the engine
        # shares its registry in, a standalone cache gets its own) plus an
        # optional event hook the engine points at its span tracer so
        # hit / publish / evict instants land on the trace timeline
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        m = self.metrics
        self._m_lookups = m.counter("prefix_lookups_total",
                                    "Prefix-cache match() walks")
        self._m_hits = m.counter("prefix_hits_total",
                                 "match() walks that found >= 1 cached page")
        self._m_hit_pages = m.counter("prefix_hit_pages_total",
                                      "Cached pages returned by match()")
        self._m_pub = m.counter("prefix_published_pages_total",
                                "Prompt pages newly inserted into the tree")
        self._m_evict = m.counter("prefix_evicted_pages_total",
                                  "Cached pages LRU-evicted back to the "
                                  "pool")
        self._m_cached_g = m.gauge("prefix_cached_pages",
                                   "Pages currently held by the tree")
        self.event_cb: Optional[Callable[..., None]] = None

    def _event(self, name: str, **args):
        if self.event_cb is not None:
            self.event_cb(name, **args)

    # -- helpers ------------------------------------------------------------
    def _block_split(self, tokens: Sequence[int]) -> List[Block]:
        ps = self.page_size
        return [tuple(tokens[i * ps:(i + 1) * ps])
                for i in range(len(tokens) // ps)]

    def _touch(self, node: _Node):
        self._clock += 1
        node.last_used = self._clock

    @property
    def cached_pages(self) -> int:
        return len(self._pages)

    def evictable_pages(self) -> int:
        """Pages whose only reference is the tree's (LRU candidates)."""
        return sum(1 for p in self._pages if self.alloc.refcount(p) == 1)

    def cached_prefix_len(self, tokens: Sequence[int]) -> int:
        """Tokens of `tokens` currently resident in cached pages (whole
        pages only) - what a preempted request would NOT have to re-prefill
        if it resumed right now.  Built on the read-only `peek`, so
        measuring survival cannot perturb eviction order."""
        return len(self.peek(tokens)) * self.page_size

    def _walk(self, tokens: Sequence[int], touch: bool) -> List[int]:
        """Longest-cached-prefix walk shared by match / cached_prefix_len:
        page ids covering the longest cached prefix of `tokens`, whole
        pages only; bumps LRU timestamps along the path iff `touch`."""
        blocks = self._block_split(tokens)
        out: List[int] = []
        node = self.root
        i = 0
        while i < len(blocks):
            child = node.children.get(blocks[i])
            if child is None:
                break
            m, lim = 0, min(len(child.blocks), len(blocks) - i)
            while m < lim and child.blocks[m] == blocks[i + m]:
                m += 1
            out.extend(child.pages[:m])
            if touch:
                self._touch(child)
            if m < len(child.blocks):
                break                       # diverged (or prompt ended) mid-edge
            node, i = child, i + m
        return out

    # -- peek (read-only) -----------------------------------------------------
    def peek(self, tokens: Sequence[int]) -> List[int]:
        """Side-effect-free longest-cached-prefix lookup: the page ids
        `match` WOULD return, without claiming them.  Never bumps LRU
        stamps, never advances the tree clock, never touches refcounts,
        and records no metrics or trace events - so an outside observer
        (the fleet router scoring every replica per request) cannot
        perturb eviction order or hit-rate accounting on replicas that
        end up not receiving the request.  The result is advisory: pages
        may be evicted between peek and a later match/attach."""
        return self._walk(tokens, touch=False)

    # -- match ----------------------------------------------------------------
    def match(self, tokens: Sequence[int]) -> List[int]:
        """Page ids holding the longest cached prefix of `tokens`, whole
        pages only.  Bumps LRU timestamps along the path.  The caller must
        `attach` (or protect) the pages before anything else can evict.
        Use `peek` for a read-only lookup with none of these effects."""
        pages = self._walk(tokens, touch=True)
        self._m_lookups.inc()
        if pages:
            self._m_hits.inc()
            self._m_hit_pages.inc(len(pages))
            self._event("prefix_hit", pages=len(pages))
        return pages

    # -- publish ----------------------------------------------------------------
    def publish(self, tokens: Sequence[int], pages: Sequence[int]) -> List[int]:
        """Insert the prompt's full pages into the tree.

        `pages[j]` must hold the K/V of the prompt's j-th token block.  New
        blocks TRANSFER the caller's reference to the tree; blocks the tree
        already caches are returned as duplicates - the caller drops its
        reference on those (tree page and slot page may be the same id:
        unref then simply removes the slot's extra reference)."""
        n_before = len(self._pages)
        dups = self._insert(tokens, pages)
        n_new = len(self._pages) - n_before
        if n_new:
            self._m_pub.inc(n_new)
            self._event("prefix_publish", pages=n_new)
        self._m_cached_g.set(len(self._pages))
        return dups

    def _insert(self, tokens: Sequence[int],
                pages: Sequence[int]) -> List[int]:
        blocks = self._block_split(tokens)
        pages = list(pages[:len(blocks)])
        dups: List[int] = []
        node = self.root
        i = 0
        while i < len(blocks):
            child = node.children.get(blocks[i])
            if child is None:
                new = _Node(blocks[i:], pages[i:], node)
                node.children[blocks[i]] = new
                self._pages.update(pages[i:])
                self._touch(new)
                return dups
            m, lim = 0, min(len(child.blocks), len(blocks) - i)
            while m < lim and child.blocks[m] == blocks[i + m]:
                m += 1
            dups.extend(pages[i:i + m])
            self._touch(child)
            if m == len(child.blocks):
                node, i = child, i + m
                continue
            # diverged (or ran out of prompt) mid-edge: split child at m
            mid = _Node(child.blocks[:m], child.pages[:m], node)
            node.children[blocks[i]] = mid
            child.blocks = child.blocks[m:]
            child.pages = child.pages[m:]
            child.parent = mid
            mid.children[child.blocks[0]] = child
            mid.last_used = child.last_used
            if i + m < len(blocks):
                new = _Node(blocks[i + m:], pages[i + m:], mid)
                mid.children[blocks[i + m]] = new
                self._pages.update(pages[i + m:])
                self._touch(new)
            return dups
        return dups

    # -- release a finished request -----------------------------------------------
    def release(self, slot: int, prompt: Sequence[int]):
        """Publish a finished request's prompt pages instead of freeing
        them.  Pages past the prompt's last full page (the partial tail
        page and all generation pages) go back to the pool."""
        pages = self.alloc.detach(slot)
        n_pub = len(prompt) // self.page_size
        for p in self.publish(prompt, pages[:n_pub]):
            self.alloc.unref(p)             # tree already caches this block
        for p in pages[n_pub:]:
            self.alloc.unref(p)

    # -- eviction ---------------------------------------------------------------
    def evict(self, n_pages: int,
              protect: FrozenSet[int] = frozenset()) -> int:
        """Free up to n_pages cached pages, LRU leaves first, tail-first
        within a leaf.  Pages in `protect` or referenced by any slot
        (refcount > 1) are never touched.  Returns pages actually freed."""
        freed = 0
        while freed < n_pages:
            leaves = self._leaves()
            leaves.sort(key=lambda nd: nd.last_used)
            progressed = False
            for leaf in leaves:
                first_block = leaf.blocks[0]
                while leaf.pages and freed < n_pages:
                    pg = leaf.pages[-1]
                    if pg in protect or self.alloc.refcount(pg) > 1:
                        break
                    leaf.pages.pop()
                    leaf.blocks.pop()
                    self._pages.discard(pg)
                    self.alloc.unref(pg)
                    freed += 1
                    progressed = True
                if not leaf.pages and leaf.parent is not None:
                    del leaf.parent.children[first_block]
                if freed >= n_pages:
                    break
            if not progressed:
                break                       # everything left is pinned
        if freed:
            self._m_evict.inc(freed)
            self._event("prefix_evict", pages=freed)
        self._m_cached_g.set(len(self._pages))
        return freed

    def _leaves(self) -> List[_Node]:
        out, stack = [], [self.root]
        while stack:
            nd = stack.pop()
            kids = list(nd.children.values())
            if not kids and nd is not self.root:
                out.append(nd)
            stack.extend(kids)
        return out

    # -- invariants ---------------------------------------------------------------
    def check_invariants(self):
        """Tree bookkeeping must agree with the allocator: every cached
        page carries the tree's reference, and the _pages set mirrors the
        tree exactly.  Delegates the global no-page-both-free-and-
        referenced check to the allocator."""
        in_tree: Set[int] = set()
        stack = [self.root]
        while stack:
            nd = stack.pop()
            assert len(nd.blocks) == len(nd.pages)
            in_tree.update(nd.pages)
            stack.extend(nd.children.values())
        assert in_tree == self._pages, "tree / _pages set out of sync"
        for p in self._pages:
            assert p != 0, "null page cached"
            assert self.alloc.refcount(p) >= 1, f"cached page {p} unreferenced"
        self.alloc.check_invariants(tree_pages=self._pages)
