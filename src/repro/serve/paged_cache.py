"""Paged KV-cache bookkeeping: fixed-size pages, a free list, block tables.

The device side is a global page pool per layer - (num_pages, page_size,
Hkv, D) slabs shared by every sequence (see models/model.py init_cache) -
plus one (max_batch, max_pages_per_seq) int32 block table.  This module owns
the HOST side: which pages are free, which belong to which slot, and the
numpy mirror of the block table.  All methods are O(pages moved); nothing
here touches jax except the tiny block-table upload.

Page 0 is the reserved NULL page.  Block-table rows of idle slots point at
it, so the batched decode step's masked K/V writes from inactive lanes land
in a page no live sequence owns (reads are masked by `lens` anyway).  Usable
capacity is therefore ``num_pages - 1`` pages.

Capacity math (see docs/serving.md): a request of P prompt tokens with N
generation budget holds ceil((P + N) / page_size) pages from admission to
completion, vs. a dense slot's ceil(max_seq / page_size).  With mixed
request lengths the pool can be sized well below max_batch * max_seq and
still never reject mid-flight: admission reserves the worst case up front,
so the only backpressure point is `can_alloc` at admit time.
"""
from __future__ import annotations

import math
from typing import List

import jax.numpy as jnp
import numpy as np

from typing import Optional

from ..configs.base import (ModelConfig, ServeConfig, dense_equivalent_pages,
                            pages_for_tokens)
from .telemetry import MetricsRegistry

# canonical page math lives in configs.base; re-exported under the serving
# vocabulary ("how many pages does this request need")
pages_needed = pages_for_tokens


def dense_kv_bytes(cfg: ModelConfig, scfg: ServeConfig) -> int:
    """Bytes of the dense (L, max_batch, max_seq, Hkv, D) K+V cache."""
    dt = jnp.dtype(cfg.dtype).itemsize
    return (2 * cfg.n_layers * scfg.max_batch * scfg.max_seq
            * cfg.n_kv_heads * cfg.head_dim * dt)


def paged_kv_bytes(cfg: ModelConfig, scfg: ServeConfig,
                   num_pages: int = 0) -> int:
    """Bytes of the paged (L, num_pages, page_size, Hkv, D) K+V pool."""
    if num_pages <= 0:
        num_pages = dense_equivalent_pages(scfg.max_batch, scfg.max_seq,
                                           scfg.page_size)
    dt = jnp.dtype(cfg.dtype).itemsize
    return (2 * cfg.n_layers * num_pages * scfg.page_size
            * cfg.n_kv_heads * cfg.head_dim * dt)


def page_kv_bytes(cfg: ModelConfig, page_size: int) -> int:
    """Bytes of K+V ONE page holds across every layer - the unit the
    engine's analytic kv_pages_read accounting converts to bytes."""
    dt = jnp.dtype(cfg.dtype).itemsize
    return 2 * cfg.n_layers * page_size * cfg.n_kv_heads * cfg.head_dim * dt


def shard_page_kv_bytes(cfg: ModelConfig, page_size: int,
                        tp_degree: int) -> int:
    """Bytes of K+V one page holds ON ONE DEVICE of a head-sharded
    tensor-parallel pool: each of the tp_degree shards owns an
    Hkv/tp_degree head slice of every page, so per-device page bytes are
    exactly page_kv_bytes / tp_degree.  The allocator's page ids and block
    table are replicated (every shard walks the same table), which is why
    the engine's per-shard byte accounting can reuse the single allocator
    unchanged - the cross-check in tests/conformance.py asserts
    shard_bytes * tp_degree == kv_pages_read * page_kv_bytes."""
    if tp_degree < 1:
        raise ValueError(f"tp_degree must be >= 1, got {tp_degree}")
    if cfg.n_kv_heads % tp_degree:
        raise ValueError(
            f"n_kv_heads ({cfg.n_kv_heads}) must divide by tp_degree "
            f"({tp_degree}) for a head-sharded page pool")
    return page_kv_bytes(cfg, page_size) // tp_degree


class OutOfPages(RuntimeError):
    """Raised by alloc() when the free list cannot cover a reservation."""


class PageAllocator:
    """Free-list page allocator + per-slot page lists + block-table mirror.

    Pages are REFERENCE COUNTED: `alloc` hands out private pages (refcount
    1), `attach` lets a slot share pages another holder already references
    (refcount + 1 each - prefix caching shares cached prompt pages this
    way), and `unref` returns a page to the free list only when its last
    reference drops.  `cow` gives a slot a private replacement for a shared
    page before a write would touch it (copy-on-write bookkeeping; the
    engine copies the device-side page contents).  Exclusive use - alloc /
    free_slot only - behaves exactly like the pre-refcount allocator.
    """

    def __init__(self, num_pages: int, page_size: int, max_batch: int,
                 max_seq: int, usable_pages: int = 0,
                 metrics: Optional[MetricsRegistry] = None):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is the null page)")
        self.num_pages = num_pages
        self.page_size = page_size
        # soft capacity cap (ServeConfig.usable_pages): only pages
        # 1..usable_pages are ever handed out; the device pool keeps its
        # full num_pages shape, so capacity pressure can be dialed without
        # recompiling anything
        self.usable_pages = usable_pages or (num_pages - 1)
        if not 1 <= self.usable_pages <= num_pages - 1:
            raise ValueError(f"usable_pages ({usable_pages}) must be in "
                             f"[1, {num_pages - 1}]")
        self.max_pages_per_seq = pages_needed(max_seq, page_size)
        # LIFO free list; page 0 stays reserved forever
        self._free: List[int] = list(range(self.usable_pages, 0, -1))
        # fault-injection hook: pages withheld from circulation by
        # quarantine() (deterministic page-pool-exhaustion chaos).  They
        # are neither free nor referenced - check_invariants accounts for
        # them explicitly, so invariants stay assertable mid-fault.
        self._quarantined: List[int] = []
        self._refs = np.zeros(num_pages, np.int32)
        self._slot_pages: List[List[int]] = [[] for _ in range(max_batch)]
        self.table = np.zeros((max_batch, self.max_pages_per_seq), np.int32)
        # page-movement counters (serve/telemetry.py registry; the engine
        # shares its registry in, a standalone allocator gets its own)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        m = self.metrics
        self._m_alloc = m.counter("pool_pages_allocated_total",
                                  "Private pages handed out by alloc/cow")
        self._m_freed = m.counter("pool_pages_freed_total",
                                  "Pages whose last reference dropped and "
                                  "returned to the free list")
        self._m_attach = m.counter("pool_pages_attached_total",
                                   "Shared-page attachments (prefix-cache "
                                   "reuse; one refcount increment each)")
        self._m_cow = m.counter("pool_cow_pages_total",
                                "Copy-on-write page splits")
        self._m_free_g = m.gauge("pool_free_pages",
                                 "Pages currently on the free list")
        self._m_used_g = m.gauge("pool_used_pages",
                                 "Usable pages currently referenced")
        self._note_pool()

    def _note_pool(self):
        self._m_free_g.set(len(self._free))
        self._m_used_g.set(self.used_pages)

    # -- queries ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.usable_pages - len(self._free) - len(self._quarantined)

    @property
    def quarantined_pages(self) -> int:
        return len(self._quarantined)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def slot_pages(self, slot: int) -> List[int]:
        return list(self._slot_pages[slot])

    def refcount(self, page: int) -> int:
        return int(self._refs[page])

    def live_pages(self) -> int:
        """Distinct pages referenced by at least one slot (the serving
        working set; excludes pages held only by a prefix cache)."""
        return len({p for lst in self._slot_pages for p in lst})

    # -- mutation ---------------------------------------------------------
    def alloc(self, slot: int, n: int) -> List[int]:
        """Append n private pages to `slot`; returns the slot's FULL page
        list (shared pages first if any were attached)."""
        if n > len(self._free):
            raise OutOfPages(f"want {n} pages, {len(self._free)} free")
        owned = self._slot_pages[slot]
        if len(owned) + n > self.max_pages_per_seq:
            raise ValueError(f"slot {slot} would exceed max_seq "
                             f"({len(owned)} + {n} pages)")
        take = [self._free.pop() for _ in range(n)]
        for p in take:
            self._refs[p] = 1
        self.table[slot, len(owned):len(owned) + n] = take
        owned.extend(take)
        self._m_alloc.inc(n)
        self._note_pool()
        return list(owned)

    def attach(self, slot: int, pages: List[int]) -> List[int]:
        """Append already-referenced pages to `slot` (refcount + 1 each);
        returns the slot's full page list.  The caller (the prefix cache)
        guarantees the pages hold valid K/V for the slot's prompt prefix."""
        owned = self._slot_pages[slot]
        if len(owned) + len(pages) > self.max_pages_per_seq:
            raise ValueError(f"slot {slot} would exceed max_seq "
                             f"({len(owned)} + {len(pages)} pages)")
        for p in pages:
            if self._refs[p] <= 0:
                raise ValueError(f"cannot attach free page {p}")
            self._refs[p] += 1
        self.table[slot, len(owned):len(owned) + len(pages)] = pages
        owned.extend(pages)
        self._m_attach.inc(len(pages))
        return list(owned)

    def unref(self, page: int):
        """Drop one reference; the last reference frees the page."""
        if page == 0 or self._refs[page] <= 0:
            raise ValueError(f"unref of page {page} (refs "
                             f"{int(self._refs[page])})")
        self._refs[page] -= 1
        if self._refs[page] == 0:
            self._free.append(page)
            self._m_freed.inc()
            self._note_pool()

    def cow(self, slot: int, index: int):
        """Replace the shared page at `slot` position `index` with a fresh
        private copy (bookkeeping only - the engine copies the device-side
        page data).  Returns (old_page, new_page)."""
        if not self._free:
            raise OutOfPages("copy-on-write needs a free page")
        old = self._slot_pages[slot][index]
        new = self._free.pop()
        self._refs[new] = 1
        self._slot_pages[slot][index] = new
        self.table[slot, index] = new
        self._m_alloc.inc()
        self._m_cow.inc()
        self.unref(old)
        self._note_pool()
        return old, new

    def free_slot(self, slot: int):
        """Drop `slot`'s reference on every page it holds and null its
        table row; pages nobody else references return to the pool."""
        for p in reversed(self._slot_pages[slot]):
            self.unref(p)
        self._slot_pages[slot] = []
        self.table[slot, :] = 0

    def detach(self, slot: int) -> List[int]:
        """Empty `slot`'s page list and table row WITHOUT touching
        refcounts; returns the list.  The caller takes over each page's
        reference (prefix-cache publish transfers them to the tree)."""
        pages = self._slot_pages[slot]
        self._slot_pages[slot] = []
        self.table[slot, :] = 0
        return pages

    def quarantine(self, n: int) -> int:
        """Withhold up to `n` FREE pages from circulation (returns how many
        were actually taken).  The deterministic page-pool-exhaustion
        fault: admission sees a smaller free list and backpressures (or
        preempts) exactly as under real pressure, while the pages - never
        referenced, never free - stay fully accounted in
        check_invariants.  Referenced pages are never touched, so no
        in-flight KV is ever yanked."""
        take = min(n, len(self._free))
        for _ in range(take):
            self._quarantined.append(self._free.pop())
        self._note_pool()
        return take

    def release_quarantine(self) -> int:
        """Return every quarantined page to the free list (fault over);
        returns how many came back."""
        n = len(self._quarantined)
        while self._quarantined:
            self._free.append(self._quarantined.pop())
        self._note_pool()
        return n

    def table_device(self) -> jnp.ndarray:
        """The block table as a device array (upload is max_batch * n_max
        int32s - trivial next to one decode step).  The host mirror is
        COPIED first: on CPU backends jnp.asarray of a suitably-aligned
        numpy array can be zero-copy, and this table is mutated in place
        by every alloc/free/preempt - an aliased upload would let those
        host writes silently retarget in-flight device reads (a real,
        alignment-lottery race, not a hypothetical)."""
        return jnp.asarray(self.table.copy())

    # -- invariants --------------------------------------------------------
    def check_invariants(self, tree_pages=()):
        """Allocator accounting must balance: refcounts equal the number of
        holders (slot memberships + prefix-cache membership), no page is
        both free and referenced, the null page is never handed out, and
        every block-table row mirrors its slot's page list exactly (no
        page both free and mapped through a stale row).  The serve-path
        test fixtures call this after every tick (tests/traffic.py)."""
        tree = set(tree_pages)
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate page in free list"
        assert 0 not in free, "null page on the free list"
        quarantined = set(self._quarantined)
        assert len(quarantined) == len(self._quarantined), \
            "duplicate page in quarantine"
        assert not quarantined & free, "page both free and quarantined"
        assert all(int(self._refs[p]) == 0 for p in quarantined), \
            "referenced page in quarantine"
        counts: dict = {}
        for lst in self._slot_pages:
            for p in lst:
                counts[p] = counts.get(p, 0) + 1
        for p in tree:
            counts[p] = counts.get(p, 0) + 1
        assert 0 not in counts, "null page referenced"
        for p in range(1, self.num_pages):
            r = int(self._refs[p])
            assert r == counts.get(p, 0), \
                f"page {p}: refcount {r} != holders {counts.get(p, 0)}"
            if p in quarantined:
                continue                 # checked above: refcount 0, not free
            if p <= self.usable_pages:
                assert (p in free) == (r == 0), \
                    f"page {p} both free and referenced (refs {r})"
            else:
                assert r == 0 and p not in free, \
                    f"page {p} beyond the usable cap is in circulation"
        for slot, pages in enumerate(self._slot_pages):
            row = self.table[slot]
            assert row[:len(pages)].tolist() == pages, \
                f"slot {slot}: table row diverged from page list"
            assert not row[len(pages):].any(), \
                f"slot {slot}: stale table entries past its page list"
        referenced = sum(1 for p in range(1, self.num_pages)
                         if self._refs[p] > 0)
        assert len(free) + referenced + len(quarantined) \
            == self.usable_pages, \
            f"page conservation violated: {len(free)} free + {referenced} " \
            f"referenced + {len(quarantined)} quarantined " \
            f"!= {self.usable_pages} usable"
        assert all(p <= self.usable_pages for p in free), \
            "page beyond the usable cap on the free list"
