"""Paged KV-cache bookkeeping: fixed-size pages, a free list, block tables.

The device side is a global page pool per layer - (num_pages, page_size,
Hkv, D) slabs shared by every sequence (see models/model.py init_cache) -
plus one (max_batch, max_pages_per_seq) int32 block table.  This module owns
the HOST side: which pages are free, which belong to which slot, and the
numpy mirror of the block table.  All methods are O(pages moved); nothing
here touches jax except the tiny block-table upload.

Page 0 is the reserved NULL page.  Block-table rows of idle slots point at
it, so the batched decode step's masked K/V writes from inactive lanes land
in a page no live sequence owns (reads are masked by `lens` anyway).  Usable
capacity is therefore ``num_pages - 1`` pages.

Capacity math (see docs/serving.md): a request of P prompt tokens with N
generation budget holds ceil((P + N) / page_size) pages from admission to
completion, vs. a dense slot's ceil(max_seq / page_size).  With mixed
request lengths the pool can be sized well below max_batch * max_seq and
still never reject mid-flight: admission reserves the worst case up front,
so the only backpressure point is `can_alloc` at admit time.
"""
from __future__ import annotations

import math
from typing import List

import jax.numpy as jnp
import numpy as np

from ..configs.base import (ModelConfig, ServeConfig, dense_equivalent_pages,
                            pages_for_tokens)

# canonical page math lives in configs.base; re-exported under the serving
# vocabulary ("how many pages does this request need")
pages_needed = pages_for_tokens


def dense_kv_bytes(cfg: ModelConfig, scfg: ServeConfig) -> int:
    """Bytes of the dense (L, max_batch, max_seq, Hkv, D) K+V cache."""
    dt = jnp.dtype(cfg.dtype).itemsize
    return (2 * cfg.n_layers * scfg.max_batch * scfg.max_seq
            * cfg.n_kv_heads * cfg.head_dim * dt)


def paged_kv_bytes(cfg: ModelConfig, scfg: ServeConfig,
                   num_pages: int = 0) -> int:
    """Bytes of the paged (L, num_pages, page_size, Hkv, D) K+V pool."""
    if num_pages <= 0:
        num_pages = dense_equivalent_pages(scfg.max_batch, scfg.max_seq,
                                           scfg.page_size)
    dt = jnp.dtype(cfg.dtype).itemsize
    return (2 * cfg.n_layers * num_pages * scfg.page_size
            * cfg.n_kv_heads * cfg.head_dim * dt)


class OutOfPages(RuntimeError):
    """Raised by alloc() when the free list cannot cover a reservation."""


class PageAllocator:
    """Free-list page allocator + per-slot page lists + block-table mirror."""

    def __init__(self, num_pages: int, page_size: int, max_batch: int,
                 max_seq: int):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is the null page)")
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_pages_per_seq = pages_needed(max_seq, page_size)
        # LIFO free list; page 0 stays reserved forever
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._slot_pages: List[List[int]] = [[] for _ in range(max_batch)]
        self.table = np.zeros((max_batch, self.max_pages_per_seq), np.int32)

    # -- queries ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def slot_pages(self, slot: int) -> List[int]:
        return list(self._slot_pages[slot])

    # -- mutation ---------------------------------------------------------
    def alloc(self, slot: int, n: int) -> List[int]:
        """Append n pages to `slot`; returns the slot's FULL page list."""
        if n > len(self._free):
            raise OutOfPages(f"want {n} pages, {len(self._free)} free")
        owned = self._slot_pages[slot]
        if len(owned) + n > self.max_pages_per_seq:
            raise ValueError(f"slot {slot} would exceed max_seq "
                             f"({len(owned)} + {n} pages)")
        take = [self._free.pop() for _ in range(n)]
        self.table[slot, len(owned):len(owned) + n] = take
        owned.extend(take)
        return list(owned)

    def free_slot(self, slot: int):
        """Return all of `slot`'s pages to the pool and null its table row."""
        self._free.extend(reversed(self._slot_pages[slot]))
        self._slot_pages[slot] = []
        self.table[slot, :] = 0

    def table_device(self) -> jnp.ndarray:
        """The block table as a device array (upload is max_batch * n_max
        int32s - trivial next to one decode step)."""
        return jnp.asarray(self.table)
