"""Serving steps: batched single-token decode + (dense or paged) prefill.

The decode step is cache-layout agnostic: pass the dense {"k","v"} cache or
the paged {"k_pages","v_pages","block_table"} cache and decode_step routes
to the matching kernel (kernels/flash_decode.py).

Lane masking contract (what preemption and chunked prefill lean on): the
fused decode step computes every lane, but a lane whose `lens` is 0 and
whose block-table row is zeroed writes its K/V into the reserved null page
and its `live` mask keeps tokens/lens untouched - so the engine can park,
preempt, or mid-prefill a slot and still run one batched launch over the
full width without corrupting any live page.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import Model
from . import sampling


def make_serve_step(model: Model, *, seq_parallel: bool = False):
    """serve_step(params, cache, tokens (B,1), lens (B,)) ->
    (logits (B,1,V), new_cache).  One new token against the KV cache."""

    def serve_step(params, cache, tokens, lens):
        return model.decode_step(params, tokens, lens, cache,
                                 seq_parallel=seq_parallel)

    return serve_step


def make_prefill_step(model: Model):
    """prefill_step(params, batch, cache) -> (last_logits, cache, lens)."""

    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)

    return prefill_step


def make_paged_prefill_step(model: Model):
    """paged_prefill_step(params, batch, cache, page_ids) ->
    (last_logits, cache, lens).  batch["tokens"]: (1, S_pad) prompt padded
    to a page multiple, real length in batch["true_lens"]; page_ids:
    (S_pad // page_size,) pages owned by the sequence (PageAllocator)."""

    def paged_prefill_step(params, batch, cache, page_ids):
        return model.prefill_paged(params, batch, cache, page_ids)

    return paged_prefill_step


def make_suffix_prefill_step(model: Model):
    """suffix_prefill_step(params, batch, cache, page_row) ->
    (last_logits, cache, lens).  Prefix-cached prefill: batch["tokens"]:
    (1, S_pad) holds only the UNCACHED prompt suffix (zero-padded), its
    absolute start position in batch["offset"], the FULL prompt length in
    batch["true_lens"]; page_row: (n_max,) the sequence's block-table row
    with cached prefix pages first (serve/prefix_cache.py)."""

    def suffix_prefill_step(params, batch, cache, page_row):
        return model.prefill_suffix(params, batch, cache, page_row)

    return suffix_prefill_step


def make_chunk_prefill_step(model: Model):
    """chunk_prefill_step(params, batch, cache, page_row) ->
    (chunk_last_logits, cache, cursor).  One MID-PROMPT chunk of a
    token-budget scheduled prefill (serve/scheduler.py): batch["tokens"]:
    (1, S_pad) the chunk (zero-padded to a page multiple), its absolute
    start in batch["offset"], and the cursor AFTER the chunk's last real
    token in batch["true_lens"] (= offset + real chunk length; equals the
    full prompt length only for the final chunk, whose logits seed
    decoding); page_row: (n_max,) the sequence's block-table row."""

    def chunk_prefill_step(params, batch, cache, page_row):
        return model.prefill_chunk(params, batch, cache, page_row)

    return chunk_prefill_step


def make_chunk_batch_step(model: Model, *, temperature: float,
                          top_k: int = 0, top_p: float = 1.0,
                          tp_mesh=None):
    """chunk_batch_step(params, batch, cache, page_tables, tokens, lens,
    key) -> (cache, tokens, lens).  ONE jitted launch for a whole tick's
    prefill plan: executes every packed chunk row (Model.prefill_chunks),
    samples the first token of every row that COMPLETED its prompt
    device-side, and folds the results into the engine's (B, 1) tokens
    and (B,) lens with single masked scatters - no per-slot host
    dispatches, no logits ever shipped to the host.

    batch carries the scheduler's pack (serve/scheduler.py ChunkBatch):
    "tokens" (K, S), "offset" (K,), "true_lens" (K,), and "final_slot"
    (K,) - the slot of each final row, `max_batch` (out of range, dropped
    by mode="drop") for non-final and dead padding rows.  `key` feeds
    temperature > 0 sampling and is ignored at 0.  tp_mesh head-shards
    the chunk kernel across the serve mesh (kernels/ops.py)."""

    def chunk_batch_step(params, batch, cache, page_tables, tokens, lens,
                         key):
        logits, cache, cursors = model.prefill_chunks(params, batch, cache,
                                                      page_tables,
                                                      tp_mesh=tp_mesh)
        toks = sample_token(logits, temperature=temperature, top_k=top_k,
                            top_p=top_p, key=key)
        slots = batch["final_slot"]
        tokens = tokens.at[slots, 0].set(toks[:, 0], mode="drop")
        lens = lens.at[slots].set(cursors, mode="drop")
        return cache, tokens, lens

    return chunk_batch_step


def make_fused_decode_step(model: Model, *, temperature: float,
                           top_k: int = 0, top_p: float = 1.0,
                           tp_mesh=None):
    """fused_decode_step(params, cache, tokens, lens, live, key) ->
    (cache, tokens, lens).  One batched decode step with sampling fused
    in: lanes where `live` (B,) is True get their sampled token written
    into tokens and their length bumped by one, dead lanes pass through
    untouched - the whole per-tick decode becomes one launch and zero
    per-slot host round-trips.  `key` feeds temperature > 0 sampling and
    is ignored at 0."""

    def fused_decode_step(params, cache, tokens, lens, live, key):
        logits, cache = model.decode_step(params, tokens, lens, cache,
                                          tp_mesh=tp_mesh)
        toks = sample_token(logits, temperature=temperature, top_k=top_k,
                            top_p=top_p, key=key)
        tokens = jnp.where(live[:, None], toks, tokens)
        lens = lens + live.astype(lens.dtype)
        return cache, tokens, lens

    return fused_decode_step


def make_spec_verify_step(model: Model, *, temperature: float,
                          top_k: int = 0, top_p: float = 1.0,
                          tp_mesh=None):
    """spec_verify_step(params, batch, cache, page_tables, tokens, lens,
    key) -> (cache, tokens, lens, n_acc).  ONE jitted launch verifies
    every draft chain the scheduler planned this tick (SpecBatch,
    serve/scheduler.py): row r holds [pending token, d_1..d_m] at
    offset = the slot's lens, scored through the batched chunk kernel
    (Model.verify_chunks) exactly as decode would have scored them one
    launch at a time - the chain's K/V scatters into the slot's reserved
    pages as a side effect, so accepted tokens need no re-decode.

    Acceptance is sample-and-compare (serve/sampling.py): the target's
    token is sampled at every chain position and a draft token is
    accepted iff it matches; the first mismatch (or chain end) yields
    the target's own token as the bonus, so every row nets n_acc + 1
    tokens.  The device updates tokens[slot] to the bonus (the new
    pending token) and lens[slot] to offset + n_acc + 1 (the new KV
    frontier: everything past it is rejected garbage the causal mask
    hides and later writes overwrite - rollback is free).  The host
    learns n_acc in the SAME fetch as the tick's tokens and reconstructs
    the accepted prefix from its own copy of the draft.

    batch: SpecBatch arrays - "tokens" (K, spec_k+1), "offset",
    "true_lens", "q_lens", "draft_lens", "row_slot" (K,) with dead pad
    rows carrying the out-of-range sentinel max_batch the mode="drop"
    scatter discards."""

    def spec_verify_step(params, batch, cache, page_tables, tokens, lens,
                         key):
        logits, cache = model.verify_chunks(params, batch, cache,
                                            page_tables, tp_mesh=tp_mesh)
        tgt = sampling.sample_chain(logits, key, temperature=temperature,
                                    top_k=top_k, top_p=top_p)
        n_acc, bonus = sampling.speculative_accept(
            tgt, batch["tokens"], batch["draft_lens"])
        slots = batch["row_slot"]
        tokens = tokens.at[slots, 0].set(bonus, mode="drop")
        lens = lens.at[slots].set(
            batch["offset"] + n_acc + 1, mode="drop")
        return cache, tokens, lens, n_acc

    return spec_verify_step


def sample_token(logits, *, temperature: float = 0.0, top_k: int = 0,
                 top_p: float = 1.0, key: Optional[jax.Array] = None):
    """logits: (B, 1, V) -> (B, 1) int32 through the device-side sampling
    stack (serve/sampling.py): greedy at temperature <= 0 (key ignored),
    otherwise temperature -> top-k -> top-p -> categorical."""
    return sampling.sample(logits[:, -1], key, temperature=temperature,
                           top_k=top_k, top_p=top_p)[:, None]
