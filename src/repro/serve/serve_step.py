"""Serving steps: batched single-token decode + chunked prefill."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import Model


def make_serve_step(model: Model, *, seq_parallel: bool = False):
    """serve_step(params, cache, tokens (B,1), lens (B,)) ->
    (logits (B,1,V), new_cache).  One new token against the KV cache."""

    def serve_step(params, cache, tokens, lens):
        return model.decode_step(params, tokens, lens, cache,
                                 seq_parallel=seq_parallel)

    return serve_step


def make_prefill_step(model: Model):
    """prefill_step(params, batch, cache) -> (last_logits, cache, lens)."""

    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)

    return prefill_step


def sample_token(logits, *, temperature: float = 0.0,
                 key: Optional[jax.Array] = None):
    """logits: (B, 1, V) -> (B, 1) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    g = jax.random.gumbel(key, logits[:, -1].shape)
    return jnp.argmax(logits[:, -1] / temperature + g, -1
                      ).astype(jnp.int32)[:, None]
