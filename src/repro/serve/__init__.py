from .drafting import ngram_draft
from .engine import ServeEngine
from .paged_cache import (OutOfPages, PageAllocator, dense_kv_bytes,
                          paged_kv_bytes, pages_needed)
from .prefix_cache import RadixPrefixCache
from .router import FleetConfig, FleetRouter, ReplicaState
from .sampling import (apply_top_k, apply_top_p, sample, sample_chain,
                       speculative_accept)
from .scheduler import (ChunkBatch, ChunkTask, DraftTask, Request,
                        RequestState, SpecBatch, TokenBudgetScheduler,
                        bucket_rows)
from .serve_step import (make_chunk_batch_step, make_chunk_prefill_step,
                         make_fused_decode_step, make_paged_prefill_step,
                         make_prefill_step, make_serve_step,
                         make_spec_verify_step, make_suffix_prefill_step,
                         sample_token)
from .telemetry import (Counter, Gauge, Histogram, LaunchRecord,
                        MetricError, MetricsRegistry, Span, SpanTracer,
                        Telemetry, TickRecord, TraceEvent,
                        export_chrome_trace, movement_breakdown)

__all__ = ["ChunkBatch", "ChunkTask", "Counter", "DraftTask", "FleetConfig",
           "FleetRouter", "Gauge",
           "Histogram", "LaunchRecord", "MetricError", "MetricsRegistry",
           "OutOfPages", "PageAllocator", "RadixPrefixCache", "ReplicaState",
           "Request",
           "RequestState", "ServeEngine", "Span", "SpanTracer", "SpecBatch",
           "Telemetry", "TickRecord", "TokenBudgetScheduler", "TraceEvent",
           "apply_top_k", "apply_top_p", "bucket_rows", "dense_kv_bytes",
           "export_chrome_trace", "make_chunk_batch_step",
           "make_chunk_prefill_step", "make_fused_decode_step",
           "make_paged_prefill_step", "make_prefill_step", "make_serve_step",
           "make_spec_verify_step", "make_suffix_prefill_step",
           "movement_breakdown", "ngram_draft", "paged_kv_bytes",
           "pages_needed", "sample", "sample_chain", "sample_token",
           "speculative_accept"]
