from .serve_step import make_prefill_step, make_serve_step, sample_token

__all__ = ["make_prefill_step", "make_serve_step", "sample_token"]
from .engine import Request, ServeEngine
