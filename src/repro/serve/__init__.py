from .engine import Request, ServeEngine
from .paged_cache import (OutOfPages, PageAllocator, dense_kv_bytes,
                          paged_kv_bytes, pages_needed)
from .prefix_cache import RadixPrefixCache
from .serve_step import (make_paged_prefill_step, make_prefill_step,
                         make_serve_step, make_suffix_prefill_step,
                         sample_token)

__all__ = ["OutOfPages", "PageAllocator", "RadixPrefixCache", "Request",
           "ServeEngine", "dense_kv_bytes", "make_paged_prefill_step",
           "make_prefill_step", "make_serve_step",
           "make_suffix_prefill_step", "paged_kv_bytes", "pages_needed",
           "sample_token"]
