from .drafting import ngram_draft
from .engine import ServeEngine
from .paged_cache import (OutOfPages, PageAllocator, dense_kv_bytes,
                          paged_kv_bytes, pages_needed)
from .prefix_cache import RadixPrefixCache
from .sampling import (apply_top_k, apply_top_p, sample, sample_chain,
                       speculative_accept)
from .scheduler import (ChunkBatch, ChunkTask, DraftTask, Request,
                        RequestState, SpecBatch, TokenBudgetScheduler,
                        bucket_rows)
from .serve_step import (make_chunk_batch_step, make_chunk_prefill_step,
                         make_fused_decode_step, make_paged_prefill_step,
                         make_prefill_step, make_serve_step,
                         make_spec_verify_step, make_suffix_prefill_step,
                         sample_token)

__all__ = ["ChunkBatch", "ChunkTask", "DraftTask", "OutOfPages",
           "PageAllocator", "RadixPrefixCache", "Request", "RequestState",
           "ServeEngine", "SpecBatch", "TokenBudgetScheduler",
           "apply_top_k", "apply_top_p", "bucket_rows", "dense_kv_bytes",
           "make_chunk_batch_step", "make_chunk_prefill_step",
           "make_fused_decode_step", "make_paged_prefill_step",
           "make_prefill_step", "make_serve_step", "make_spec_verify_step",
           "make_suffix_prefill_step", "ngram_draft", "paged_kv_bytes",
           "pages_needed", "sample", "sample_chain", "sample_token",
           "speculative_accept"]
