from .engine import Request, ServeEngine
from .paged_cache import (OutOfPages, PageAllocator, dense_kv_bytes,
                          paged_kv_bytes, pages_needed)
from .serve_step import (make_paged_prefill_step, make_prefill_step,
                         make_serve_step, sample_token)

__all__ = ["OutOfPages", "PageAllocator", "Request", "ServeEngine",
           "dense_kv_bytes", "make_paged_prefill_step", "make_prefill_step",
           "make_serve_step", "paged_kv_bytes", "pages_needed",
           "sample_token"]
