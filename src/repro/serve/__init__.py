from .engine import ServeEngine
from .paged_cache import (OutOfPages, PageAllocator, dense_kv_bytes,
                          paged_kv_bytes, pages_needed)
from .prefix_cache import RadixPrefixCache
from .scheduler import (ChunkBatch, ChunkTask, Request, RequestState,
                        TokenBudgetScheduler, bucket_rows)
from .serve_step import (make_chunk_batch_step, make_chunk_prefill_step,
                         make_fused_decode_step, make_paged_prefill_step,
                         make_prefill_step, make_serve_step,
                         make_suffix_prefill_step, sample_token)

__all__ = ["ChunkBatch", "ChunkTask", "OutOfPages", "PageAllocator",
           "RadixPrefixCache", "Request", "RequestState", "ServeEngine",
           "TokenBudgetScheduler", "bucket_rows", "dense_kv_bytes",
           "make_chunk_batch_step", "make_chunk_prefill_step",
           "make_fused_decode_step", "make_paged_prefill_step",
           "make_prefill_step", "make_serve_step",
           "make_suffix_prefill_step", "paged_kv_bytes", "pages_needed",
           "sample_token"]
