"""Fleet router: prefix-aware dispatch over N independent serve engines.

The paper's core economy - keep data where it already lives instead of
round-tripping it through a shared buffer - applies one level above the
kernel: a request whose KV prefix is already resident on some replica
should LAND on that replica, not recompute the prefix somewhere else.
This module is that scheduling layer.  A `FleetRouter` fronts N
independent `ServeEngine` replicas (each with its own page pool, radix
prefix tree, scheduler, and telemetry registry) and owns the fleet
lifecycle: `submit()` / `tick()` (`step()`) / `run_until_done()` mirror
the single-engine API, so callers swap an engine for a fleet without
code changes.

Dispatch is a cache-hit-weighted score, evaluated per submit:

  score(r) = saved_r
             - load_weight     * outstanding_work_r
             - pressure_weight * page_shortfall_r * page_size

  saved_r            prompt tokens replica r's radix tree already caches,
                     read with the side-effect-free `RadixPrefixCache.
                     peek()` - peeking N-1 losing replicas must not bump
                     their LRU stamps, refcounts, or hit counters (a
                     router probe is not a hit).  Capped at len(prompt)-1
                     because a fully cached prompt still recomputes its
                     last token for logits.
  outstanding_work_r replica r's queued + in-flight work tokens (prompt
                     remaining + unspent generation budget), from the
                     engine's registry-backed `load_stats()` - the
                     queue-depth / in-flight-work term.
  page_shortfall_r   pages of the request's reservation that replica r
                     could not grant right now even after LRU eviction
                     (free + evictable headroom) - the page-pool-pressure
                     term, scaled to tokens by page_size.

All three terms are deterministic host-side integers; ties break to the
LOWEST replica index, so a replayed trace routes bit-identically.
Placement is STICKY: a request never migrates after submit (its KV pages
live in one replica's pool; preemption inside a replica parks and
resumes there).  Per-replica admission backpressure is a queue-depth cap
(`spill_queue_depth`): when the best-scoring replica's queue is at the
cap the request SPILLS to the next-best under the cap (counted in
`fleet_spills_total`); if every replica is at the cap the best one takes
it anyway - the cap sheds imbalance, it never rejects work.

Fleet telemetry: the router has its own `MetricsRegistry` (dispatch /
spill / affinity-hit counters, per-replica dispatch labels),
`fleet_snapshot()` adds a summed view over every replica's registry,
`fleet_stats()` aggregates the engines' `stats()`, and `export_trace()`
merges every replica's Perfetto trace into one file with one process
(track group) per replica.

Because jitted serve steps are SHARED per model across engines
(`engine._shared_steps`), every replica runs the very same compiled
executables - greedy outputs for a given request are bit-identical
whichever replica serves it, which is what makes the differential
1-replica-vs-N-replica conformance suite (tests/test_router.py) exact
rather than approximate.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..configs.base import ServeConfig
from ..models import Model
from .engine import ServeEngine
from .paged_cache import pages_needed
from .scheduler import Request
from .telemetry import MetricsRegistry


@dataclass(frozen=True)
class FleetConfig:
    """Router-level knobs (per-replica behavior stays in ServeConfig)."""
    n_replicas: int = 2
    policy: str = "affinity"        # affinity | round_robin
    # score weights: tokens of cached prefix a unit of each term is worth
    load_weight: float = 0.1        # per outstanding work token
    pressure_weight: float = 4.0    # per token of ungrantable reservation
    # per-replica admission backpressure: spill to the next-best replica
    # when the chosen one has this many requests queued (0 = off)
    spill_queue_depth: int = 0

    def validate(self) -> "FleetConfig":
        if self.n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, "
                             f"got {self.n_replicas}")
        if self.policy not in ("affinity", "round_robin"):
            raise ValueError(f"policy must be 'affinity' or 'round_robin', "
                             f"got {self.policy!r}")
        if self.load_weight < 0 or self.pressure_weight < 0:
            raise ValueError("score weights must be >= 0")
        if self.spill_queue_depth < 0:
            raise ValueError(f"spill_queue_depth must be >= 0, "
                             f"got {self.spill_queue_depth}")
        return self


class FleetRouter:
    """N serve-engine replicas behind one engine-shaped front door."""

    def __init__(self, model: Model, params, scfg: ServeConfig,
                 fcfg: Optional[FleetConfig] = None):
        self.fcfg = (fcfg or FleetConfig()).validate()
        self.scfg = scfg
        # replicas share the model/params (and therefore the jitted steps:
        # identical executables => bit-identical numerics across replicas)
        self.engines: List[ServeEngine] = [
            ServeEngine(model, params, scfg)
            for _ in range(self.fcfg.n_replicas)]
        # fleet uid -> (replica index, replica-local Request); fleet uids
        # are issued in submit order, so the SAME trace through different
        # fleet sizes keys its outputs identically
        self._fuid = 0
        self.placement: Dict[int, int] = {}
        self.requests: Dict[int, Request] = {}
        self._rr_next = 0               # round_robin cursor
        self.metrics = MetricsRegistry()
        m = self.metrics
        m.counter("fleet_requests_total", "Requests accepted by the router")
        m.counter("fleet_dispatch_total",
                  "Requests dispatched, per replica", labelnames=("replica",))
        m.counter("fleet_spills_total",
                  "Dispatches diverted off the best-scoring replica by the "
                  "spill_queue_depth admission cap")
        m.counter("fleet_affinity_hits_total",
                  "Dispatches whose chosen replica already cached >= 1 "
                  "prompt page at decision time")
        m.counter("fleet_affinity_hit_tokens_total",
                  "Prompt tokens already cached on the chosen replica at "
                  "decision time (peek-measured, whole pages)")
        m.counter("fleet_ticks_total",
                  "Fleet ticks (one tick of every replica)")
        m.gauge("fleet_replicas", "Engine replicas fronted by this router")
        m.get("fleet_replicas").set(self.fcfg.n_replicas)

    # ------------------------------------------------------------------
    # dispatch scoring
    # ------------------------------------------------------------------
    def _peek_saved(self, eng: ServeEngine,
                    prompt: Sequence[int]) -> Tuple[int, int, bool]:
        """(saved_tokens, cached_pages, full_cover) on one replica, via
        the side-effect-free peek - probing must not perturb the replica's
        LRU order, refcounts, or hit accounting."""
        if eng.prefix is None:
            return 0, 0, False
        pages = eng.prefix.peek(prompt)
        ps = eng.scfg.page_size
        full = len(pages) * ps >= len(prompt)
        saved = min(len(pages) * ps, len(prompt) - 1)
        return saved, len(pages), full

    def _score(self, ridx: int, prompt: Sequence[int],
               n_new: int) -> Tuple[float, int]:
        """(score, saved_tokens) of dispatching to replica `ridx`.  All
        inputs are deterministic host-side state; equal scores are broken
        by replica index at the call site."""
        eng = self.engines[ridx]
        saved, n_cached, full = self._peek_saved(eng, prompt)
        load = eng.load_stats()
        pressure = 0
        if eng.paged:
            need = pages_needed(len(prompt) + n_new, eng.scfg.page_size)
            # cached pages are attached, not allocated - but a fully
            # cached prompt COWs its final page, which costs one fresh one
            need -= max(0, n_cached - (1 if full else 0))
            headroom = load["free_pages"] + load["evictable_pages"]
            pressure = max(0, need - headroom)
        score = (saved
                 - self.fcfg.load_weight * load["outstanding_work_tokens"]
                 - self.fcfg.pressure_weight * pressure
                 * eng.scfg.page_size)
        return score, saved

    def _choose(self, prompt: Sequence[int],
                n_new: int) -> Tuple[int, int, int]:
        """(chosen replica, best-scoring replica, saved tokens on the
        chosen one).  chosen != best iff the admission cap spilled."""
        n = len(self.engines)
        if self.fcfg.policy == "round_robin":
            base = self._rr_next % n
            self._rr_next += 1
            order = [(base + k) % n for k in range(n)]
            saved_of = {}               # peeked lazily, accounting only
        else:
            scored = [self._score(i, prompt, n_new) for i in range(n)]
            # highest score wins; ties to the lowest index (sort is
            # stable and the key's second element pins the order), so
            # replays are bit-reproducible
            order = sorted(range(n), key=lambda i: (-scored[i][0], i))
            saved_of = {i: scored[i][1] for i in range(n)}
        best = chosen = order[0]
        cap = self.fcfg.spill_queue_depth
        if cap:
            for i in order:
                if len(self.engines[i].queue) < cap:
                    chosen = i
                    break
            # every replica at the cap: the best one absorbs the request
            # (backpressure sheds imbalance, it never rejects work)
        if chosen not in saved_of:
            saved_of[chosen] = self._peek_saved(self.engines[chosen],
                                                prompt)[0]
        return chosen, best, saved_of[chosen]

    # ------------------------------------------------------------------
    # engine-shaped lifecycle
    # ------------------------------------------------------------------
    def submit(self, prompt: List[int],
               max_new_tokens: Optional[int] = None,
               stop_tokens: Optional[Sequence[int]] = None,
               priority: int = 0) -> int:
        """Route one request and enqueue it on the chosen replica.
        Returns a FLEET uid (monotone in submit order, stable across
        fleet sizes); the placement is sticky for the request's life."""
        n_new = self.scfg.max_new_tokens if max_new_tokens is None \
            else max_new_tokens
        ridx, best, saved = self._choose(prompt, n_new)
        eng = self.engines[ridx]
        eng.submit(prompt, max_new_tokens, stop_tokens, priority)
        req = eng.sched.queue[-1]
        self._fuid += 1
        fuid = self._fuid
        req.fleet_uid = fuid            # stamped for finished-tick callers
        self.placement[fuid] = ridx
        self.requests[fuid] = req
        m = self.metrics
        m.get("fleet_requests_total").inc()
        m.get("fleet_dispatch_total").labels(str(ridx)).inc()
        if ridx != best:
            m.get("fleet_spills_total").inc()
        if saved > 0:
            m.get("fleet_affinity_hits_total").inc()
            m.get("fleet_affinity_hit_tokens_total").inc(saved)
        return fuid

    def tick(self) -> List[Request]:
        """One fleet iteration: every replica ticks once, in replica
        order (replicas are independent, so the order is cosmetic - but
        fixed, for deterministic merged telemetry).  Returns the requests
        that finished this tick, each stamped with `.fleet_uid`."""
        finished: List[Request] = []
        for eng in self.engines:
            finished.extend(eng.tick())
        self.metrics.get("fleet_ticks_total").inc()
        return finished

    # the engine API spells one iteration `tick`; `step` is the router
    # alias some fleet-level callers prefer
    step = tick

    def run_until_done(self, max_ticks: int = 10_000,
                       on_exhaust: str = "raise") -> List[Request]:
        """Tick until every replica's queue and slots drain (same
        semantics as ServeEngine.run_until_done)."""
        done: List[Request] = []
        for _ in range(max_ticks):
            done.extend(self.tick())
            if self.idle:
                return done
        if self.idle:
            return done
        pending = sum(len(e.queue) + sum(s is not None for s in e.slots)
                      for e in self.engines)
        msg = (f"FleetRouter.run_until_done: {max_ticks} ticks exhausted "
               f"with {pending} requests still pending "
               f"({len(done)} finished)")
        if on_exhaust == "raise":
            raise RuntimeError(msg)
        import warnings
        warnings.warn(msg)
        return done

    @property
    def idle(self) -> bool:
        return all(not e.queue and all(s is None for s in e.slots)
                   for e in self.engines)

    def outputs(self) -> Dict[int, List[int]]:
        """{fleet uid: generated tokens} for every submitted request -
        the differential-conformance view (fleet uids are submit-ordered,
        so 1-replica and N-replica runs of one trace key identically)."""
        return {fuid: list(r.out_tokens)
                for fuid, r in self.requests.items()}

    def check_invariants(self):
        """Every replica's engine invariants plus the router's own
        bookkeeping: placements in range, dispatch counters conserved."""
        for eng in self.engines:
            eng.check_invariants()
        n = len(self.engines)
        assert all(0 <= r < n for r in self.placement.values()), \
            "placement outside the fleet"
        dispatched = sum(
            child.value for _, child in
            self.metrics.get("fleet_dispatch_total").label_items())
        assert dispatched == len(self.placement) \
            == self.metrics.get("fleet_requests_total").value, \
            "dispatch accounting out of sync with placements"

    # ------------------------------------------------------------------
    # fleet telemetry
    # ------------------------------------------------------------------
    _SUM_KEYS = ("requests", "work_tokens", "gen_tokens", "prefill_tokens",
                 "prefix_hit_tokens", "prompt_tokens", "jit_calls",
                 "host_syncs", "chunks_run", "packs_run", "preemptions",
                 "resumes", "priority_boosts", "cow_copies")

    def dispatch_counts(self) -> List[int]:
        """Requests dispatched per replica, replica order."""
        by_label = dict(self.metrics.get("fleet_dispatch_total")
                        .label_items())
        return [int(by_label[(str(i),)].value) if (str(i),) in by_label
                else 0 for i in range(len(self.engines))]

    def fleet_stats(self) -> Dict[str, Any]:
        """Aggregated engine stats (summed per-replica counters) plus the
        router's dispatch accounting - the fleet analog of
        ServeEngine.stats()."""
        per = [e.stats() for e in self.engines]
        out: Dict[str, Any] = {
            k: sum(s[k] for s in per) for k in self._SUM_KEYS}
        out["n_replicas"] = len(self.engines)
        out["policy"] = self.fcfg.policy
        out["ticks"] = int(self.metrics.get("fleet_ticks_total").value)
        out["dispatch"] = self.dispatch_counts()
        out["spills"] = int(self.metrics.get("fleet_spills_total").value)
        out["affinity_hits"] = int(
            self.metrics.get("fleet_affinity_hits_total").value)
        out["affinity_hit_tokens"] = int(
            self.metrics.get("fleet_affinity_hit_tokens_total").value)
        out["per_replica"] = per
        return out

    @staticmethod
    def _sum_value(acc: Dict[str, Any], name: str, value: Any):
        """Fold one replica's metric value into the summed view: scalars
        add, labeled metrics add per label, histograms add count/sum."""
        if isinstance(value, dict):
            if "buckets" in value:          # histogram
                slot = acc.setdefault(name, {"count": 0, "sum": 0.0})
                slot["count"] += value["count"]
                slot["sum"] += value["sum"]
            else:                           # labeled children
                slot = acc.setdefault(name, {})
                for k, v in value.items():
                    slot[k] = slot.get(k, 0) + v
            return
        acc[name] = acc.get(name, 0) + value

    def fleet_snapshot(self) -> Dict[str, Any]:
        """The fleet registry view: the router's own metrics, every
        replica's full registry snapshot, and a `sum` section folding the
        per-replica counters/gauges together (gauges sum too - fleet
        queue depth is the sum of replica queue depths; peak watermarks
        become a fleet-wide upper bound)."""
        replicas = [e.metrics_snapshot() for e in self.engines]
        summed: Dict[str, Any] = {}
        for snap in replicas:
            for name, meta in snap.items():
                self._sum_value(summed, name, meta["value"])
        return {"router": self.metrics.snapshot(),
                "replicas": replicas,
                "sum": summed}

    def export_trace(self, path, clock: str = "wall") -> Dict[str, Any]:
        """Merge every replica's Perfetto trace into one file with one
        process-pair (engine + requests track group) per replica, pids
        offset so Perfetto renders `replica0:engine`, `replica0:requests`,
        `replica1:engine`, ...  Requires ServeConfig(telemetry=True).
        With clock="wall" the replicas share the host clock but not an
        epoch-aligned tracer start; clock="work" is the deterministic,
        replay-stable view."""
        events: List[Dict[str, Any]] = []
        for i, eng in enumerate(self.engines):
            trace = eng.export_trace(None, clock=clock)
            for ev in trace["traceEvents"]:
                ev = dict(ev)
                ev["pid"] = 2 * i + ev["pid"]
                if ev.get("ph") == "M" and ev.get("name") == "process_name":
                    ev["args"] = {
                        "name": f"replica{i}:{ev['args']['name']}"}
                events.append(ev)
        merged = {"traceEvents": events, "displayTimeUnit": "ms",
                  "otherData": {"clock": clock,
                                "n_replicas": len(self.engines)}}
        if path is not None:
            with open(path, "w") as f:
                json.dump(merged, f, indent=None, separators=(",", ":"))
        return merged
